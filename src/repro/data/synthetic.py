"""Deterministic synthetic token pipeline.

Generates a reproducible pseudo-corpus (Zipfian unigrams + a short-range
Markov mixer) so training loss is a meaningful, decreasing signal without
external datasets (offline container).  Every batch is a pure function of
(seed, step) — restart-safe by construction: resuming at step k reproduces
the exact batch stream a non-failed run would have seen, which is what makes
checkpoint/restart bit-identical in the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1         # unigram skew
    markov_mix: float = 0.7     # P(next ~ markov) vs unigram resample
    frontend_len: int = 0       # [audio]/[vlm]: prefix length
    frontend_dim: int = 0


def _unigram_logits(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks ** cfg.zipf_a
    return np.log(probs / probs.sum()).astype(np.float32)


@dataclasses.dataclass
class SyntheticDataset:
    cfg: DataConfig

    def __post_init__(self):
        self._logits = jnp.asarray(_unigram_logits(self.cfg))

    def batch(self, step: int) -> dict[str, Array]:
        """Pure function of (seed, step) -> {tokens, labels, mask[, embeds]}."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k_tok, k_mix, k_shift, k_emb = jax.random.split(key, 4)
        b, s = cfg.global_batch, cfg.seq_len

        base = jax.random.categorical(k_tok, self._logits, shape=(b, s + 1))
        # Markov mixer: with prob markov_mix, token t = f(token t-1) via a
        # fixed pseudo-random permutation (learnable structure).
        perm_mult = 2654435761 % cfg.vocab_size  # Knuth multiplicative hash
        mapped = (base[:, :-1] * perm_mult + 12289) % cfg.vocab_size
        take_markov = jax.random.bernoulli(k_mix, cfg.markov_mix, (b, s))
        toks = jnp.where(take_markov, mapped, base[:, 1:])
        tokens = jnp.concatenate([base[:, :1], toks[:, :-1]], axis=1)
        labels = toks
        mask = jnp.ones((b, s), jnp.float32)

        out = {
            "tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32),
            "mask": mask,
        }
        if cfg.frontend_len:
            out["embeds"] = jax.random.normal(
                k_emb, (b, cfg.frontend_len, cfg.frontend_dim), jnp.float32
            )
            # prefix positions carry no next-token loss
            out["mask"] = mask.at[:, : cfg.frontend_len].set(0.0)
        return out


def make_dataset(model_cfg, seq_len: int, global_batch: int, seed: int = 0):
    return SyntheticDataset(DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        frontend_len=model_cfg.frontend_len if model_cfg.frontend else 0,
        frontend_dim=model_cfg.frontend_dim if model_cfg.frontend else 0,
    ))
