"""Host-side prefetcher: overlaps batch synthesis/IO with device compute.

A small background thread keeps `depth` batches ahead of the training loop
(the latency-sensitive 'CPU-class' traffic stream in the KF scheduler's
terms — see dist/kf_scheduler.py).  On real multi-host topologies each host
prefetches only its data-parallel shard; here the shard is the full batch.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], dict], depth: int = 2,
                 start_step: int = 0):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            step = self._next
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._next = step + 1

    def get(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self.get()
