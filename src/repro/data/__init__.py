"""Data pipeline: deterministic synthetic corpus + host-side prefetch."""
