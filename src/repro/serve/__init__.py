"""Serving substrate: KV/SSM slot caches, continuous-batching engine with
KF-arbitrated prefill/decode scheduling (the paper's technique at the
serving layer)."""
