"""Slot-indexed decode caches: insert prefilled requests, free finished ones.

`lm.DecodeState` stacks per-layer caches with a batch dimension = decode
slots.  This module provides the slot algebra the engine needs: write a
single prefilled request's cache into slot `i`, clear a slot, and track
occupancy.  Works for every cache kind (attention KV, Mamba conv/ssm,
hybrid shared-attn) because it operates structurally on the pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm

Array = jax.Array


def batch_axis_of(path_leaf_shape: tuple, stacked: bool) -> int:
    """Caches are stacked (n_super, B, ...): slot axis is 1; engine-level
    leaves like `length` are (B,): slot axis 0."""
    return 1 if stacked else 0


def insert_request(
    state: lm.DecodeState, prefilled: lm.DecodeState, slot: int | Array
) -> lm.DecodeState:
    """Copy request-0 of `prefilled` (batch=1 state) into `slot` of `state`."""

    def ins(dst: Array, src: Array, axis: int) -> Array:
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        return dst.at[tuple(idx)].set(jnp.take(src, 0, axis=axis))

    new_caches = [
        jax.tree.map(lambda d, s: ins(d, s, 1), dc, sc)
        for dc, sc in zip(state.caches, prefilled.caches)
    ]
    shared = state.shared_kv
    if shared is not None:
        shared = jax.tree.map(
            lambda d, s: ins(d, s, 1), shared, prefilled.shared_kv
        )
    length = state.length.at[slot].set(prefilled.length[0])
    return lm.DecodeState(caches=new_caches, shared_kv=shared, length=length)


def clear_slot(state: lm.DecodeState, slot: int | Array) -> lm.DecodeState:
    """Zero a slot's length (cache contents become dead weight)."""
    return lm.DecodeState(
        caches=[
            jax.tree.map(
                lambda c: c.at[:, slot].set(jnp.zeros_like(c[:, slot]))
                if isinstance(c, jax.Array) and c.ndim >= 2 else c,
                cache,
            )
            for cache in state.caches
        ],
        shared_kv=state.shared_kv,
        length=state.length.at[slot].set(0),
    )


def kv_occupancy(state: lm.DecodeState, max_len: int) -> float:
    """Fraction of cache capacity holding live tokens — the engine's
    'dramfull' (HBM pressure) telemetry signal."""
    total = state.length.sum()
    cap = state.length.shape[0] * max_len
    return float(total) / float(cap)
