"""Continuous-batching engine with KF-arbitrated prefill/decode scheduling.

The paper, transplanted to the serving layer of a shared accelerator pod:

  traffic classes   prefill (new requests)   = bursty, bandwidth-bound (GPU)
                    decode  (active slots)   = steady, latency-sensitive (CPU)
  VC partition      per-iteration token budget split between the classes
                    config 0: 50/50          config 1: 75/25 prefill-boosted
  switch arbiter    interleave ORDER within an iteration
                    config 0: alternate P,D  config 1: P,P,D (Fig. 8's 2:1)
  KF telemetry      z = [kv_occupancy (dramfull), prefill_backlog_tokens
                    (icnt_push), decode_queue_wait (stall_icnt)]
  hysteresis        the same warmup/hold/revert machine (core.allocator)

Modes: 'rr' (static 50/50, the paper's baseline), 'static' (fixed split),
'kf' (full technique).  Time is a virtual clock advanced by a calibrated
cost model (tokens processed), making runs deterministic on CPU; on real
hardware the same engine advances on wall time.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kalman
from repro.core.allocator import (
    PolicyConfig, apply_policy, init_policy_state,
)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serve import cache as cache_lib
from repro.serve.batching import Request


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    mode: str = "kf"             # rr | static | kf
    max_slots: int = 8
    max_len: int = 256
    budget_tokens: int = 256     # per engine iteration
    static_prefill_frac: float = 0.5
    # KF + hysteresis (iteration-scaled analogues of the paper's cycles)
    warmup_iters: int = 4
    hold_iters: int = 2
    revert_iters: int = 8
    kf_q: float = 1e-3
    kf_r: float = 2e-1
    # virtual-clock cost model: seconds per token (prefill is batched ->
    # cheaper per token; decode pays per-step launch overhead)
    c_prefill: float = 1.0e-4
    c_decode: float = 2.5e-4
    c_iter: float = 1.0e-3


@dataclasses.dataclass
class EngineStats:
    finished: list
    iters: int
    clock: float
    kf_signals: list
    configs: list

    def summary(self) -> dict:
        ttfts = [r.ttft for r in self.finished]
        lats = [r.latency for r in self.finished]
        toks = sum(r.tokens_out for r in self.finished)
        return {
            "n_finished": len(self.finished),
            "mean_ttft": float(np.mean(ttfts)) if ttfts else 0.0,
            "p90_ttft": float(np.percentile(ttfts, 90)) if ttfts else 0.0,
            "mean_latency": float(np.mean(lats)) if lats else 0.0,
            "throughput_tok_s": toks / self.clock if self.clock else 0.0,
            "kf_on_frac": float(np.mean(self.configs)) if self.configs else 0.0,
        }


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.state = lm.init_decode_state(ecfg.max_slots, ecfg.max_len, cfg)
        self.slots: list[Optional[Request]] = [None] * ecfg.max_slots
        self.queue: deque[Request] = deque()
        self.clock = 0.0
        self.key = jax.random.PRNGKey(seed)
        self.temperature = temperature
        # KF + policy (paper §3.2 rules, iteration-scaled)
        self.kf_params = kalman.paper_params(q=ecfg.kf_q, r=ecfg.kf_r)
        self.kf_state = kalman.init_state(1)
        self.policy_cfg = PolicyConfig(
            warmup=ecfg.warmup_iters, hold=ecfg.hold_iters,
            revert=ecfg.revert_iters,
        )
        self.policy = init_policy_state()
        self.iter = 0
        self.decode_wait_ema = 0.0
        self._decode_fn = jax.jit(
            lambda p, t, s: lm.decode_step(p, t, s, cfg))
        self._tokens = jnp.zeros((ecfg.max_slots, 1), jnp.int32)
        self.stats = EngineStats([], 0, 0.0, [], [])

    # ---- class telemetry (the paper's three counters) ----
    def _observe(self) -> jnp.ndarray:
        backlog = sum(r.prompt_len for r in self.queue)
        occ = cache_lib.kv_occupancy(self.state, self.ecfg.max_len)
        raw = jnp.asarray([
            occ,                                   # dramfull analogue
            backlog / self.ecfg.budget_tokens,     # icnt_push analogue
            self.decode_wait_ema,                  # stall_icnt analogue
        ], jnp.float32)
        hi = jnp.asarray([1.0, 4.0, 4.0])
        return kalman.normalize_observations(raw, jnp.zeros(3), hi)

    def _config(self) -> int:
        if self.ecfg.mode == "rr":
            return 0
        if self.ecfg.mode == "static":
            return 1 if self.ecfg.static_prefill_frac > 0.5 else 0
        return int(self.policy.config)

    # ---- engine iteration ----
    def submit(self, req: Request):
        # context-window admission: prompt + generation must fit the slot
        limit = self.ecfg.max_len - req.gen_len - 1
        if req.prompt_len > limit:
            req.prompt_len = max(limit, 1)
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _prefill_one(self, req: Request, slot: int):
        tokens = jnp.zeros((1, req.prompt_len), jnp.int32)
        prefilled = lm.prefill_caches(
            self.params, tokens, self.cfg, self.ecfg.max_len)
        self.state = cache_lib.insert_request(self.state, prefilled, slot)
        self.slots[slot] = req
        self.clock += req.prompt_len * self.ecfg.c_prefill
        req.t_first_token = self.clock
        req.tokens_out = 1

    def _decode_batch(self):
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        logits, self.state = self._decode_fn(
            self.params, self._tokens, self.state)
        self.clock += (len(active) * self.ecfg.c_decode + self.ecfg.c_iter)
        for i in active:
            r = self.slots[i]
            r.tokens_out += 1
            if r.tokens_out >= r.gen_len:
                r.t_done = self.clock
                self.stats.finished.append(r)
                self.slots[i] = None
                self.state = cache_lib.clear_slot(self.state, i)

    def step(self):
        """One engine iteration under the active configuration."""
        config = self._config()
        budget = self.ecfg.budget_tokens
        prefill_frac = 0.75 if config == 1 else 0.5
        prefill_budget = int(budget * prefill_frac)
        # arbitration pattern (paper Fig. 8): config 0 alternates P,D;
        # config 1 issues P,P,D
        pattern = ["P", "P", "D"] if config == 1 else ["P", "D"]
        decode_due = any(r is not None for r in self.slots)
        t_wait_start = self.clock
        did_work = False
        did_prefill = False

        for phase in pattern * 4:   # a few rounds per iteration
            if phase == "P":
                free = self._free_slots()
                # budget caps ADDITIONAL prefills; the first one always
                # proceeds (deadlock-free even when prompt > budget share)
                if (self.queue and free
                        and self.queue[0].arrival <= self.clock
                        and (not did_prefill
                             or self.queue[0].prompt_len <= prefill_budget)):
                    req = self.queue.popleft()
                    prefill_budget -= req.prompt_len
                    self._prefill_one(req, free[0])
                    did_work = did_prefill = True
            else:
                if any(r is not None for r in self.slots):
                    self._decode_batch()
                    did_work = True
        # decode-wait telemetry: how long decode waited behind prefills
        if decode_due:
            self.decode_wait_ema = (0.8 * self.decode_wait_ema
                                    + 0.2 * (self.clock - t_wait_start))
        # idle: advance the virtual clock to the next arrival
        if not did_work and self.queue:
            self.clock = max(self.clock, self.queue[0].arrival)
        self.iter += 1

        if self.ecfg.mode == "kf":
            z = self._observe()
            self.kf_state, _, _ = kalman.step(self.kf_params, self.kf_state, z)
            signal = kalman.binarize(self.kf_state.x[0])
            self.policy = apply_policy(
                self.policy_cfg, self.policy, signal, jnp.int32(self.iter))
        self.stats.kf_signals.append(int(self._config()))
        self.stats.configs.append(config)
        self.stats.iters = self.iter
        self.stats.clock = self.clock

    def run(self, requests: list[Request], max_iters: int = 1000) -> EngineStats:
        for r in requests:
            self.submit(r)
        while (self.queue or any(self.slots)) and self.iter < max_iters:
            self.step()
        return self.stats
