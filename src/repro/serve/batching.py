"""Request workload generator: Markov-modulated bursty arrivals.

The paper's GPU traffic (Fig. 4) is bursty — phases of heavy injection
alternating with calm — while CPU traffic is steady.  The serving analogue:
prefill demand (new requests, bandwidth-bound) arrives in bursts; decode
demand (active sequences, latency-sensitive) is steady.  The generator
reproduces that shape so the KF has real dynamics to track.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float          # virtual-clock arrival time
    prompt_len: int
    gen_len: int
    # measured by the engine:
    t_first_token: float = -1.0
    t_done: float = -1.0
    tokens_out: int = 0

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.arrival

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 64
    mean_prompt: int = 96
    mean_gen: int = 24
    burst_rate: float = 2.5      # arrivals per unit time in a burst
    calm_rate: float = 0.25
    p_enter_burst: float = 0.15  # per-arrival phase-switch probabilities
    p_exit_burst: float = 0.3
    seed: int = 0


def generate(cfg: WorkloadConfig) -> list[Request]:
    rng = np.random.default_rng(cfg.seed)
    reqs = []
    t = 0.0
    burst = False
    for rid in range(cfg.n_requests):
        if burst and rng.random() < cfg.p_exit_burst:
            burst = False
        elif not burst and rng.random() < cfg.p_enter_burst:
            burst = True
        rate = cfg.burst_rate if burst else cfg.calm_rate
        t += rng.exponential(1.0 / rate)
        prompt = max(8, int(rng.gamma(4.0, cfg.mean_prompt / 4.0)))
        gen = max(4, int(rng.gamma(2.0, cfg.mean_gen / 2.0)))
        reqs.append(Request(rid=rid, arrival=t, prompt_len=prompt,
                            gen_len=gen))
    return reqs
