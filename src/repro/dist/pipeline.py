"""GPipe-style pipeline parallelism over a `stage` mesh axis.

`pipeline_apply` runs S identical-signature stages on S devices with the
classic rotating schedule: at tick t, stage s computes microbatch t-s and
ppermutes its activation to stage s+1, so the pipe drains in
n_micro + S - 1 ticks with every stage busy in the steady state.  The whole
schedule lives inside one shard_map + lax.scan, is differentiable (ppermute
and psum have transposes), and degenerates to a plain per-microbatch apply
at S = 1 — tested against that oracle in tests/test_dist.py and against the
4-stage composition in tests/test_multidevice.py.

Stages must preserve the activation shape (the rotating buffer is a single
(mb, ...) slot); parameters carry a leading stage dim (see `split_stages`).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding

Array = jax.Array


def split_stages(params: Any, n_stages: int) -> Any:
    """Fold a leading layer dim into (n_stages, layers_per_stage, ...)."""
    def split(leaf):
        n = leaf.shape[0]
        if n % n_stages:
            raise ValueError(
                f"cannot split {n} layers into {n_stages} stages")
        return leaf.reshape((n_stages, n // n_stages) + leaf.shape[1:])

    return jax.tree.map(split, params)


def pipeline_apply(
    stage_fn: Callable[[Any, Array], Array],
    params: Any,
    microbatches: Array,
    mesh,
    *,
    stage_axis: str = "stage",
) -> Array:
    """Apply S pipeline stages to every microbatch.

    params:       pytree with a leading stage dim of size S on every leaf
                  (device s applies ``stage_fn(params[s], x)``).
    microbatches: (n_micro, mb, ...) activations, replicated.
    Returns       (n_micro, mb, ...) — each microbatch through all S stages.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_stages = sizes[stage_axis]
    n_micro = microbatches.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(w_stack, mbs):
        s = jax.lax.axis_index(stage_axis)
        w_local = jax.tree.map(lambda l: l[0], w_stack)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 pulls microbatch t from the feed; others read the ring
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(mbs, mb_idx, 0,
                                                keepdims=False)
            y = stage_fn(w_local, jnp.where(s == 0, feed, buf))
            # the last stage finishes microbatch t - (S-1) at tick t
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (s == n_stages - 1) & (t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0,
                                               keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(valid, y, cur), out_idx, 0)
            return (jax.lax.ppermute(y, stage_axis, perm), outs), None

        outs0 = jnp.zeros_like(mbs)
        (_, outs), _ = jax.lax.scan(
            tick, (jnp.zeros_like(mbs[0]), outs0),
            jnp.arange(n_micro + n_stages - 1))
        # only the last stage holds results; sum-select replicates them
        outs = jax.lax.psum(
            jnp.where(s == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    w_specs = jax.tree.map(lambda _: P(stage_axis), params)
    return sharding.shard_map(
        local, mesh=mesh,
        in_specs=(w_specs, P()), out_specs=P(),
        axis_names={stage_axis}, check_vma=False,
    )(params, microbatches)
