"""KF scheduler: the paper's control loop at the fleet layer.

Two deployments of the same predictor:

  KFScheduler — ONE filter arbitrating which pre-compiled train-step
    variant runs next (balanced vs comm-priority), exactly the paper's
    {equal split, GPU-boosted} configuration pair: telemetry -> KF epoch
    update -> binarized signal -> hysteresis machine (core.allocator's
    warmup/hold/revert rules) -> variant index.

  FleetKF — a BANK of filters, one per (pod x traffic-class) link, advanced
    in lockstep by the Pallas kf_bank kernel each telemetry epoch; emits a
    per-link throttle(0)/boost(1) signal like the paper's per-router VC
    reallocation.  Algebraically identical to the single-filter
    core.kalman step (congruence-tested in tests/test_dist.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import kalman
from repro.core.allocator import (
    PolicyConfig, apply_policy, init_policy_state,
)
from repro.dist.telemetry import StaticCosts, Telemetry  # noqa: F401  (re-export)
from repro.kernels.kf_bank import ops as kf_ops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Step-scaled analogues of the paper's cycle counts (§3.2)."""

    epoch_steps: int = 10        # KF measurement cadence
    warmup_steps: int = 30       # ignore KF decisions before this step
    hold_steps: int = 20         # freeze after any reallocation
    revert_steps: int = 10_000   # max boosted steps before forced fallback
    kf_q: float = 1e-3           # process noise
    kf_r: float = 1e-1           # observation noise (per counter)


class KFScheduler:
    """Dispatches between pre-compiled step variants (train/loop.py)."""

    def __init__(self, cfg: SchedulerConfig,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg
        self.telemetry = (telemetry if telemetry is not None
                          else Telemetry(costs_by_variant={}))
        self.kf_params = kalman.paper_params(q=cfg.kf_q, r=cfg.kf_r)
        self.kf_state = kalman.init_state(1)
        self.policy_cfg = PolicyConfig(
            warmup=cfg.warmup_steps, hold=cfg.hold_steps,
            revert=cfg.revert_steps)
        self.policy = init_policy_state()
        self.step_count = 0
        self.signals: list[int] = []

    @property
    def variant(self) -> int:
        return int(self.policy.config)

    def on_step(self) -> int:
        """Advance one step; at epoch boundaries run the KF + policy."""
        self.step_count += 1
        if self.cfg.epoch_steps > 0 and \
                self.step_count % self.cfg.epoch_steps == 0:
            z = self.telemetry.observe()
            self.kf_state, _, _ = kalman.step(
                self.kf_params, self.kf_state, z)
            signal = kalman.binarize(self.kf_state.x[0])
            self.signals.append(int(signal))
            self.policy = apply_policy(
                self.policy_cfg, self.policy, signal,
                jnp.int32(self.step_count))
        return self.variant


class FleetKF:
    """Bank of n independent scalar-state filters on the Pallas kernel.

    One filter per (pod x traffic-class); `epoch` advances every filter one
    predict+correct cycle on the epoch's observation matrix and returns the
    binarized boost signals."""

    def __init__(self, n: int, cfg: Optional[SchedulerConfig] = None,
                 h: tuple[float, ...] = (1.0, 1.0, 1.0)):
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.n = n
        self.h = jnp.asarray(h, jnp.float32)
        self.r = jnp.full((len(h),), self.cfg.kf_r, jnp.float32)
        # matches core.kalman.init_state(p0=1.0), leaf-for-leaf on n=1
        self.x = jnp.zeros((n,), jnp.float32)
        self.p = jnp.ones((n,), jnp.float32)

    def epoch(self, z: Array) -> Array:
        """z: (n, m) normalized observations -> (n,) int32 boost signals."""
        z = jnp.asarray(z, jnp.float32)
        self.x, self.p = kf_ops.kf_bank_step(
            self.x, self.p, z, self.h, self.r,
            a=1.0, q=self.cfg.kf_q)
        return kalman.binarize(self.x)
