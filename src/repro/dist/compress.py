"""int8 gradient compression with error feedback (EF).

Used by the comm-priority train-step variant (train/step.py): the per-chip
gradient shard is quantized to int8 for the cross-pod (DCI) all-gather —
1 byte/element on the expensive wire — and the quantization error is kept
locally and added back into the next step's gradient, so the bias of
repeated rounding vanishes (the compression is contractive, not a
different optimizer; tested in tests/test_dist.py and the multi-device
loss-trajectory equivalence test).

Contract (tests/test_properties.py):
  |g + r - dequantize(q, scale)| <= scale / 2   elementwise
  new_residual == (g + r) - dequantize(q, scale)  exactly (fp32)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_LEVELS = 127.0  # symmetric int8 grid, -127..127 (no -128 asymmetry)


def quantize_ef(g: Array, residual: Array) -> tuple[Array, Array, Array]:
    """Quantize `g + residual` to int8. Returns (q, scale, new_residual).

    scale is a scalar (per-tensor absmax / 127); new_residual carries the
    rounding error forward.  All accumulation in fp32.
    """
    acc = g.astype(jnp.float32) + residual.astype(jnp.float32)
    scale = jnp.max(jnp.abs(acc)) / _LEVELS
    # all-zero tensors: keep the divide well-defined (q comes out 0 anyway)
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(acc / scale), -_LEVELS, _LEVELS).astype(jnp.int8)
    new_residual = acc - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def dequantize(q: Array, scale: Array) -> Array:
    """Inverse of `quantize_ef` (up to the rounding the residual carries)."""
    return q.astype(jnp.float32) * scale
