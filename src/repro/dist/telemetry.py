"""Fleet telemetry: the KF scheduler's measurement path.

The paper feeds its filter three normalized NoC counters
(GPU_Stall_Dramfull, GPU_Icnt_Push, GPU_Stall_Icnt-Shader).  At the
training-fleet layer the analogues are:

  z1 dramfull   — HBM demand of the balanced step vs chip capacity
  z2 icnt_push  — collective (fabric) bytes of the balanced step vs the
                  wire budget `comm_scale`
  z3 stall      — fraction of step time spent waiting on input
                  (prefetch starvation), from the live StepTimer

z1/z2 come from a static per-variant cost model (`StaticCosts`, typically
filled from the dry-run's compiled-cost analysis); they measure DEMAND
under the balanced schedule, which reconfiguration relieves but does not
change — so the signal is stable and the hysteresis machine, not
measurement noise, decides when to revert (mirroring the paper, where the
counters characterize the workload's pressure on the fabric).
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core import kalman


@dataclasses.dataclass(frozen=True)
class StaticCosts:
    """Per-step cost of one compiled variant (from dry-run analysis)."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0


class StepTimer:
    """Wall-clock step phases: begin -> input ready -> end.

    Driven by train/loop.py around each dispatched step; exports an EMA of
    the input-wait fraction (the stall observation) and of step time (the
    straggler/FleetKF signal at pod scale)."""

    def __init__(self, ema: float = 0.8):
        self._ema = ema
        self.wait_frac = 0.0
        self.step_time = None
        self._t0 = None
        self._t_ready = None

    def step_begin(self) -> None:
        self._t0 = time.perf_counter()
        self._t_ready = None

    def mark_input_ready(self) -> None:
        if self._t0 is not None:
            self._t_ready = time.perf_counter()

    def step_end(self) -> None:
        if self._t0 is None:
            # an end without a begin must not leave a ready mark behind to
            # be attributed to the NEXT step's wait time
            self._t_ready = None
            return
        now = time.perf_counter()
        dt = max(now - self._t0, 1e-12)
        # `is not None`: perf_counter() can legitimately be 0.0 (counter
        # epoch), and a falsy check would silently drop that wait sample
        wait = (self._t_ready - self._t0) if self._t_ready is not None else 0.0
        frac = min(max(wait / dt, 0.0), 1.0)
        # seed the EMA with the first observed fraction instead of decaying
        # from 0.0, which under-reports stalls for the first ~1/(1-ema) steps
        self.wait_frac = (frac if self.step_time is None
                          else self._ema * self.wait_frac
                          + (1 - self._ema) * frac)
        self.step_time = (dt if self.step_time is None
                          else 0.9 * self.step_time + 0.1 * dt)
        self._t0 = self._t_ready = None


@dataclasses.dataclass
class Telemetry:
    """Measurement source for KFScheduler.

    costs_by_variant maps variant index -> StaticCosts; only variant 0
    (the balanced schedule) feeds the observations today — it IS the
    demand — but the full table is the declared cost-model interface
    (a relief-aware signal would read the other entries)."""

    costs_by_variant: dict
    comm_scale: float = 1e9       # fabric bytes/step considered saturating
    hbm_capacity: float = 16e9    # per-chip HBM budget
    timer: StepTimer = dataclasses.field(default_factory=StepTimer)

    def observe(self) -> jnp.ndarray:
        """The 3-vector z, normalized to [-1, 1] (paper §3.2)."""
        demand = self.costs_by_variant.get(0, StaticCosts())
        raw = jnp.asarray([
            demand.hbm_bytes / self.hbm_capacity,
            demand.collective_bytes / self.comm_scale,
            self.timer.wait_frac,
        ], jnp.float32)
        hi = jnp.asarray([1.0, 2.0, 1.0], jnp.float32)
        return kalman.normalize_observations(raw, jnp.zeros((3,)), hi)
