"""repro.dist — the distribution subsystem.

The paper predicts per-link bandwidth demand with a Kalman filter and
reallocates NoC resources between pre-defined router configurations; this
package applies the same technique one layer up, to a training/serving
fleet (DESIGN.md §9):

  sharding      logical-axis -> mesh-axis resolution (divisibility checks,
                conflict fallback to FSDP, multi-pod batch axes)
  compress      int8 error-feedback gradient quantization for the
                cross-pod (DCI) wire of the comm-priority step variant
  pipeline      GPipe-style pipeline parallelism over a `stage` mesh axis
  kf_scheduler  KFScheduler (variant dispatch) + FleetKF (one banked
                filter per pod x traffic-class, on the Pallas kf_bank)
  telemetry     step timers + static cost models -> the KF's three
                normalized observations (the paper's counters, fleet-scale)
"""
from repro.dist import (  # noqa: F401
    compress,
    kf_scheduler,
    pipeline,
    sharding,
    telemetry,
)
