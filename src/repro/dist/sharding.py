"""Logical-axis sharding: resolution of model-level axis names onto mesh axes.

Model code annotates parameters and activations with LOGICAL names
("batch", "mlp", "kv", ...; see models/layers.py).  This module owns the
single source of truth for how those names land on the physical mesh:

  * divisibility — an axis is only sharded if the dim size divides the mesh
    axis size; otherwise it degrades to replication (never an error, which
    is what makes elastic remesh (ckpt/elastic.py) a pure re-resolution);
  * conflict fallback — within one PartitionSpec each mesh axis is claimed
    at most once.  Claims resolve in priority order (primary tensor-parallel
    users first), and losers fall through their candidate list: e.g. in a
    MoE weight (expert, embed, mlp) `expert` takes `model` and `mlp` falls
    back to `data` (FSDP);
  * multi-pod batch — "batch" claims every data-parallel mesh axis it can
    (("pod", "data") jointly when the pod axis exists and divides).

Options (process-global, see `set_option`):
  seq_parallel — resolve the activation sequence axis onto `model`
                 (Megatron-style sequence parallelism for norm/residual);
  dp_only      — drop every tensor-parallel rule (pure data parallel), used
                 by the dry-run hillclimb as an ablation.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Ordered mesh-axis candidates per logical name.  First candidate that is
# (a) present in the mesh, (b) unclaimed within the spec and (c) divides the
# dim wins; an empty tuple means "always replicate".
AXIS_RULES: dict[str, tuple[str, ...]] = {
    "batch":      ("pod", "data"),     # joint claim (multi-pod data parallel)
    "grad_shard": ("data",),           # EF residual shards (train/step.py)
    "vocab":      ("model",),
    "heads":      ("model",),
    "kv":         ("model",),
    "expert":     ("model",),          # expert parallelism
    "mlp":        ("model", "data"),   # FSDP fallback on conflict
    "kv_seq":     ("model",),          # seq-sharded KV cache when kv loses
    "embed":      (),
    "seq":        (),                  # ("model",) under seq_parallel
}

# Logical names that claim ALL their candidates jointly (one tuple entry)
# rather than first-fit.
_JOINT = frozenset({"batch"})

# Conflict priority: lower resolves first.  Primary tensor-parallel users
# (heads/kv/expert/vocab) outrank the FSDP fallback (mlp), which outranks
# the opportunistic KV-sequence shard.
_PRIORITY = {"mlp": 1, "kv_seq": 2}

_OPTIONS = {"seq_parallel": False, "dp_only": False}
_ACTIVE_MESH: Optional[Any] = None


# --------------------------------------------------------------------------
# Options + active-mesh context
# --------------------------------------------------------------------------

def set_option(name: str, value: bool) -> None:
    if name not in _OPTIONS:
        raise KeyError(f"unknown sharding option {name!r} "
                       f"(have {sorted(_OPTIONS)})")
    _OPTIONS[name] = bool(value)


def get_option(name: str) -> bool:
    return _OPTIONS[name]


def seq_axis() -> str:
    """Logical name of the activation sequence axis (resolution is governed
    by the seq_parallel option, so call sites never branch)."""
    return "seq"


@contextlib.contextmanager
def activate(mesh):
    """Make `mesh` the resolution target for `constrain` within the block."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev


def active_mesh():
    return _ACTIVE_MESH


# --------------------------------------------------------------------------
# Resolution
# --------------------------------------------------------------------------

def _mesh_sizes(mesh) -> dict[str, int]:
    # works for jax.sharding.Mesh and duck-typed meshes (tests use a
    # FakeMesh with .axis_names / .devices only)
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _candidates(name: str, sizes: dict[str, int]) -> tuple[str, ...]:
    if _OPTIONS["dp_only"] and name not in ("batch", "grad_shard"):
        return ()
    if name == "seq":
        return ("model",) if _OPTIONS["seq_parallel"] else ()
    if name in AXIS_RULES:
        return AXIS_RULES[name]
    if name in sizes:          # already a mesh-axis name: pass through
        return (name,)
    return ()                  # unknown logical name: replicate


def _prio(entry) -> int:
    names = entry if isinstance(entry, tuple) else (entry,)
    return min((_PRIORITY.get(n, 0) for n in names if n is not None),
               default=0)


def _divides(dim: Optional[int], n: int) -> bool:
    return dim is None or (n > 0 and dim % n == 0)


def logical_to_mesh(spec: P, shape: Optional[Sequence[int]], mesh) -> P:
    """Resolve a logical PartitionSpec against `mesh` for a tensor `shape`.

    Returns a spec whose entries are tuples of mesh-axis names (or None) —
    ready for `NamedSharding`.  `shape` may be None to skip divisibility
    checks, or shorter/longer than the spec (extra dims replicate).
    """
    sizes = _mesh_sizes(mesh)
    entries = list(spec)
    dims: list[Optional[int]] = [None] * len(entries)
    if shape is not None:
        for i in range(min(len(entries), len(shape))):
            dims[i] = int(shape[i])

    resolved: list[Optional[tuple[str, ...]]] = [None] * len(entries)
    used: set[str] = set()
    order = sorted(range(len(entries)), key=lambda i: (_prio(entries[i]), i))
    for i in order:
        entry = entries[i]
        if entry is None:
            continue
        claimed: list[str] = []
        for name in (entry if isinstance(entry, tuple) else (entry,)):
            if name is None:
                continue
            cands = tuple(a for a in _candidates(name, sizes)
                          if a in sizes and a not in used
                          and a not in claimed)
            if name in _JOINT:
                # longest suffix of the candidate list whose product divides
                # (prefer ("pod","data") jointly, then ("data",), ...)
                for k in range(len(cands)):
                    sub = cands[k:]
                    prod = 1
                    for a in sub:
                        prod *= sizes[a]
                    if _divides(dims[i], prod):
                        claimed.extend(sub)
                        break
            else:
                for a in cands:
                    if _divides(dims[i], sizes[a]):
                        claimed.append(a)
                        break
        if claimed:
            used.update(claimed)
            resolved[i] = tuple(claimed)
    return P(*resolved)


def shard_specs(spec_tree, template, mesh):
    """Resolve a logical spec tree into a NamedSharding tree.

    `template` supplies shapes (arrays or ShapeDtypeStructs) and must be
    congruent with `spec_tree` (tested by tests/test_spec_congruence.py).
    """
    return jax.tree.map(
        lambda s, t: NamedSharding(
            mesh, logical_to_mesh(s, getattr(t, "shape", None), mesh)),
        spec_tree, template,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# Activation constraints
# --------------------------------------------------------------------------

def _in_manual_region() -> bool:
    """True while tracing inside shard_map (named mesh axes in scope).
    with_sharding_constraint over manual axes is invalid there — the body is
    already per-device — so `constrain` becomes the identity."""
    try:
        env = jax.core.trace_ctx.axis_env          # jax <= 0.4.x
        return bool(getattr(env, "axis_sizes", None))
    except AttributeError:
        pass
    try:
        return bool(jax.core.nonempty_axis_env_DO_NOT_USE())
    except Exception:
        return False


def constrain(x, *logical_axes):
    """`with_sharding_constraint` by logical names against the active mesh.

    No-op when no mesh is active (single-host eager paths) or inside a
    shard_map body (the manual region owns its own layout)."""
    mesh = _ACTIVE_MESH
    if mesh is None or _in_manual_region():
        return x
    spec = logical_to_mesh(P(*logical_axes), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Host-local sweep mesh
# --------------------------------------------------------------------------

def sweep_mesh(n_devices: Optional[int] = None, axis: str = "sweep"):
    """A 1-D mesh over host-local devices for data-parallel sweep dispatch.

    The NoC sweep engine (core/noc/sim.py) splits its flat batch axis over
    this mesh's single `sweep` axis — pure data parallelism, no collectives,
    so the shard_map shim below stays on the psum-safe path on every jax
    version.  `n_devices=None` takes every local device.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 0 < n <= len(devs):
        raise ValueError(
            f"sweep_mesh over {n} devices, but {len(devs)} are available"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


# --------------------------------------------------------------------------
# shard_map version compat
# --------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = True):
    """`jax.shard_map` across jax versions.

    New jax exposes jax.shard_map(axis_names=..., check_vma=...); 0.4.x has
    jax.experimental.shard_map.shard_map(auto=..., check_rep=...) with the
    complementary axis set.  Call sites (train/step.py, dist/pipeline.py)
    use the new-style signature.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
