"""Oracle for the noc_cycle kernel: the production dense-jnp switch
allocator from `repro.core.noc.router`.

`router.arbitrate` IS the reference — the simulator's default backend runs
it directly, and the Pallas lane kernel in `kernel.py` must agree with it
bitwise on every output (grant/winner/down_vc/deq/new_rr/any_req/w_cls);
tests/test_cycle_engine.py pins that on random router states and on a full
`router_cycle` step."""
from __future__ import annotations

from repro.core.noc.router import Arbitration, arbitrate

noc_cycle_ref = arbitrate

__all__ = ["Arbitration", "noc_cycle_ref"]
