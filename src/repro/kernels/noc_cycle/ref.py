"""Oracle for the noc_cycle kernels: the production dense-jnp engine in
`repro.core.noc`.

`router.arbitrate` IS the arbitration reference — the simulator's default
backend runs it directly, and the Pallas lane kernel in `kernel.py` must
agree with it bitwise on every output (grant/winner/down_vc/deq/new_rr/
any_req/w_cls); tests/test_cycle_engine.py pins that on random router
states and on a full `router_cycle` step.  The fused full-cycle kernel
(`fused.py`, DESIGN.md §13) widens the oracle to the whole dense
`sim.cycle_body` — `router.router_cycle`/`inject_all` and the MC/counter
stages are its per-stage references, pinned by the same test module."""
from __future__ import annotations

from repro.core.noc.router import Arbitration, arbitrate

noc_cycle_ref = arbitrate

__all__ = ["Arbitration", "noc_cycle_ref"]
