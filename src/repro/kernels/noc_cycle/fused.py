"""Lane-layout twins of the dense cycle engine + the fused full-cycle step.

This module is the pure-jnp half of the fused Pallas cycle kernel
(DESIGN.md §13): every stage of `sim._simulate_impl`'s `cycle_body` — MC
acceptance/service, route + switch allocation, buffer dequeue/enqueue
writes, MC enqueue, reply completion, source generation, the merged
inject, and the metrics counters — rewritten over the packed lane layout
the arbitration kernel (kernel.py) introduced, as plain 2D
(sublane, lane) ops with NO captured constant arrays.  `cycle_step_lanes`
is therefore callable both as a regular jitted function (the dense twin
the micro-congruence tests compare stage by stage) and as the body of one
`pallas_call` per simulated cycle (`kernel.fused_cycle_kernel`).

Lane layout
-----------
Subnet-resolved state rides an `(S * 64)`-lane axis: lane `l` holds
(subnet `l // 64`, router `l % 64`), with routers padded 36 -> 64 so every
subnet block is XY-shift-closed (a mesh neighbor is always `l +/- 1` or
`l +/- width` *within* the block; shifts that cross a block edge land on
padded or masked lanes only).  Per-node state (MC queues, MSHRs, source
backlogs, burst phase, epoch counters) rides one 128-lane block with
routers in lanes `0..R-1`.  Rows are the microarchitectural axes,
flattened C-style exactly like the dense state:

  buf_meta/buf_binj : (P*V*B, S*64)  row = (p*V + v)*B + b
  head/count        : (P*V,   S*64)  row = p*V + v
  rr                : (P,     S*64)
  mcq               : (Q,     128)
  mc                : (6,     128)   rows MC_HEAD..MC_SCLS
  node              : (3,     128)   rows ND_OUTST/ND_BACKLOG/ND_PHASE
  cnt               : (1,     128)   lane i = EpochCounters field i

Everything is int32 on the lane axis; `pack_state`/`unpack_state` convert
to/from the narrow packed dtypes (int16 meta, uint16/int32 stamps, int8
head/count/rr/q_meta) with value-exact casts (meta < 2^15, q_meta < 2^7,
and a uint16 stamp cast reproduces the dense engine's wraparound stores).

Garbage-value conventions are inherited from the arbitration kernel
(DESIGN.md §11): padded lanes and cross-block shift reads hold arbitrary
values, but every such site is masked (by `exists`, a false grant, or a
false eject) before it can reach a state write or a counter — the dense
engine and this module agree BITWISE on all carried state and counters,
which tests/test_cycle_engine.py pins per stage and end to end.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc.router import META_CLS_SHIFT, META_SRC_SHIFT, SubnetState
from repro.core.noc.topology import (
    N_PORTS,
    NT_CPU,
    NT_GPU,
    NT_MC,
    OPPOSITE,
    PORT_L,
    Topology,
)
from repro.core.noc.traffic import (
    WorkloadProfile,
    injection_rates,
    step_phase_u,
)

Array = jax.Array

R_PAD = 64     # router lanes per subnet block (shift-closed padding)
LANES_R = 128  # per-node state rides one 128-lane block
BIG = 1 << 20  # grant-rank sentinel — must match kernel.py / router.py

OPP = tuple(int(p) for p in OPPOSITE)

# `mc` row indices (mirror sim.MCState field order)
MC_HEAD, MC_COUNT, MC_TIMER, MC_SVALID, MC_SDST, MC_SCLS = range(6)
MC_ROWS = 6
# `node` row indices
ND_OUTST, ND_BACKLOG, ND_PHASE = range(3)
ND_ROWS = 3
# counter lanes — must equal sim.EpochCounters._fields (asserted at dispatch)
COUNTER_FIELDS = (
    "gpu_push", "gpu_stall_icnt", "gpu_stall_dram", "cpu_push",
    "gpu_done", "cpu_done", "gpu_gen", "cpu_gen",
    "lat_sum", "lat_cnt", "cpu_lat_sum", "cpu_lat_cnt",
    "gpu_lat_sum", "gpu_lat_cnt", "moved",
)
N_COUNTERS = len(COUNTER_FIELDS)

# per-cycle xs rows (int / float blocks); XI_MCOK carries the per-epoch MC
# fault mask (DESIGN.md §16) — R-padded then S-tiled like every lane row, and
# consumed on the first 128 lanes only (garbage tiles masked by ntype)
XI_CYCLE, XI_SA, XI_GATE, XI_ACTIVE, XI_DEST, XI_MCOK = range(6)
XI_ROWS = 6
XF_UPHASE, XF_UGEN = range(2)
XF_ROWS = 2
# per-run policy rows (subnet-resolved / per-node)
PS_ENABLED, PS_IS_REQ, PS_IS_REP, PS_REQ_MATCH = range(4)
PS_ROWS = 4
PR_FS, PR_NREQ = range(2)
PR_ROWS = 2


class LaneDims(NamedTuple):
    """Static shape/parameter bundle threaded through every lane stage.

    Hashable (all ints), so it can close over a Pallas kernel body and key
    jit caches.  `stamp_mask` is 0xFFFF when the dense engine carries
    uint16 injection stamps (total cycles <= 2^16) and 0 for int32 stamps;
    the lane engine carries stamps as int32 and applies the mask to the
    latency subtraction, which reproduces the uint16 wraparound arithmetic
    bit for bit (see `cycle_step_lanes`).
    """

    S: int
    R: int
    V: int
    B: int
    Q: int
    width: int
    mc_service_period: int
    mshr_limit: int
    bcap: int
    stamp_mask: int

    @property
    def PV(self) -> int:
        return N_PORTS * self.V

    @property
    def lanes_sr(self) -> int:
        return self.S * R_PAD

    @property
    def deltas(self) -> tuple[int, int, int, int, int]:
        """Lane offset of the neighbor through each port (N, E, S, W, L)."""
        return (-self.width, 1, self.width, -1, 0)


class LaneState(NamedTuple):
    """The whole cycle-scan carry in lane layout (all int32, lanes last)."""

    buf_meta: Array  # (P*V*B, S*64)
    buf_binj: Array  # (P*V*B, S*64)
    head: Array      # (P*V,   S*64)
    count: Array     # (P*V,   S*64)
    rr: Array        # (P,     S*64)
    mcq: Array       # (Q, 128)
    mc: Array        # (MC_ROWS, 128)
    node: Array      # (ND_ROWS, 128)
    cnt: Array       # (1, 128) — counter lanes


class ProbeLanes(NamedTuple):
    """Flight-recorder counter lanes (DESIGN.md §14) — an OPTIONAL extra
    scan carry next to LaneState, present only when `ProbeConfig.enabled`
    compiled the probed kernel variant.  All int32; accumulated per cycle
    from END-of-cycle state so the lane engine agrees bitwise with the
    dense engine's probe accumulators."""

    occ: Array  # (P*V, S*64) — sum over cycles of per-buffer flit count
    arb: Array  # (2, S*64)   — rows (PB_GRANT, PB_DENY) switch outcomes
    mcq: Array  # (2, 128)    — rows (PB_MCQ_SUM, PB_MCQ_MAX) queue depth


PB_GRANT, PB_DENY = 0, 1
PB_MCQ_SUM, PB_MCQ_MAX = 0, 1


def zero_probe(d: LaneDims) -> ProbeLanes:
    return ProbeLanes(
        occ=jnp.zeros((N_PORTS * d.V, d.lanes_sr), jnp.int32),
        arb=jnp.zeros((2, d.lanes_sr), jnp.int32),
        mcq=jnp.zeros((2, LANES_R), jnp.int32),
    )


class LaneArb(NamedTuple):
    """Per-output-port arbitration results as lists of (rows, L) blocks.

    The list-of-rows form keeps the port loop unrolled at trace time for
    both consumers: the standalone arbitration kernel concatenates the
    lists into its output refs, the fused step indexes them per port.
    """

    grant: list      # O x (1, L) bool
    winner: list     # O x (1, L) int32
    down_vc: list    # O x (1, L) int32
    deq: Array       # (PV, L) int32 0/1
    new_rr: list     # O x (1, L) int32
    any_req: list    # O x (1, L) bool
    w_cls: list      # O x (1, L) int32
    sel: list        # O x (PV, L) bool — winner one-hot over requesters


def lane_arbitrate(
    valid: Array,    # (PV, L) bool — head packet present
    cls: Array,      # (PV, L) int32
    out_port: Array,  # (PV, L) int32
    rr: Array,       # (O, L) int32
    down: Array,     # (O*V, L) int32 — downstream VC occupancy
    exists: Array,   # (O, L) bool
    gmask: Array,    # (V, L) bool
    cmask: Array,    # (V, L) bool
    sa: Array,       # (1, L) int32
    accept: Array,   # (1, L) bool
    active: Array,   # (1, L) bool
    *,
    depth: int,
) -> LaneArb:
    """Switch allocation over lanes — the value-level arbitration kernel.

    Bitwise-identical to `router.arbitrate` on every output (the packed-min
    winner pick, the min-of-iota first-free-VC pick mirroring argmax-of-bool,
    and the garbage-when-ungranted conventions are all mirrored exactly);
    shared verbatim by `kernel._noc_cycle_kernel` and `cycle_step_lanes`.
    """
    PV, _ = valid.shape
    O = rr.shape[0]
    V = gmask.shape[0]
    P = PV // V
    local = O - 1  # PORT_L is the last port by convention

    pv_iota = jax.lax.broadcasted_iota(jnp.int32, valid.shape, 0)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, gmask.shape, 0)
    is_pref = (cls == sa) | (sa < 0)
    penalty = jnp.where(is_pref, 0, PV)  # (PV, L)

    grants, winners, down_vcs, new_rrs = [], [], [], []
    any_reqs, w_clss, w_ports, sel_ohs = [], [], [], []
    for o in range(O):
        req_o = valid & (out_port == o)                # (PV, L)
        rr_o = rr[o:o + 1, :]                          # (1, L)
        key = (pv_iota - rr_o) % PV + penalty
        # the empty-column sentinel must be a multiple of PV so the garbage
        # winner (% PV) is 0, exactly like the reference's packed min
        packed = jnp.where(req_o, key * PV + pv_iota, PV * (1 << 14))
        win_o = jnp.min(packed, axis=0, keepdims=True) % PV
        any_o = jnp.any(req_o, axis=0, keepdims=True)
        sel_o = pv_iota == win_o                       # (PV, L) one-hot
        wcls_o = jnp.sum(jnp.where(sel_o, cls, 0), axis=0, keepdims=True)

        allowed = jnp.where(wcls_o == 1, gmask, cmask)  # (V, L)
        dc_o = down[o * V:(o + 1) * V, :]               # (V, L)
        has = (dc_o < depth) & allowed
        credit_o = jnp.any(has, axis=0, keepdims=True)
        first_vc = jnp.min(jnp.where(has, v_iota, V), axis=0, keepdims=True)
        down_vc_o = jnp.where(credit_o, first_vc, 0)   # argmax-of-bool conv.

        if o == local:
            grant_o = any_o & accept & active
        else:
            exists_o = exists[o:o + 1, :]
            grant_o = any_o & exists_o & credit_o & active

        grants.append(grant_o)
        winners.append(win_o)
        down_vcs.append(down_vc_o)
        any_reqs.append(any_o)
        w_clss.append(wcls_o)
        w_ports.append(win_o // V)
        sel_ohs.append(sel_o)
        new_rrs.append((win_o + 1) % PV)

    # one traversal per input port: keep the lowest-output grant per port
    ranks = [jnp.where(grants[o], o, BIG) for o in range(O)]
    min_rank = []
    for p in range(P):
        mr = jnp.full_like(ranks[0], BIG)
        for o in range(O):
            mr = jnp.minimum(mr, jnp.where(w_ports[o] == p, ranks[o], BIG))
        min_rank.append(mr)
    deq = jnp.zeros(valid.shape, jnp.int32)
    for o in range(O):
        sel_rank = jnp.zeros_like(ranks[o])
        for p in range(P):
            sel_rank = sel_rank + jnp.where(w_ports[o] == p, min_rank[p], 0)
        grants[o] = grants[o] & (ranks[o] == sel_rank)
        deq = deq | (sel_ohs[o] & grants[o]).astype(jnp.int32)
        new_rrs[o] = jnp.where(grants[o], new_rrs[o], rr[o:o + 1, :])

    return LaneArb(
        grant=grants, winner=winners, down_vc=down_vcs, deq=deq,
        new_rr=new_rrs, any_req=any_reqs, w_cls=w_clss, sel=sel_ohs,
    )


# ---------------------------------------------------------------------------
# lane-axis helpers (pure value-level ops, usable inside a kernel body)
# ---------------------------------------------------------------------------

def _shift(x: Array, delta: int) -> Array:
    """out[:, l] = x[:, l + delta] (lane wrap — wrapped reads are masked)."""
    if delta == 0:
        return x
    if delta > 0:
        return jnp.concatenate([x[:, delta:], x[:, :delta]], axis=1)
    d = -delta
    return jnp.concatenate([x[:, -d:], x[:, :-d]], axis=1)


def _tile_r(x: Array, S: int) -> Array:
    """Broadcast a per-node (k, 128) row onto the (k, S*64) subnet lanes."""
    return jnp.concatenate([x[:, :R_PAD]] * S, axis=1)


def _pad_r(x: Array) -> Array:
    """Pad a (k, 64) router block back up to the (k, 128) node lanes."""
    k, w = x.shape
    pad = jnp.zeros((k, LANES_R - w), x.dtype)
    return jnp.concatenate([x, pad], axis=1)


def _s_slices(d: LaneDims, x: Array):
    """The per-subnet (k, 64) blocks of a (k, S*64) row."""
    return [x[:, s * R_PAD:(s + 1) * R_PAD] for s in range(d.S)]


# ---------------------------------------------------------------------------
# stage twins — each mirrors one `sim.cycle_body` stage over lanes
# ---------------------------------------------------------------------------

def mc_service_lanes(
    d: LaneDims, mc: Array, mcq: Array, ntype: Array,
    mc_ok: Array | None = None,
):
    """MC service tick: timers, head request -> staging (cycle_body stage 1).

    Returns the six updated `mc` rows; the queue head peek is a Q-step
    one-hot sum (head is always in [0, Q), so it equals the dense
    take_along_axis gather exactly).

    `mc_ok` (1, 128) bool is the MC-stall fault mask (DESIGN.md §16): a
    False lane freezes service (timer, staging and dequeue all hold)
    while the queue keeps filling — the lane twin of the dense engine's
    `can_serve & mc_ok` gate.  None behaves as all-True.
    """
    i32 = jnp.int32
    is_mc = ntype == NT_MC
    head = mc[MC_HEAD:MC_HEAD + 1]
    count = mc[MC_COUNT:MC_COUNT + 1]
    svalid = mc[MC_SVALID:MC_SVALID + 1] != 0

    can_serve = is_mc & (count > 0) & ~svalid
    if mc_ok is not None:
        can_serve = can_serve & mc_ok
    timer = jnp.where(
        can_serve, jnp.maximum(mc[MC_TIMER:MC_TIMER + 1] - 1, 0),
        mc[MC_TIMER:MC_TIMER + 1],
    )
    done = can_serve & (timer == 0)
    q_head = jnp.zeros_like(head)
    for q in range(d.Q):
        q_head = q_head + jnp.where(head == q, mcq[q:q + 1], 0)
    src_out = q_head & ((1 << META_SRC_SHIFT) - 1)
    cls_out = q_head >> META_SRC_SHIFT
    head = jnp.where(done, (head + 1) % d.Q, head)
    count = count - done.astype(i32)
    timer = jnp.where(done, d.mc_service_period, timer)
    sdst = jnp.where(done, src_out, mc[MC_SDST:MC_SDST + 1])
    scls = jnp.where(done, cls_out, mc[MC_SCLS:MC_SCLS + 1])
    svalid = svalid | done
    return head, count, timer, svalid, sdst, scls


def router_stage_lanes(
    d: LaneDims,
    buf_meta: Array, buf_binj: Array, head: Array, count: Array, rr: Array,
    gmask: Array, cmask: Array, sa: Array, accept: Array, active: Array,
    route: Array, exists: Array,
):
    """One full router cycle over lanes (cycle_body stage 2 / router_cycle).

    Head peeks are B-step one-hot sums over strided buffer rows, the route
    lookup is an R-step one-hot sum over the route table rows, and every
    neighbor gather (downstream credit, upstream traversal) is a static
    lane shift: input port p of lane l is driven only by lane
    `l + deltas[p]`'s output port `OPP[p]`.  Cross-block and mesh-edge
    shift reads are garbage but always masked by `exists` before use.

    Returns the updated buffer rows plus the per-lane event rows
    (ej, eject_src, eject_cls, eject_binj), the (moved, dram_block_gpu)
    scalars the counter stage consumes, and the per-lane probe rows
    (grant_cnt, deny_cnt) — switch-allocation outcomes summed over output
    ports, the lane twin of CycleEvents.grant_cnt/deny_cnt (DESIGN.md
    §14; dead code when probes are off).
    """
    i32 = jnp.int32
    V, B, P = d.V, d.B, N_PORTS

    # --- peek head-of-line packets
    meta_h = jnp.zeros_like(head)
    binj_h = jnp.zeros_like(head)
    for b in range(B):
        at_b = head == b
        meta_h = meta_h + jnp.where(at_b, buf_meta[b::B], 0)
        binj_h = binj_h + jnp.where(at_b, buf_binj[b::B], 0)
    dest_h = meta_h & ((1 << META_SRC_SHIFT) - 1)
    cls_h = meta_h >> META_CLS_SHIFT
    valid = count > 0

    # --- route: desired output port of each head packet
    out_port = jnp.zeros_like(meta_h)
    for dst in range(d.R):
        out_port = out_port + jnp.where(dest_h == dst, route[dst:dst + 1], 0)

    # --- downstream VC occupancy: neighbor through output o is lane
    # l + deltas[o]; its input port facing us is OPP[o]
    down = jnp.concatenate(
        [
            _shift(count[OPP[o] * V:(OPP[o] + 1) * V], d.deltas[o])
            for o in range(P)
        ],
        axis=0,
    )

    arb = lane_arbitrate(
        valid, cls_h, out_port, rr, down, exists, gmask, cmask,
        sa, accept, active, depth=B,
    )

    # --- dequeue winners, advance RR pointers past them
    deq = arb.deq != 0
    head2 = jnp.where(deq, (head + 1) % B, head)
    count2 = count - arb.deq
    rr2 = jnp.concatenate(arb.new_rr, axis=0)

    # --- winner packet fields per output (one-hot reduction; like the dense
    # gsel, a garbage winner (any_req false -> winner 0) selects row 0's
    # real value, so even the garbage sites agree with the reference)
    w_meta = jnp.concatenate(
        [
            jnp.sum(jnp.where(arb.sel[o], meta_h, 0), axis=0, keepdims=True)
            for o in range(P)
        ],
        axis=0,
    )
    w_binj = jnp.concatenate(
        [
            jnp.sum(jnp.where(arb.sel[o], binj_h, 0), axis=0, keepdims=True)
            for o in range(P)
        ],
        axis=0,
    )
    w_src = (w_meta >> META_SRC_SHIFT) & (
        (1 << (META_CLS_SHIFT - META_SRC_SHIFT)) - 1
    )

    # --- ejections: only the Local output column can eject
    ej = arb.grant[PORT_L]
    eject_src = w_src[PORT_L:PORT_L + 1]
    eject_cls = arb.w_cls[PORT_L]
    eject_binj = w_binj[PORT_L:PORT_L + 1]
    moved = jnp.sum(jnp.concatenate(arb.grant, axis=0).astype(i32))
    blocked_local = arb.any_req[PORT_L] & ~accept
    dram_block_gpu = jnp.sum(
        (blocked_local & (arb.w_cls[PORT_L] == 1)).astype(i32)
    )

    # --- probe rows: grants and refusals per lane, summed over outputs
    # (padded lanes have no valid heads -> any_req false -> both stay 0)
    grant_cnt = sum(arb.grant[o].astype(i32) for o in range(P))
    deny_cnt = sum(
        (arb.any_req[o] & ~arb.grant[o]).astype(i32) for o in range(P)
    )

    # --- link traversals as dense pulls through static lane shifts
    tail = (head2 + count2) % B
    new_meta, new_binj, vmask_rows = [], [], []
    for p in range(P):
        po = OPP[p]
        dl = d.deltas[p]
        in_ok = _shift(arb.grant[po], dl) & exists[p:p + 1]
        in_vc = _shift(arb.down_vc[po], dl)
        in_meta = _shift(w_meta[po:po + 1], dl)
        in_binj = _shift(w_binj[po:po + 1], dl)
        for v in range(V):
            pv = p * V + v
            vm = in_ok & (in_vc == v)
            vmask_rows.append(vm)
            for b in range(B):
                row = pv * B + b
                bm = vm & (tail[pv:pv + 1] == b)
                new_meta.append(
                    jnp.where(bm, in_meta, buf_meta[row:row + 1])
                )
                new_binj.append(
                    jnp.where(bm, in_binj, buf_binj[row:row + 1])
                )
    buf_meta2 = jnp.concatenate(new_meta, axis=0)
    buf_binj2 = jnp.concatenate(new_binj, axis=0)
    count3 = count2 + jnp.concatenate(vmask_rows, axis=0).astype(i32)

    return (
        buf_meta2, buf_binj2, head2, count3, rr2,
        ej, eject_src, eject_cls, eject_binj, moved, dram_block_gpu,
        grant_cnt, deny_cnt,
    )


def inject_lanes(
    d: LaneDims,
    buf_meta: Array, buf_binj: Array, head: Array, count: Array,
    want: Array, dest: Array, src: Array, cls: Array, binj: Array,
    gmask: Array, cmask: Array,
):
    """Inject at the Local port of every lane (twin of `router.inject_all`).

    The first-free-VC pick is a min-of-iota mirroring the dense argmax-of-
    bool (VC 0 when no space, gated by `ok`).  Returns the updated buffer
    rows and the per-lane `ok` row.
    """
    i32 = jnp.int32
    V, B = d.V, d.B
    l0 = PORT_L * V

    lcount = count[l0:l0 + V]                         # (V, L)
    allowed = jnp.where(cls == 1, gmask, cmask)       # (V, L)
    has = (lcount < B) & allowed
    v_iota = jax.lax.broadcasted_iota(i32, has.shape, 0)
    first = jnp.min(jnp.where(has, v_iota, V), axis=0, keepdims=True)
    any_has = jnp.any(has, axis=0, keepdims=True)
    vc = jnp.where(any_has, first, 0)
    ok = want & any_has

    tail = (head[l0:l0 + V] + lcount) % B
    meta = dest + (src << META_SRC_SHIFT) + (cls << META_CLS_SHIFT)
    new_meta, new_binj, vmask_rows = [], [], []
    for v in range(V):
        vm = ok & (vc == v)
        vmask_rows.append(vm)
        for b in range(B):
            row = (l0 + v) * B + b
            bm = vm & (tail[v:v + 1] == b)
            new_meta.append(jnp.where(bm, meta, buf_meta[row:row + 1]))
            new_binj.append(jnp.where(bm, binj, buf_binj[row:row + 1]))
    buf_meta2 = jnp.concatenate(
        [buf_meta[:l0 * B]] + new_meta, axis=0
    )
    buf_binj2 = jnp.concatenate(
        [buf_binj[:l0 * B]] + new_binj, axis=0
    )
    count2 = jnp.concatenate(
        [count[:l0], lcount + jnp.concatenate(vmask_rows, axis=0).astype(i32)],
        axis=0,
    )
    return buf_meta2, buf_binj2, count2, ok


def mc_enqueue_lanes(
    d: LaneDims, mcq: Array, head: Array, count: Array,
    req_ej: Array, q_val: Array,
):
    """Enqueue request ejections into MC ring slots (cycle_body stage 3a).

    The per-subnet exclusive prefix over the S blocks serializes same-MC
    arrivals into consecutive slots, matching the dense cumsum exactly.
    Returns (mcq', count', arrivals) on the 64-lane router block.
    """
    i32 = jnp.int32
    head64 = head[:, :R_PAD]
    cnt64 = count[:, :R_PAD]
    off = jnp.zeros_like(head64)
    arr_s, slot_s, val_s = [], [], []
    for s in range(d.S):
        a = req_ej[:, s * R_PAD:(s + 1) * R_PAD]
        arr_s.append(a)
        slot_s.append((head64 + cnt64 + off) % d.Q)
        val_s.append(q_val[:, s * R_PAD:(s + 1) * R_PAD])
        off = off + a.astype(i32)
    rows = []
    for q in range(d.Q):
        hit = jnp.zeros(head64.shape, jnp.bool_)
        val = jnp.zeros_like(head64)
        for s in range(d.S):
            m = arr_s[s] & (slot_s[s] == q)
            hit = hit | m
            val = val + jnp.where(m, val_s[s], 0)
        old = mcq[q:q + 1]
        new64 = jnp.where(hit, val, old[:, :R_PAD])
        rows.append(jnp.concatenate([new64, old[:, R_PAD:]], axis=1))
    return jnp.concatenate(rows, axis=0), cnt64 + off, off


def counter_row(d: LaneDims, values: dict) -> Array:
    """Scatter the 15 counter increments onto their `cnt` lanes.

    `values` maps every COUNTER_FIELDS name to its scalar increment; the
    row add is a 15-step one-hot sum so the kernel never materializes a
    scatter.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, LANES_R), 1)
    inc = jnp.zeros((1, LANES_R), jnp.int32)
    for i, name in enumerate(COUNTER_FIELDS):
        inc = inc + jnp.where(iota == i, values[name], 0)
    return inc


def cycle_step_lanes(
    d: LaneDims,
    st: LaneState,
    xi: Array,      # (XI_ROWS, S*64) int32 — per-cycle xs
    xf: Array,      # (XF_ROWS, 128) float32 — per-cycle uniforms
    gmask: Array,   # (V, S*64) int32 0/1 — epoch VC masks
    cmask: Array,   # (V, S*64) int32 0/1
    prof: Array,    # (5, 128) float32 — WorkloadProfile rows
    pol_sr: Array,  # (PS_ROWS, S*64) int32 — subnet structure rows
    pol_r: Array,   # (PR_ROWS, 128) int32
    ntype: Array,   # (1, 128) int32 (padded lanes -1)
    route: Array,   # (R, S*64) int32 — route[dst, lane] table
    exists: Array,  # (P, S*64) int32 0/1 — link exists through port p
    probe: ProbeLanes | None = None,
):
    """ONE full simulated NoC cycle over lanes — the fused kernel body.

    Stage order and semantics mirror `sim.cycle_body` exactly; every
    input/output is a 2D (sublane, lane) int32/float32 block so the same
    function traces as a Pallas kernel body and as a plain jitted twin.

    With `probe` (the flight-recorder carry) the return value is
    (LaneState, ProbeLanes) instead of a bare LaneState; the probed
    variant is its own compiled program, so the probes-off kernel stays
    byte-identical to before.
    """
    i32 = jnp.int32
    S, Q = d.S, d.Q

    cycle = xi[XI_CYCLE:XI_CYCLE + 1]
    sa = xi[XI_SA:XI_SA + 1]
    gate = xi[XI_GATE:XI_GATE + 1] != 0
    active = xi[XI_ACTIVE:XI_ACTIVE + 1] != 0
    dests = xi[XI_DEST:XI_DEST + 1]
    mc_ok = xi[XI_MCOK:XI_MCOK + 1, :LANES_R] != 0
    u_ph = xf[XF_UPHASE:XF_UPHASE + 1]
    u_gen = xf[XF_UGEN:XF_UGEN + 1]

    gmask_b = gmask != 0
    cmask_b = cmask != 0
    sub_en = pol_sr[PS_ENABLED:PS_ENABLED + 1] != 0
    sub_req = pol_sr[PS_IS_REQ:PS_IS_REQ + 1] != 0
    sub_rep = pol_sr[PS_IS_REP:PS_IS_REP + 1] != 0
    req_match = pol_sr[PS_REQ_MATCH:PS_REQ_MATCH + 1] != 0
    fs_sr = _tile_r(pol_r[PR_FS:PR_FS + 1], S) != 0
    n_req = pol_r[PR_NREQ:PR_NREQ + 1]

    is_mc_r = ntype == NT_MC
    is_gpu_r = ntype == NT_GPU
    is_cpu_r = ntype == NT_CPU
    is_mc_sr = _tile_r(is_mc_r, S)
    node_cls_sr = _tile_r(jnp.where(is_gpu_r, 1, 0), S)
    sub_id_sr = jax.lax.broadcasted_iota(i32, cycle.shape, 1) // R_PAD

    # ---- MC acceptance: queue depth BEFORE this cycle's service
    mc_count0 = st.mc[MC_COUNT:MC_COUNT + 1]
    can_accept = jnp.where(is_mc_r, mc_count0 <= Q - n_req, True)
    accept = jnp.where(sub_req, _tile_r(can_accept, S), True)

    # ---- 1. MC service
    mc_head, mc_count, mc_timer, svalid, sdst, scls = mc_service_lanes(
        d, st.mc, st.mcq, ntype, mc_ok
    )

    # ---- 2. route/arbitrate every subnet
    (buf_meta, buf_binj, head, count, rr,
     ej, eject_src, eject_cls, eject_binj, moved, dram_gpu,
     grant_cnt, deny_cnt,
     ) = router_stage_lanes(
        d, st.buf_meta, st.buf_binj, st.head, st.count, st.rr,
        gmask_b, cmask_b, sa, accept, active, route, exists,
    )

    # ---- 3a. request ejections at MCs -> MC queues
    req_ej = ej & sub_req & is_mc_sr
    q_val = eject_src + (eject_cls << META_SRC_SHIFT)
    mcq, mc_count64, _ = mc_enqueue_lanes(
        d, st.mcq, mc_head, mc_count, req_ej, q_val
    )
    mc_count = jnp.concatenate([mc_count64, mc_count[:, R_PAD:]], axis=1)

    # ---- 3b. reply ejections at sources -> complete transactions
    rep_ej = ej & sub_rep & ~is_mc_sr
    rep_done64 = jnp.zeros((1, R_PAD), jnp.bool_)
    rep_cls64 = jnp.zeros((1, R_PAD), i32)
    for s in range(S):
        r_s = rep_ej[:, s * R_PAD:(s + 1) * R_PAD]
        rep_done64 = rep_done64 | r_s
        rep_cls64 = rep_cls64 + jnp.where(
            r_s, eject_cls[:, s * R_PAD:(s + 1) * R_PAD], 0
        )
    rep_done = _pad_r(rep_done64)
    rep_cls = _pad_r(rep_cls64)
    outstanding = st.node[ND_OUTST:ND_OUTST + 1] - rep_done.astype(i32)

    # ---- 3c. packet latency: the masked subtraction reproduces the dense
    # engine's stamp-dtype arithmetic (uint16 wraparound when stamp_mask
    # is 0xFFFF, plain int32 otherwise)
    age = cycle - eject_binj
    if d.stamp_mask:
        age = age & d.stamp_mask
    ej_lat = jnp.where(ej, age, 0)
    cpu_ej = ej & (eject_cls == 0)
    gpu_ej = ej & (eject_cls == 1)

    # ---- 4. source generation -> per-node source-queue depth
    prof_t = WorkloadProfile(
        *(prof[i:i + 1] for i in range(len(WorkloadProfile._fields)))
    )
    phase = step_phase_u(prof_t, st.node[ND_PHASE:ND_PHASE + 1], u_ph)
    rates = injection_rates(prof_t, ntype, phase)
    gen = (u_gen < rates) & ~is_mc_r
    backlog = st.node[ND_BACKLOG:ND_BACKLOG + 1]
    can_push = gen & (backlog < d.bcap)
    backlog = backlog + can_push.astype(i32)
    can_inj = (backlog > 0) & (outstanding < d.mshr_limit) & ~is_mc_r

    # ---- 5. ONE merged inject: sources (request rows) + staged replies
    want_src = req_match & _tile_r(can_inj, S)
    rep_target = jnp.where(fs_sr, 2 * _tile_r(scls, S) + 1, 1)
    want_rep = (
        (sub_id_sr == rep_target)
        & _tile_r(svalid & is_mc_r, S)
        & sub_en & gate
    )
    dest_i = jnp.where(sub_req, dests, _tile_r(sdst, S))
    src_i = jax.lax.broadcasted_iota(i32, cycle.shape, 1) % R_PAD
    cls_i = jnp.where(sub_req, node_cls_sr, _tile_r(scls, S))
    binj_i = jnp.where(sub_req, cycle, cycle + 1)
    buf_meta, buf_binj, count, ok = inject_lanes(
        d, buf_meta, buf_binj, head, count,
        want_src | want_rep, dest_i, src_i, cls_i, binj_i,
        gmask_b, cmask_b,
    )
    inj_ok64 = jnp.zeros((1, R_PAD), jnp.bool_)
    stage_hit64 = jnp.zeros((1, R_PAD), jnp.bool_)
    for s in range(S):
        ok_s = ok[:, s * R_PAD:(s + 1) * R_PAD]
        req_s = sub_req[:, s * R_PAD:(s + 1) * R_PAD]
        inj_ok64 = inj_ok64 | (ok_s & req_s)
        stage_hit64 = stage_hit64 | (ok_s & ~req_s)
    inj_ok = _pad_r(inj_ok64)
    svalid = svalid & ~_pad_r(stage_hit64)
    backlog = backlog - inj_ok.astype(i32)
    outstanding = outstanding + inj_ok.astype(i32)

    # ---- 6. counters
    gpu_blocked = is_gpu_r & (backlog > 0)
    inc = counter_row(d, {
        "gpu_push": jnp.sum((inj_ok & is_gpu_r).astype(i32)),
        "gpu_stall_icnt": jnp.sum(gpu_blocked.astype(i32)),
        "gpu_stall_dram": dram_gpu,
        "cpu_push": jnp.sum((inj_ok & is_cpu_r).astype(i32)),
        "gpu_done": jnp.sum((rep_done & (rep_cls == 1)).astype(i32)),
        "cpu_done": jnp.sum((rep_done & (rep_cls == 0)).astype(i32)),
        "gpu_gen": jnp.sum((gen & is_gpu_r).astype(i32)),
        "cpu_gen": jnp.sum((gen & is_cpu_r).astype(i32)),
        "lat_sum": jnp.sum(ej_lat),
        "lat_cnt": jnp.sum(ej.astype(i32)),
        "cpu_lat_sum": jnp.sum(jnp.where(cpu_ej, ej_lat, 0)),
        "cpu_lat_cnt": jnp.sum(cpu_ej.astype(i32)),
        "gpu_lat_sum": jnp.sum(jnp.where(gpu_ej, ej_lat, 0)),
        "gpu_lat_cnt": jnp.sum(gpu_ej.astype(i32)),
        "moved": moved,
    })

    mc_rows = jnp.concatenate(
        [mc_head, mc_count, mc_timer, svalid.astype(i32), sdst, scls], axis=0
    )
    node_rows = jnp.concatenate(
        [outstanding, backlog, phase.astype(i32)], axis=0
    )
    st2 = LaneState(
        buf_meta=buf_meta, buf_binj=buf_binj, head=head, count=count, rr=rr,
        mcq=mcq, mc=mc_rows, node=node_rows, cnt=st.cnt + inc,
    )
    if probe is None:
        return st2
    # ---- 7. flight-recorder accumulation from END-of-cycle state — the
    # lane twin of the dense engine's ProbeAcc update (sim.cycle_body)
    probe2 = ProbeLanes(
        occ=probe.occ + count,
        arb=probe.arb + jnp.concatenate([grant_cnt, deny_cnt], axis=0),
        mcq=jnp.concatenate(
            [
                probe.mcq[PB_MCQ_SUM:PB_MCQ_SUM + 1] + mc_count,
                jnp.maximum(probe.mcq[PB_MCQ_MAX:PB_MCQ_MAX + 1], mc_count),
            ],
            axis=0,
        ),
    )
    return st2, probe2


# ---------------------------------------------------------------------------
# packing: dense sim state <-> lane layout, plus the per-run constant rows
# ---------------------------------------------------------------------------

def lane_dims(
    *, S: int, R: int, V: int, B: int, Q: int, width: int,
    mc_service_period: int, mshr_limit: int, bcap: int, stamp_mask: int,
) -> LaneDims:
    assert R <= R_PAD <= LANES_R, (R, R_PAD, LANES_R)
    assert (S * R_PAD) % LANES_R == 0, (S, R_PAD)
    return LaneDims(
        S=S, R=R, V=V, B=B, Q=Q, width=width,
        mc_service_period=mc_service_period, mshr_limit=mshr_limit,
        bcap=bcap, stamp_mask=stamp_mask,
    )


def run_consts(d: LaneDims, topo: Topology):
    """Constant lane tables (route, link-exists, node-type) as device rows.

    Passed to the kernel as INPUT refs — Pallas kernel bodies may not
    capture non-scalar constant arrays.
    """
    route = np.zeros((d.R, R_PAD), np.int32)
    route[:, :d.R] = topo.route.T        # route[dst, r] = port at r toward dst
    route = np.tile(route, (1, d.S))
    exists = np.zeros((N_PORTS, R_PAD), np.int32)
    exists[:, :d.R] = (topo.neighbor >= 0).T
    exists = np.tile(exists, (1, d.S))
    ntype = np.full((1, LANES_R), -1, np.int32)
    ntype[0, :d.R] = topo.node_type
    return jnp.asarray(route), jnp.asarray(exists), jnp.asarray(ntype)


def placement_rows(d: LaneDims, ntype_e: Array) -> Array:
    """Per-epoch node-type lane row from a traced placement (DESIGN.md §17).

    The lane layout's node-type row was a run constant (`run_consts`);
    with placement the virtual node type is per-epoch DATA, so the epoch
    body rebuilds this (1, 128) row — padded lanes carry -1, exactly the
    constant row's convention, so every `ntype == NT_*` compare in the
    kernel stays false on padding.  Identity placement reproduces the
    `run_consts` row bit-for-bit."""
    pad = jnp.full((LANES_R - d.R,), -1, jnp.int32)
    return jnp.concatenate([ntype_e.astype(jnp.int32), pad])[None, :]


def policy_rows(
    d: LaneDims,
    sub_enabled: Array, sub_is_req: Array, sub_is_rep: Array,  # (S,) bool
    req_match: Array,                                          # (S, R) bool
    fs: Array, n_req_subs: Array,                              # () scalars
):
    """Subnet-structure rows: (PS_ROWS, S*64) + (PR_ROWS, 128)."""
    i32 = jnp.int32

    def sr_of_s(x):
        return jnp.repeat(x.astype(i32), R_PAD)[None, :]

    rm = jnp.pad(req_match.astype(i32), ((0, 0), (0, R_PAD - d.R)))
    pol_sr = jnp.concatenate(
        [sr_of_s(sub_enabled), sr_of_s(sub_is_req), sr_of_s(sub_is_rep),
         rm.reshape(1, d.lanes_sr)],
        axis=0,
    )
    pol_r = jnp.stack(
        [
            jnp.broadcast_to(fs.astype(i32), (LANES_R,)),
            jnp.broadcast_to(n_req_subs.astype(i32), (LANES_R,)),
        ],
        axis=0,
    )
    return pol_sr, pol_r


def mask_rows(d: LaneDims, g_vec: Array, c_vec: Array):
    """Epoch VC-partition masks (V,) -> (V, S*64) int32 rows."""
    i32 = jnp.int32
    gm = jnp.broadcast_to(g_vec.astype(i32)[:, None], (d.V, d.lanes_sr))
    cm = jnp.broadcast_to(c_vec.astype(i32)[:, None], (d.V, d.lanes_sr))
    return gm, cm


def prof_rows(prof: WorkloadProfile) -> Array:
    """This epoch's scalar profile leaves broadcast to (n_fields, 128)."""
    return jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(leaf, jnp.float32), (LANES_R,))
            for leaf in prof
        ],
        axis=0,
    )


def cycle_xs(
    d: LaneDims,
    cycles: Array,      # (E,) int32
    u_phase: Array,     # (E,) float32
    u_gen: Array,       # (E, R) float32
    dests_all: Array,   # (E, R) int32
    sa_all: Array,      # (E,) int32
    active_all: Array,  # (E, S) bool
    rep_gate: Array,    # (E,) bool
    router_ok: Array | None = None,  # (R,) bool — epoch fault mask
    mc_ok: Array | None = None,      # (R,) bool — epoch fault mask
):
    """Per-cycle scan xs in lane layout: (E, XI_ROWS, S*64) + (E, XF_ROWS, 128).

    The epoch-constant fault masks (DESIGN.md §16) ride the xs rows:
    `router_ok` ANDs into the XI_ACTIVE row (a browned-out router grants
    nothing in any subnet; padded lanes carry 0, which is inert — they
    never hold valid heads), `mc_ok` becomes the XI_MCOK row.  None
    behaves as all-True.
    """
    E = cycles.shape[0]
    L = d.lanes_sr
    i32 = jnp.int32

    def b_sr(x):
        return jnp.broadcast_to(x.astype(i32)[:, None], (E, L))

    def r_row(x):  # (R,) -> (L,) lane row: pad to R_PAD, tile over subnets
        return jnp.tile(jnp.pad(x.astype(i32), (0, R_PAD - d.R)), d.S)

    dest_rows = jnp.tile(
        jnp.pad(dests_all.astype(i32), ((0, 0), (0, R_PAD - d.R))), (1, d.S)
    )
    act_rows = jnp.repeat(active_all.astype(i32), R_PAD, axis=1)
    if router_ok is not None:
        act_rows = act_rows * r_row(router_ok)[None, :]
    mcok_src = (
        jnp.ones((d.R,), i32) if mc_ok is None else mc_ok
    )
    mcok_rows = jnp.broadcast_to(r_row(mcok_src)[None, :], (E, L))
    xi = jnp.stack(
        [b_sr(cycles), b_sr(sa_all), b_sr(rep_gate), act_rows, dest_rows,
         mcok_rows],
        axis=1,
    )
    u_ph = jnp.broadcast_to(
        u_phase.astype(jnp.float32)[:, None], (E, LANES_R)
    )
    u_g = jnp.pad(
        u_gen.astype(jnp.float32), ((0, 0), (0, LANES_R - d.R))
    )
    xf = jnp.stack([u_ph, u_g], axis=1)
    return xi, xf


def _to_sr_rows(d: LaneDims, x: Array) -> Array:
    """(S, R, *tail) -> (prod(tail), S*64) int32, tail flattened C-style."""
    tail = x.shape[2:]
    rows = 1
    for t in tail:
        rows *= t
    x = x.astype(jnp.int32).reshape(d.S, d.R, rows)
    x = jnp.moveaxis(x, 2, 0)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, R_PAD - d.R)))
    return x.reshape(rows, d.lanes_sr)


def _from_sr_rows(d: LaneDims, x: Array, tail: tuple, dtype) -> Array:
    rows = x.shape[0]
    x = x.reshape(rows, d.S, R_PAD)[:, :, :d.R]
    return jnp.moveaxis(x, 0, 2).reshape((d.S, d.R) + tail).astype(dtype)


def _to_r_row(d: LaneDims, x: Array) -> Array:
    return jnp.pad(x.astype(jnp.int32), (0, LANES_R - d.R))[None, :]


def pack_state(
    d: LaneDims, subs: SubnetState, mc, outstanding: Array,
    backlog: Array, phase: Array,
) -> LaneState:
    """Dense sim carry -> lane layout (all int32; uint16 stamps widen
    value-exactly, so in-lane stamps stay full-width until unpack)."""
    mcq = jnp.pad(
        mc.q_meta.astype(jnp.int32).T, ((0, 0), (0, LANES_R - d.R))
    )
    mc_rows = jnp.concatenate(
        [
            _to_r_row(d, mc.head), _to_r_row(d, mc.count),
            _to_r_row(d, mc.timer), _to_r_row(d, mc.stage_valid),
            _to_r_row(d, mc.stage_dst), _to_r_row(d, mc.stage_cls),
        ],
        axis=0,
    )
    node_rows = jnp.concatenate(
        [
            _to_r_row(d, outstanding), _to_r_row(d, backlog),
            jnp.broadcast_to(phase.astype(jnp.int32), (1, LANES_R)),
        ],
        axis=0,
    )
    return LaneState(
        buf_meta=_to_sr_rows(d, subs.buf_meta),
        buf_binj=_to_sr_rows(d, subs.buf_binj),
        head=_to_sr_rows(d, subs.head),
        count=_to_sr_rows(d, subs.count),
        rr=_to_sr_rows(d, subs.rr_ptr),
        mcq=mcq,
        mc=mc_rows,
        node=node_rows,
        cnt=jnp.zeros((1, LANES_R), jnp.int32),
    )


def unpack_state(d: LaneDims, ls: LaneState, mc_cls, binj_dtype):
    """Lane layout -> dense sim carry.  `mc_cls` is the dense MCState class
    (sim.MCState — passed in to avoid a circular import); the int32 ->
    narrow-dtype casts reproduce the dense engine's stored values exactly
    (meta < 2^15, q_meta < 2^7, and the uint16 stamp cast IS the dense
    engine's wraparound store)."""
    P, V, B = N_PORTS, d.V, d.B
    subs = SubnetState(
        buf_meta=_from_sr_rows(d, ls.buf_meta, (P, V, B), jnp.int16),
        buf_binj=_from_sr_rows(d, ls.buf_binj, (P, V, B), binj_dtype),
        head=_from_sr_rows(d, ls.head, (P, V), jnp.int8),
        count=_from_sr_rows(d, ls.count, (P, V), jnp.int8),
        rr_ptr=_from_sr_rows(d, ls.rr, (P,), jnp.int8),
    )
    mc = mc_cls(
        q_meta=ls.mcq[:, :d.R].T.astype(jnp.int8),
        head=ls.mc[MC_HEAD, :d.R],
        count=ls.mc[MC_COUNT, :d.R],
        timer=ls.mc[MC_TIMER, :d.R],
        stage_valid=ls.mc[MC_SVALID, :d.R] != 0,
        stage_dst=ls.mc[MC_SDST, :d.R],
        stage_cls=ls.mc[MC_SCLS, :d.R],
    )
    outstanding = ls.node[ND_OUTST, :d.R]
    backlog = ls.node[ND_BACKLOG, :d.R]
    phase = ls.node[ND_PHASE, 0]
    return subs, mc, outstanding, backlog, phase


def unpack_probe(d: LaneDims, pb: ProbeLanes):
    """Probe lanes -> dense probe accumulators, all int32:
    (occ (S,R,P,V), grant (S,R), deny (S,R), mcq_sum (R,), mcq_max (R,)).

    Padded lanes never accumulate (no heads, no links, no MCs), so the
    [:R] slices are exact — not a masked approximation."""
    occ = _from_sr_rows(d, pb.occ, (N_PORTS, d.V), jnp.int32)
    arb = _from_sr_rows(d, pb.arb, (2,), jnp.int32)
    return (
        occ,
        arb[..., PB_GRANT],
        arb[..., PB_DENY],
        pb.mcq[PB_MCQ_SUM, :d.R],
        pb.mcq[PB_MCQ_MAX, :d.R],
    )
