"""Pallas TPU kernel: the NoC router-arbitration inner loop over lanes.

One simulated cycle's switch allocation — VC allocation at the downstream
router, per-output round-robin arbitration, and the one-traversal-per-input
grant filter — for EVERY (subnet, router) pair at once.  The pairs ride the
128-wide TPU lanes as a flattened `(S*R)` lane axis (batched sweeps flatten
`(B*S*R)`), and the small microarchitectural axes (P*V requesters, O output
ports, V virtual channels) ride sublanes with the port/VC loops unrolled at
trace time — every op in the kernel is a 2D (sublane, lane) VPU op.

This is the jax_pallas-facing half of the cycle engine (DESIGN.md §11, §13):
the dense-jnp `router.arbitrate` is the oracle, `ops.arbitrate_lanes` is the
`simulate(..., backend="pallas_arb")` entry with interpret-mode fallback
off-TPU, and the two must agree BITWISE — the packed-min trick, the
argmax-of-bool VC pick and the garbage-when-ungranted conventions are all
mirrored exactly.  The value-level arbitration body lives in
`fused.lane_arbitrate` and is shared with `fused_cycle_kernel` — the
full-cycle kernel that `simulate(..., backend="pallas")` launches once per
simulated cycle with the whole scan carry in its refs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.noc_cycle import fused

BIG = fused.BIG


def _noc_cycle_kernel(
    valid_ref, cls_ref, out_port_ref, rr_ref, down_ref, exists_ref,
    gmask_ref, cmask_ref, sa_ref, accept_ref, active_ref,
    grant_ref, winner_ref, down_vc_ref, deq_ref, new_rr_ref,
    any_req_ref, w_cls_ref,
    *,
    depth: int,
):
    arb = fused.lane_arbitrate(
        valid_ref[...] != 0,
        cls_ref[...],
        out_port_ref[...],
        rr_ref[...],
        down_ref[...],
        exists_ref[...] != 0,
        gmask_ref[...] != 0,
        cmask_ref[...] != 0,
        sa_ref[...],
        accept_ref[...] != 0,
        active_ref[...] != 0,
        depth=depth,
    )
    grant_ref[...] = jnp.concatenate(arb.grant, axis=0).astype(jnp.int32)
    winner_ref[...] = jnp.concatenate(arb.winner, axis=0)
    down_vc_ref[...] = jnp.concatenate(arb.down_vc, axis=0)
    deq_ref[...] = arb.deq
    new_rr_ref[...] = jnp.concatenate(arb.new_rr, axis=0)
    any_req_ref[...] = jnp.concatenate(arb.any_req, axis=0).astype(jnp.int32)
    w_cls_ref[...] = jnp.concatenate(arb.w_cls, axis=0)


def noc_cycle_kernel(
    valid: jax.Array,       # (PV, L) int32 0/1
    cls: jax.Array,         # (PV, L) int32
    out_port: jax.Array,    # (PV, L) int32
    rr_ptr: jax.Array,      # (O, L) int32
    down_count: jax.Array,  # (O*V, L) int32
    down_exists: jax.Array,  # (O, L) int32 0/1
    gmask: jax.Array,       # (V, L) int32 0/1
    cmask: jax.Array,       # (V, L) int32 0/1
    sa_pref: jax.Array,     # (1, L) int32
    accept: jax.Array,      # (1, L) int32 0/1
    active: jax.Array,      # (1, L) int32 0/1
    *,
    depth: int,
    n_vcs: int,
    block_l: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Lane-blocked dispatch; L must be a multiple of `block_l`."""
    pv, lanes = valid.shape
    o = rr_ptr.shape[0]
    assert lanes % block_l == 0, (lanes, block_l)
    grid = (lanes // block_l,)

    def spec(rows):
        return pl.BlockSpec((rows, block_l), lambda i: (0, i))

    out_rows = [o, o, o, pv, o, o, o]
    kernel = functools.partial(_noc_cycle_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            spec(pv), spec(pv), spec(pv), spec(o), spec(o * n_vcs),
            spec(o), spec(n_vcs), spec(n_vcs), spec(1), spec(1), spec(1),
        ],
        out_specs=[spec(r) for r in out_rows],
        out_shape=[
            jax.ShapeDtypeStruct((r, lanes), jnp.int32) for r in out_rows
        ],
        interpret=interpret,
    )(valid, cls, out_port, rr_ptr, down_count, down_exists,
      gmask, cmask, sa_pref, accept, active)


# ---------------------------------------------------------------------------
# fused full-cycle kernel: ONE launch per simulated NoC cycle (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _fused_cycle_kernel(
    xi_ref, xf_ref, gmask_ref, cmask_ref, prof_ref, pol_sr_ref, pol_r_ref,
    ntype_ref, route_ref, exists_ref,
    buf_meta_ref, buf_binj_ref, head_ref, count_ref, rr_ref,
    mcq_ref, mc_ref, node_ref, cnt_ref,
    o_buf_meta, o_buf_binj, o_head, o_count, o_rr,
    o_mcq, o_mc, o_node, o_cnt,
    *,
    dims: fused.LaneDims,
):
    state = fused.LaneState(
        buf_meta=buf_meta_ref[...],
        buf_binj=buf_binj_ref[...],
        head=head_ref[...],
        count=count_ref[...],
        rr=rr_ref[...],
        mcq=mcq_ref[...],
        mc=mc_ref[...],
        node=node_ref[...],
        cnt=cnt_ref[...],
    )
    new = fused.cycle_step_lanes(
        dims, state, xi_ref[...], xf_ref[...],
        gmask_ref[...], cmask_ref[...], prof_ref[...],
        pol_sr_ref[...], pol_r_ref[...],
        ntype_ref[...], route_ref[...], exists_ref[...],
    )
    o_buf_meta[...] = new.buf_meta
    o_buf_binj[...] = new.buf_binj
    o_head[...] = new.head
    o_count[...] = new.count
    o_rr[...] = new.rr
    o_mcq[...] = new.mcq
    o_mc[...] = new.mc
    o_node[...] = new.node
    o_cnt[...] = new.cnt


def _fused_cycle_probed_kernel(
    xi_ref, xf_ref, gmask_ref, cmask_ref, prof_ref, pol_sr_ref, pol_r_ref,
    ntype_ref, route_ref, exists_ref,
    buf_meta_ref, buf_binj_ref, head_ref, count_ref, rr_ref,
    mcq_ref, mc_ref, node_ref, cnt_ref,
    p_occ_ref, p_arb_ref, p_mcq_ref,
    o_buf_meta, o_buf_binj, o_head, o_count, o_rr,
    o_mcq, o_mc, o_node, o_cnt,
    o_p_occ, o_p_arb, o_p_mcq,
    *,
    dims: fused.LaneDims,
):
    """Flight-recorder variant of `_fused_cycle_kernel` (DESIGN.md §14):
    the ProbeLanes carry rides three extra in/out refs.  Separate kernel
    function so the probes-off pallas_call signature is untouched."""
    state = fused.LaneState(
        buf_meta=buf_meta_ref[...],
        buf_binj=buf_binj_ref[...],
        head=head_ref[...],
        count=count_ref[...],
        rr=rr_ref[...],
        mcq=mcq_ref[...],
        mc=mc_ref[...],
        node=node_ref[...],
        cnt=cnt_ref[...],
    )
    probe = fused.ProbeLanes(
        occ=p_occ_ref[...], arb=p_arb_ref[...], mcq=p_mcq_ref[...]
    )
    new, new_probe = fused.cycle_step_lanes(
        dims, state, xi_ref[...], xf_ref[...],
        gmask_ref[...], cmask_ref[...], prof_ref[...],
        pol_sr_ref[...], pol_r_ref[...],
        ntype_ref[...], route_ref[...], exists_ref[...],
        probe=probe,
    )
    o_buf_meta[...] = new.buf_meta
    o_buf_binj[...] = new.buf_binj
    o_head[...] = new.head
    o_count[...] = new.count
    o_rr[...] = new.rr
    o_mcq[...] = new.mcq
    o_mc[...] = new.mc
    o_node[...] = new.node
    o_cnt[...] = new.cnt
    o_p_occ[...] = new_probe.occ
    o_p_arb[...] = new_probe.arb
    o_p_mcq[...] = new_probe.mcq


def fused_cycle_kernel(
    state: fused.LaneState,
    xi: jax.Array,       # (XI_ROWS, S*64) int32 — this cycle's xs
    xf: jax.Array,       # (XF_ROWS, 128) float32
    gmask: jax.Array,    # (V, S*64) int32 0/1 — epoch VC masks
    cmask: jax.Array,    # (V, S*64) int32 0/1
    prof: jax.Array,     # (n_prof, 128) float32 — workload rows
    pol_sr: jax.Array,   # (PS_ROWS, S*64) int32 — subnet structure
    pol_r: jax.Array,    # (PR_ROWS, 128) int32
    ntype: jax.Array,    # (1, 128) int32 — node types (constant)
    route: jax.Array,    # (R, S*64) int32 — route table (constant)
    exists: jax.Array,   # (P, S*64) int32 0/1 — link table (constant)
    *,
    dims: fused.LaneDims,
    interpret: bool = False,
    probe: fused.ProbeLanes | None = None,
):
    """One simulated cycle as ONE pallas_call over the whole lane state.

    Every operand is small enough (< 100 KiB total at the paper's shapes)
    that the kernel runs as a single full-width block: the grid is (1,) and
    every BlockSpec covers its operand.  Constant tables arrive as input
    refs because Pallas kernel bodies may not capture constant arrays.

    With `probe` the ProbeLanes carry joins the refs and the return value
    is (LaneState, ProbeLanes) — a distinct kernel (so probes-off stays
    byte-identical), still ONE launch per cycle.
    """
    ins = (xi, xf, gmask, cmask, prof, pol_sr, pol_r, ntype, route, exists)
    carry = tuple(state) if probe is None else tuple(state) + tuple(probe)

    def spec(x):
        return pl.BlockSpec(x.shape, lambda i: (0, 0))

    body = _fused_cycle_kernel if probe is None else _fused_cycle_probed_kernel
    kernel = functools.partial(body, dims=dims)
    outs = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[spec(x) for x in ins + carry],
        out_specs=[spec(x) for x in carry],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype) for x in carry],
        interpret=interpret,
    )(*ins, *carry)
    if probe is None:
        return fused.LaneState(*outs)
    n = len(fused.LaneState._fields)
    return fused.LaneState(*outs[:n]), fused.ProbeLanes(*outs[n:])
