"""Pallas TPU kernel: the NoC router-arbitration inner loop over lanes.

One simulated cycle's switch allocation — VC allocation at the downstream
router, per-output round-robin arbitration, and the one-traversal-per-input
grant filter — for EVERY (subnet, router) pair at once.  The pairs ride the
128-wide TPU lanes as a flattened `(S*R)` lane axis (batched sweeps flatten
`(B*S*R)`), and the small microarchitectural axes (P*V requesters, O output
ports, V virtual channels) ride sublanes with the port/VC loops unrolled at
trace time — every op in the kernel is a 2D (sublane, lane) VPU op.

This is the jax_pallas-facing half of the cycle engine (DESIGN.md §11): the
dense-jnp `router.arbitrate` is the oracle, `ops.arbitrate_lanes` is the
`simulate(..., backend="pallas")` entry with interpret-mode fallback off-TPU,
and the two must agree BITWISE — the packed-min trick, the argmax-of-bool VC
pick and the garbage-when-ungranted conventions are all mirrored exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1 << 20


def _noc_cycle_kernel(
    valid_ref, cls_ref, out_port_ref, rr_ref, down_ref, exists_ref,
    gmask_ref, cmask_ref, sa_ref, accept_ref, active_ref,
    grant_ref, winner_ref, down_vc_ref, deq_ref, new_rr_ref,
    any_req_ref, w_cls_ref,
    *,
    depth: int,
):
    PV, _ = valid_ref.shape          # requesters (P*V) x lane block
    O = rr_ref.shape[0]              # output ports
    V = gmask_ref.shape[0]           # virtual channels
    P = PV // V                      # input ports (== O on a crossbar)
    local = O - 1                    # PORT_L is the last port by convention

    valid = valid_ref[...] != 0
    cls = cls_ref[...]
    op = out_port_ref[...]
    sa = sa_ref[...]                                   # (1, L)
    accept = accept_ref[...] != 0
    active = active_ref[...] != 0
    gmask = gmask_ref[...] != 0                        # (V, L)
    cmask = cmask_ref[...] != 0

    pv_iota = jax.lax.broadcasted_iota(jnp.int32, valid.shape, 0)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, gmask.shape, 0)
    is_pref = (cls == sa) | (sa < 0)
    penalty = jnp.where(is_pref, 0, PV)                # (PV, L)

    grants, winners, down_vcs, new_rrs = [], [], [], []
    any_reqs, w_clss, w_ports, sel_ohs = [], [], [], []
    for o in range(O):
        req_o = valid & (op == o)                      # (PV, L)
        rr_o = rr_ref[o:o + 1, :]                      # (1, L)
        key = (pv_iota - rr_o) % PV + penalty
        # the empty-column sentinel must be a multiple of PV so the garbage
        # winner (% PV) is 0, exactly like the reference's packed min
        packed = jnp.where(req_o, key * PV + pv_iota, PV * (1 << 14))
        win_o = jnp.min(packed, axis=0, keepdims=True) % PV
        any_o = jnp.any(req_o, axis=0, keepdims=True)
        sel_o = pv_iota == win_o                       # (PV, L) one-hot
        wcls_o = jnp.sum(jnp.where(sel_o, cls, 0), axis=0, keepdims=True)

        allowed = jnp.where(wcls_o == 1, gmask, cmask)  # (V, L)
        dc_o = down_ref[o * V:(o + 1) * V, :]           # (V, L)
        has = (dc_o < depth) & allowed
        credit_o = jnp.any(has, axis=0, keepdims=True)
        first_vc = jnp.min(jnp.where(has, v_iota, V), axis=0, keepdims=True)
        down_vc_o = jnp.where(credit_o, first_vc, 0)   # argmax-of-bool conv.

        if o == local:
            grant_o = any_o & accept & active
        else:
            exists_o = exists_ref[o:o + 1, :] != 0
            grant_o = any_o & exists_o & credit_o & active

        grants.append(grant_o)
        winners.append(win_o)
        down_vcs.append(down_vc_o)
        any_reqs.append(any_o)
        w_clss.append(wcls_o)
        w_ports.append(win_o // V)
        sel_ohs.append(sel_o)
        new_rrs.append((win_o + 1) % PV)

    # one traversal per input port: keep the lowest-output grant per port
    ranks = [jnp.where(grants[o], o, BIG) for o in range(O)]
    min_rank = []
    for p in range(P):
        mr = jnp.full_like(ranks[0], BIG)
        for o in range(O):
            mr = jnp.minimum(mr, jnp.where(w_ports[o] == p, ranks[o], BIG))
        min_rank.append(mr)
    deq = jnp.zeros(valid.shape, jnp.int32)
    for o in range(O):
        sel_rank = jnp.zeros_like(ranks[o])
        for p in range(P):
            sel_rank = sel_rank + jnp.where(w_ports[o] == p, min_rank[p], 0)
        grants[o] = grants[o] & (ranks[o] == sel_rank)
        deq = deq | (sel_ohs[o] & grants[o]).astype(jnp.int32)
        new_rrs[o] = jnp.where(grants[o], new_rrs[o], rr_ref[o:o + 1, :])

    grant_ref[...] = jnp.concatenate(grants, axis=0).astype(jnp.int32)
    winner_ref[...] = jnp.concatenate(winners, axis=0)
    down_vc_ref[...] = jnp.concatenate(down_vcs, axis=0)
    deq_ref[...] = deq
    new_rr_ref[...] = jnp.concatenate(new_rrs, axis=0)
    any_req_ref[...] = jnp.concatenate(any_reqs, axis=0).astype(jnp.int32)
    w_cls_ref[...] = jnp.concatenate(w_clss, axis=0)


def noc_cycle_kernel(
    valid: jax.Array,       # (PV, L) int32 0/1
    cls: jax.Array,         # (PV, L) int32
    out_port: jax.Array,    # (PV, L) int32
    rr_ptr: jax.Array,      # (O, L) int32
    down_count: jax.Array,  # (O*V, L) int32
    down_exists: jax.Array,  # (O, L) int32 0/1
    gmask: jax.Array,       # (V, L) int32 0/1
    cmask: jax.Array,       # (V, L) int32 0/1
    sa_pref: jax.Array,     # (1, L) int32
    accept: jax.Array,      # (1, L) int32 0/1
    active: jax.Array,      # (1, L) int32 0/1
    *,
    depth: int,
    n_vcs: int,
    block_l: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Lane-blocked dispatch; L must be a multiple of `block_l`."""
    pv, lanes = valid.shape
    o = rr_ptr.shape[0]
    assert lanes % block_l == 0, (lanes, block_l)
    grid = (lanes // block_l,)

    def spec(rows):
        return pl.BlockSpec((rows, block_l), lambda i: (0, i))

    out_rows = [o, o, o, pv, o, o, o]
    kernel = functools.partial(_noc_cycle_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            spec(pv), spec(pv), spec(pv), spec(o), spec(o * n_vcs),
            spec(o), spec(n_vcs), spec(n_vcs), spec(1), spec(1), spec(1),
        ],
        out_specs=[spec(r) for r in out_rows],
        out_shape=[
            jax.ShapeDtypeStruct((r, lanes), jnp.int32) for r in out_rows
        ],
        interpret=interpret,
    )(valid, cls, out_port, rr_ptr, down_count, down_exists,
      gmask, cmask, sa_pref, accept, active)
