"""noc_cycle: Pallas lane kernels for the NoC cycle engine.

* `ref`    — the dense-jnp oracle (`router.arbitrate` et al.);
* `kernel` — the pallas_call launch shapes (arbitration-only and the
  fused full-cycle kernel, DESIGN.md §11/§13);
* `fused`  — lane layout, stage twins, and pack/unpack for the fused
  engine;
* `ops`    — dispatch entries (`arbitrate_lanes`, `fused_cycle_step`)
  with interpret-mode fallback off-TPU.
"""
