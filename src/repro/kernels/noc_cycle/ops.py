"""Lane-flattening wrappers for the noc_cycle kernels + backend dispatch.

`arbitrate_lanes` is signature-compatible with `repro.core.noc.router.
arbitrate` (the oracle in ref.py): it flattens every leading dimension of
the router state onto the kernel's lane axis — `(S, R)` for a single run,
`(B, S, R)` under a batched sweep — pads lanes to the 128-wide block, and
returns the same `Arbitration` pytree.  It backs
`simulate(..., backend="pallas_arb")`, the arbitration-only kernel swap.

`fused_cycle_step` is the full-cycle entry behind
`simulate(..., backend="pallas")`: one `fused_cycle_kernel` launch per
simulated cycle with the whole scan carry in lane layout (DESIGN.md §13).
Off-TPU both run in interpret mode (like `repro.kernels.kf_bank`), so every
backend works everywhere the tests run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.noc.router import Arbitration
from repro.kernels.noc_cycle import fused
from repro.kernels.noc_cycle.kernel import fused_cycle_kernel, noc_cycle_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_cycle_step(
    dims: fused.LaneDims,
    state: fused.LaneState,
    xi: jax.Array, xf: jax.Array,
    gmask: jax.Array, cmask: jax.Array, prof: jax.Array,
    pol_sr: jax.Array, pol_r: jax.Array,
    ntype: jax.Array, route: jax.Array, exists: jax.Array,
    probe: fused.ProbeLanes | None = None,
):
    """One fused simulated cycle (interpret-mode fallback off-TPU).

    Returns LaneState, or (LaneState, ProbeLanes) when a flight-recorder
    carry is threaded through (DESIGN.md §14)."""
    return fused_cycle_kernel(
        state, xi, xf, gmask, cmask, prof, pol_sr, pol_r,
        ntype, route, exists,
        dims=dims, interpret=_interpret(), probe=probe,
    )


def arbitrate_lanes(
    valid: jax.Array,        # (..., P*V) bool
    cls: jax.Array,          # (..., P*V) int32
    out_port: jax.Array,     # (..., P*V) int32
    rr_ptr: jax.Array,       # (..., O) int32
    down_count: jax.Array,   # (..., O, V) int32
    down_exists: jax.Array,  # (..., O) bool
    gpu_vc_mask: jax.Array,  # (..., V) bool
    cpu_vc_mask: jax.Array,  # (..., V) bool
    sa_pref: jax.Array,      # (...,) int32
    accept: jax.Array,       # (...,) bool
    active: jax.Array,       # (...,) bool
    *,
    depth: int,
    block_l: int = 128,
) -> Arbitration:
    lead = valid.shape[:-1]
    pv = valid.shape[-1]
    o = rr_ptr.shape[-1]
    v = down_count.shape[-1]
    lanes = 1
    for d in lead:
        lanes *= d
    pad = (-lanes) % block_l

    def to_lanes(x, tail: tuple[int, ...]):
        """Broadcast to full lead shape, flatten, pad, lanes-last layout."""
        rows = 1
        for d in tail:
            rows *= d
        x = jnp.broadcast_to(x, lead + tail).reshape(lanes, rows)
        x = jnp.pad(x.astype(jnp.int32), ((0, pad), (0, 0)))
        return x.T                                      # (rows, L)

    outs = noc_cycle_kernel(
        to_lanes(valid, (pv,)),
        to_lanes(cls, (pv,)),
        to_lanes(out_port, (pv,)),
        to_lanes(rr_ptr, (o,)),
        to_lanes(down_count, (o, v)),
        to_lanes(down_exists, (o,)),
        to_lanes(gpu_vc_mask, (v,)),
        to_lanes(cpu_vc_mask, (v,)),
        to_lanes(sa_pref, ()),
        to_lanes(accept, ()),
        to_lanes(active, ()),
        depth=depth,
        n_vcs=v,
        block_l=block_l,
        interpret=_interpret(),
    )

    def back(x, tail: tuple[int, ...], as_bool: bool = False):
        x = x.T[:lanes].reshape(lead + tail)
        return x != 0 if as_bool else x

    grant, winner, down_vc, deq, new_rr, any_req, w_cls = outs
    return Arbitration(
        grant=back(grant, (o,), as_bool=True),
        winner=back(winner, (o,)),
        down_vc=back(down_vc, (o,)),
        deq=back(deq, (pv,), as_bool=True),
        new_rr=back(new_rr, (o,)),
        any_req=back(any_req, (o,), as_bool=True),
        w_cls=back(w_cls, (o,)),
    )
