"""Jit'd wrapper for the KF-bank kernel with padding + backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kf_bank.kernel import kf_bank_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("a", "q", "block_b"))
def kf_bank_step(
    x: jax.Array,   # (B,) states
    p: jax.Array,   # (B,) variances
    z: jax.Array,   # (B, M) observations
    h: jax.Array,   # (M,)
    r: jax.Array,   # (M,)
    *,
    a: float = 1.0,
    q: float = 1e-3,
    block_b: int = 1024,
) -> tuple[jax.Array, jax.Array]:
    b = x.shape[0]
    block = min(block_b, max(b, 1))
    pad = (-b) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        p = jnp.pad(p, (0, pad), constant_values=1.0)  # variance stays valid
        z = jnp.pad(z, ((0, pad), (0, 0)))
    x_new, p_new = kf_bank_kernel(
        x, p, z, h, r, a=a, q=q, block_b=block, interpret=_interpret()
    )
    return x_new[:b], p_new[:b]
