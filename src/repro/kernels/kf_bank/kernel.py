"""Pallas TPU kernel: a BANK of independent scalar-state Kalman Filters.

The paper runs ONE filter (scalar IPC-trend state, 3 NoC counters).  At
fleet scale the same predictor runs per link x traffic-class x pod — tens of
thousands of concurrent filters advancing in lock-step each telemetry epoch.
This kernel advances B filters one predict+correct cycle.

TPU adaptation (DESIGN.md §3): the textbook measurement update (paper
Eqs. 3–5) needs an m x m innovation-covariance solve per filter — scalar
gather/solve chains that would serialize on the VPU.  For a scalar state
with diagonal R the measurement update has an exactly equivalent
*information-filter* form:

    1/p_k  = 1/p^_k + sum_m h_m^2 / r_m
    x_k    = p_k * (x^_k / p^_k + sum_m h_m z_m / r_m)

which is pure elementwise arithmetic + a tiny sum over m: filters ride the
128-wide lanes, observations ride sublanes.  Algebraic equivalence to
Eqs. 3–5 is asserted in tests against `repro.core.kalman` (the paper-form
oracle).

Layout: z (M, B) with B on lanes; x, p (1, B); h, r (M, 1) broadcast.
Grid tiles B in TB-lane blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kf_bank_kernel(
    x_ref, p_ref, z_ref, h_ref, r_ref,   # (1,TB) (1,TB) (M,TB) (M,1) (M,1)
    x_out, p_out,                         # (1, TB) each
    *,
    a: float,
    q: float,
):
    x = x_ref[...]
    p = p_ref[...]
    z = z_ref[...]
    h = h_ref[...]
    r = r_ref[...]

    # time update (Eqs. 1-2), scalar state
    x_prior = a * x
    p_prior = a * a * p + q

    # measurement update in information form (== Eqs. 3-5 for n=1, diag R)
    hr = h / r                                  # (M, 1)
    info = jnp.sum(h * hr, axis=0, keepdims=True)          # sum h^2/r  (1,1)
    p_post = 1.0 / (1.0 / p_prior + info)                  # (1, TB)
    innov = jnp.sum(hr * z, axis=0, keepdims=True)         # sum h z / r (1,TB)
    x_post = p_post * (x_prior / p_prior + innov)

    x_out[...] = x_post
    p_out[...] = p_post


def kf_bank_kernel(
    x: jax.Array,   # (B,) fp32 posterior state estimates
    p: jax.Array,   # (B,) fp32 posterior variances
    z: jax.Array,   # (B, M) fp32 observations
    h: jax.Array,   # (M,) observation model
    r: jax.Array,   # (M,) diagonal observation noise
    *,
    a: float = 1.0,
    q: float = 1e-3,
    block_b: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, m = z.shape
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    n_b = b // block_b

    xs = x.reshape(1, b)
    ps = p.reshape(1, b)
    zs = z.T.reshape(m, b)
    hs = h.reshape(m, 1).astype(jnp.float32)
    rs = r.reshape(m, 1).astype(jnp.float32)

    kernel = functools.partial(_kf_bank_kernel, a=a, q=q)
    x_new, p_new = pl.pallas_call(
        kernel,
        grid=(n_b,),
        in_specs=[
            pl.BlockSpec((1, block_b), lambda i: (0, i)),
            pl.BlockSpec((1, block_b), lambda i: (0, i)),
            pl.BlockSpec((m, block_b), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b), lambda i: (0, i)),
            pl.BlockSpec((1, block_b), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, b), jnp.float32),
            jax.ShapeDtypeStruct((1, b), jnp.float32),
        ],
        interpret=interpret,
    )(xs, ps, zs, hs, rs)
    return x_new.reshape(b), p_new.reshape(b)
