"""Oracle for the KF-bank kernel: the PAPER-FORM update (Eqs. 1-5) from
`repro.core.kalman`, vmapped over the bank — proving the kernel's
information-form update is algebraically identical."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kalman


def kf_bank_ref(
    x: jax.Array,   # (B,)
    p: jax.Array,   # (B,)
    z: jax.Array,   # (B, M)
    h: jax.Array,   # (M,)
    r: jax.Array,   # (M,)
    *,
    a: float = 1.0,
    q: float = 1e-3,
) -> tuple[jax.Array, jax.Array]:
    m = z.shape[1]
    params = kalman.KalmanParams(
        a=jnp.full((1, 1), a, jnp.float32),
        b=jnp.zeros((1, 1), jnp.float32),
        h=h.reshape(m, 1).astype(jnp.float32),
        q=jnp.full((1, 1), q, jnp.float32),
        r=jnp.diag(r.astype(jnp.float32)),
    )
    states = kalman.KalmanState(x=x[:, None], p=p[:, None, None])
    post, _, _ = kalman.batched_step(params, states, z, None)
    return post.x[:, 0], post.p[:, 0, 0]
