"""Pallas TPU selective-scan kernel (Mamba1 diagonal recurrence).

    h_t = a_t * h_{t-1} + b_t        a, b: (B, L, D, S) fp32

Grid (B, nD, nL): the LAST axis walks chunks of the sequence sequentially,
carrying the (TD, S) boundary state in VMEM scratch — the Pallas mirror of
`repro.models.mamba.chunked_scan`.  Inside a chunk the recurrence runs as a
log2(TC)-step Hillis–Steele doubling scan over the time axis: each step is
one full-tile multiply-add on the VPU (time on sublanes, channels on lanes),
instead of TC serial scalar steps.

TPU adaptation note (DESIGN.md §3): the CUDA Mamba kernel fuses conv1d +
scan per thread-block with warp shuffles; TPU has no warp-level exchange, so
the doubling scan over a (TC, TD*S) VMEM tile is the natural lowering — the
shifted operand is a sublane roll, compute stays dense elementwise.

VMEM per step (TC=256, TD=256, S=16): a/b/hs tiles 3 x 4 MiB fp32 + carry
16 KiB ≈ 12 MiB — sized to the v5e budget; shrink TD for larger S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    a_ref, b_ref, h0_ref,     # (1, TC, TD, S), (1, TC, TD, S), (1, TD, S)
    hs_ref, hlast_ref,        # (1, TC, TD, S), (1, TD, S)
    h_scr,                    # VMEM (TD, S) fp32 carry across chunks
    *,
    chunk: int,
    n_chunks: int,
):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[0]              # (TC, TD, S)
    b = b_ref[0]

    # Hillis–Steele doubling: after step k, (a, b)[t] composes the recurrence
    # over the last 2^k elements.  Shift via jnp.roll + mask (sublane roll).
    shift = 1
    while shift < chunk:
        a_prev = jnp.roll(a, shift, axis=0)
        b_prev = jnp.roll(b, shift, axis=0)
        t = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
        live = t >= shift
        a_new = jnp.where(live, a * a_prev, a)
        b_new = jnp.where(live, a * b_prev + b, b)
        a, b = a_new, b_new
        shift *= 2

    # prefix over the chunk composed with the incoming carry
    h0 = h_scr[...]
    hs = a * h0[None] + b     # (TC, TD, S)
    hs_ref[0] = hs
    h_scr[...] = hs[-1]

    @pl.when(il == n_chunks - 1)
    def _final():
        hlast_ref[0] = hs[-1]


def mamba_scan_kernel(
    a: jax.Array,   # (B, L, D, S) fp32
    b: jax.Array,
    h0: jax.Array,  # (B, D, S) fp32
    *,
    chunk: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bsz, L, d, s = a.shape
    chunk = min(chunk, L)
    block_d = min(block_d, d)
    assert L % chunk == 0 and d % block_d == 0, (L, chunk, d, block_d)
    n_chunks, n_d = L // chunk, d // block_d

    grid = (bsz, n_d, n_chunks)
    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    hs, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d, s), lambda b_, id_, il: (b_, il, id_, 0)),
            pl.BlockSpec((1, chunk, block_d, s), lambda b_, id_, il: (b_, il, id_, 0)),
            pl.BlockSpec((1, block_d, s), lambda b_, id_, il: (b_, id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d, s), lambda b_, id_, il: (b_, il, id_, 0)),
            pl.BlockSpec((1, block_d, s), lambda b_, id_, il: (b_, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, L, d, s), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, s), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return hs, hlast
