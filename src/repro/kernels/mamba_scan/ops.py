"""Jit'd wrapper for the selective-scan kernel with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def mamba_chunk_scan(
    a: jax.Array,   # (B, L, D, S) fp32
    b: jax.Array,
    h0: jax.Array,  # (B, D, S) fp32
    *,
    chunk: int = 256,
    block_d: int = 256,
) -> tuple[jax.Array, jax.Array]:
    return mamba_scan_kernel(
        a, b, h0, chunk=chunk, block_d=block_d, interpret=_interpret()
    )
