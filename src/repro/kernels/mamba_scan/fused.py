"""Fused selective-scan kernel v2: builds the recurrence inputs in VMEM.

The v1 kernel (kernel.py) consumes precomputed a = exp(dt*A) and b = dt*x*B
of shape (B, L, D, S) — an O(L*D*S) HBM round-trip that dominates the
falcon-mamba roofline (S=16 => 16x the O(L*D) activation traffic).  v2
fuses the construction AND the C-projection:

    HBM in : dt, xc (B, L, D) + b, c (B, L, S) + A (D, S)
    VMEM   : a = exp(dt x A), bx = (dt*xc) x b, doubling scan, y = <h, c>
    HBM out: y (B, L, D) + h_last (B, D, S)

traffic O(L*D + L*S) — the 2(S)x win the §Perf hillclimb claims, backed by
this kernel validating against the same oracle as v1.

Grid (B, nD, nL), sequence chunks innermost (sequential) with the carry in
VMEM scratch, exactly like v1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(
    dt_ref, xc_ref, b_ref, c_ref, a_mat_ref,   # (1,TC,TD) x2, (1,TC,S) x2, (TD,S)
    y_ref, hlast_ref,                          # (1,TC,TD), (1,TD,S)
    h_scr,                                     # VMEM (TD, S)
    *,
    chunk: int,
    n_chunks: int,
):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)          # (TC, TD)
    xc = xc_ref[0].astype(jnp.float32)
    bt = b_ref[0].astype(jnp.float32)           # (TC, S)
    ct = c_ref[0].astype(jnp.float32)
    a_mat = a_mat_ref[...]                      # (TD, S) negative decay

    # build recurrence inputs in VMEM (never hit HBM)
    a = jnp.exp(dt[:, :, None] * a_mat[None])              # (TC, TD, S)
    bx = (dt * xc)[:, :, None] * bt[:, None, :]            # (TC, TD, S)

    # Hillis–Steele doubling over time (sublane axis)
    shift = 1
    while shift < chunk:
        a_prev = jnp.roll(a, shift, axis=0)
        b_prev = jnp.roll(bx, shift, axis=0)
        t = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
        live = t >= shift
        a, bx = (jnp.where(live, a * a_prev, a),
                 jnp.where(live, a * b_prev + bx, bx))
        shift *= 2

    hs = a * h_scr[...][None] + bx                         # (TC, TD, S)
    y_ref[0] = jnp.sum(hs * ct[:, None, :], axis=-1).astype(y_ref.dtype)
    h_scr[...] = hs[-1]

    @pl.when(il == n_chunks - 1)
    def _final():
        hlast_ref[0] = hs[-1]


def fused_mamba_scan_kernel(
    dt: jax.Array,     # (B, L, D) fp32
    xc: jax.Array,     # (B, L, D)
    b: jax.Array,      # (B, L, S)
    c: jax.Array,      # (B, L, S)
    a_mat: jax.Array,  # (D, S) negative decay matrix
    *,
    chunk: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bsz, L, d = dt.shape
    s = a_mat.shape[1]
    chunk = min(chunk, L)
    block_d = min(block_d, d)
    assert L % chunk == 0 and d % block_d == 0, (L, chunk, d, block_d)
    n_chunks, n_d = L // chunk, d // block_d

    grid = (bsz, n_d, n_chunks)
    kernel = functools.partial(_fused_kernel, chunk=chunk, n_chunks=n_chunks)
    y, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, id_, il: (b_, il, id_)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, id_, il: (b_, il, id_)),
            pl.BlockSpec((1, chunk, s), lambda b_, id_, il: (b_, il, 0)),
            pl.BlockSpec((1, chunk, s), lambda b_, id_, il: (b_, il, 0)),
            pl.BlockSpec((block_d, s), lambda b_, id_, il: (id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, id_, il: (b_, il, id_)),
            pl.BlockSpec((1, block_d, s), lambda b_, id_, il: (b_, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, L, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, s), jnp.float32)],
        interpret=interpret,
    )(dt, xc, b, c, a_mat)
    return y, hlast


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def fused_mamba_scan(dt, xc, b, c, a_mat, *, chunk: int = 256,
                     block_d: int = 256):
    return fused_mamba_scan_kernel(
        dt, xc, b, c, a_mat, chunk=chunk, block_d=block_d,
        interpret=_interpret())
