"""Pure-jnp oracle for the selective-scan kernel: naive O(L) recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_ref(
    a: jax.Array,   # (B, L, D, S)
    b: jax.Array,
    h0: jax.Array,  # (B, D, S)
) -> tuple[jax.Array, jax.Array]:
    def body(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    h_last, hs = jax.lax.scan(body, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1), h_last
