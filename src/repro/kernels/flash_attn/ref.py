"""Pure-jnp oracle for the flash attention kernel ((B, H, S, D) layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    kv_len: int | None = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    rep = h // kvh
    kv_len = sk if kv_len is None else kv_len
    kr = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) / jnp.sqrt(d)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos < kv_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows give uniform p; zero them like the kernel does
    any_valid = jnp.any(mask, axis=-1)                        # (Sq,)
    p = jnp.where(any_valid[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)
