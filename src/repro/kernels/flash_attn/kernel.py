"""Pallas TPU flash attention: online-softmax tiling over KV blocks.

Grid (B, H, nQ, nK) — the LAST axis iterates sequentially on TPU, so the
online-softmax running max / denominator / accumulator live in VMEM scratch
carried across KV iterations; the output tile is written once at ik == nK-1.

VMEM working set per grid step (defaults TQ=TK=512, D=128, bf16 in / fp32
acc):  q 128 KiB + k 128 KiB + v 128 KiB + acc 256 KiB + m/l 512 KiB
≈ 1.2 MiB — comfortably inside the ~16 MiB v5e VMEM budget, with MXU-aligned
(multiple-of-128) matmul dims.

Causal and sliding-window block skipping happens at two levels: fully-masked
blocks are skipped via `pl.when` (no MXU work issued), partially-masked
blocks apply an element mask.  GQA is handled by the k/v index_map mapping
query-head h to kv-head h // (H // KV) — repeated KV is never materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
MIN_LANE = 128


def _flash_kernel(
    q_ref, k_ref, v_ref,            # (1, 1, TQ, D), (1, 1, TK, D) x2
    o_ref,                          # (1, 1, TQ, D)
    m_scr, l_scr, acc_scr,          # VMEM scratch: (TQ, 128), (TQ, 128), (TQ, D)
    *,
    causal: bool,
    window: int | None,
    logit_cap: float | None,
    kv_len: int,
    block_q: int,
    block_k: int,
    n_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # --- block-level skip tests (static grid, dynamic predicate) ---
    run = True
    if causal:
        # block fully above the diagonal -> no valid (q, k) pair
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        # block fully left of every query's window -> skip
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (TQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (TK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) / math.sqrt(d)                              # (TQ, TK)
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                         # (TQ, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (TQ, TK)
        corr = jnp.exp(m_prev - m_new)                # (TQ, 1)
        l_new = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)            # fully-masked rows -> 0
        o_ref[0, 0, ...] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    kv_len: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0
    rep = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    n_q, n_k = sq // block_q, sk // block_k
    kv_len = sk if kv_len is None else kv_len

    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal, window=window, logit_cap=logit_cap,
        kv_len=kv_len, block_q=block_q, block_k=block_k, n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // rep, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // rep, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
