"""Jit'd public wrapper: model layout (B, S, H, D) -> kernel layout, padding,
backend dispatch (Pallas-compiled on TPU, interpret=True elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_cap", "block_q", "block_k"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D) — model layout
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    sq, sk = q.shape[1], k.shape[1]
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, min(block_q, 128))
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, min(block_k, 128))
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, min(block_k, 128))
    bq = min(block_q, qt.shape[2])
    bk = min(block_k, kt.shape[2])
    # shrink block until it divides (padding guarantees divisibility by 128)
    while qt.shape[2] % bq:
        bq //= 2
    while kt.shape[2] % bk:
        bk //= 2
    out = flash_attention_kernel(
        qt, kt, vt,
        causal=causal, window=window, logit_cap=logit_cap,
        kv_len=sk, block_q=bq, block_k=bk,
        interpret=_interpret(),
    )
    return out[:, :, :sq].transpose(0, 2, 1, 3)
