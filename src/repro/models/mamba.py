"""Selective state-space layers: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

Scan strategy
-------------
The diagonal recurrence  h_t = a_t * h_{t-1} + b_t  is evaluated with a
*chunked associative scan*: `lax.scan` over chunks of `cfg.ssm_chunk` tokens
carrying only the (B, d_inner, d_state) boundary state, with
`lax.associative_scan` inside each chunk.  The (L, d_inner, d_state) tensor
is therefore never materialized beyond one chunk — this is what makes the
prefill_32k / long-context cells lower with bounded memory, and it is the
structure the Pallas kernel (`repro.kernels.mamba_scan`) mirrors with VMEM
tiles.  A naive O(L) scan lives in `ref_scan` as the oracle.

Decode is a single-step state update (`*_decode`), carrying a conv ring
buffer + the SSM state — the SSM analogue of a KV cache, O(1) in context
length (why the 500k-token cell runs on the SSM/hybrid archs only).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


# --------------------------------------------------------------------------
# Core diagonal-recurrence scans
# --------------------------------------------------------------------------

def _assoc_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, a2 * b1 + b2


def ref_scan(a: Array, b: Array, h0: Array) -> tuple[Array, Array]:
    """Oracle: h_t = a_t h_{t-1} + b_t via lax.scan over time.

    a, b: (B, L, ...) broadcast-compatible; h0: (B, ...).
    Returns (hs (B, L, ...), h_final).
    """

    def body(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    h_last, hs = jax.lax.scan(body, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1), h_last


def chunked_scan(a: Array, b: Array, h0: Array, chunk: int) -> tuple[Array, Array]:
    """Chunked associative scan. a, b: (B, L, ...) broadcast-compatible
    trailing dims (mamba2's decay is (B, L, nh, 1, 1)); L % chunk == 0.

    NOTE: materializes hs for the full L — use only for small L / tests.
    The production path is `fused_chunked_scan_m1/_m2`, which fold the
    decay construction and the C-projection into the chunk loop so nothing
    of size (L, d_inner, d_state) ever exists.
    """
    bsz, L = b.shape[0], b.shape[1]
    n = L // chunk
    rest = b.shape[2:]
    a_c = a.reshape(bsz, n, chunk, *a.shape[2:])
    b_c = b.reshape(bsz, n, chunk, *rest)

    def body(h, ab):
        ac, bc = ab  # (B, chunk, ...)
        pa, pb = jax.lax.associative_scan(_assoc_combine, (ac, bc), axis=1)
        hs = pa * h[:, None] + pb
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(
        body, h0, (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0))
    )
    hs = jnp.moveaxis(hs, 0, 1).reshape(bsz, L, *rest)
    return hs, h_last


def fused_chunked_scan_m1(
    dt: Array,    # (B, L, di) fp32 — softplus'd step sizes
    xc: Array,    # (B, L, di) conv output (post-silu)
    b_t: Array,   # (B, L, ds)
    c_t: Array,   # (B, L, ds)
    a_mat: Array,  # (di, ds) negative decay matrix
    h0: Array,    # (B, di, ds) fp32
    chunk: int,
) -> tuple[Array, Array]:
    """Memory-bounded Mamba1 scan: per-chunk working set only.

    Builds a = exp(dt*A) and b = dt*x*B INSIDE the chunk loop and folds the
    C-projection, emitting y (B, L, di) — the (L, di, ds) tensor never
    materializes (prefill_32k at d_inner=8192 would otherwise need TBs).
    """
    bsz, L, di = dt.shape
    ds = a_mat.shape[1]
    n = L // chunk

    def rs(x):
        return jnp.moveaxis(
            x.reshape(bsz, n, chunk, *x.shape[2:]), 1, 0)

    def body(h, inputs):
        dt_c, xc_c, b_c, c_c = inputs           # (B, C, di) / (B, C, ds)
        a = jnp.exp(dt_c[..., None] * a_mat)    # (B, C, di, ds)
        bx = (dt_c * xc_c.astype(jnp.float32))[..., None] \
            * b_c.astype(jnp.float32)[:, :, None, :]
        pa, pb = jax.lax.associative_scan(_assoc_combine, (a, bx), axis=1)
        hs = pa * h[:, None] + pb
        y = jnp.einsum("bcds,bcs->bcd", hs, c_c.astype(jnp.float32))
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(
        body, h0, (rs(dt), rs(xc), rs(b_t), rs(c_t)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, L, di)
    return y, h_last


def fused_chunked_scan_m2(
    dt: Array,    # (B, L, nh) fp32
    xh: Array,    # (B, L, nh, hd)
    b_t: Array,   # (B, L, ds)
    c_t: Array,   # (B, L, ds)
    a_h: Array,   # (nh,) negative per-head decay
    h0: Array,    # (B, nh, hd, ds) fp32
    chunk: int,
) -> tuple[Array, Array]:
    """Memory-bounded Mamba2/SSD scan; emits y (B, L, nh, hd)."""
    bsz, L, nh = dt.shape
    n = L // chunk

    def rs(x):
        return jnp.moveaxis(
            x.reshape(bsz, n, chunk, *x.shape[2:]), 1, 0)

    def body(h, inputs):
        dt_c, xh_c, b_c, c_c = inputs
        a = jnp.exp(dt_c * a_h)[..., None, None]          # (B,C,nh,1,1)
        bx = (dt_c[..., None] * xh_c.astype(jnp.float32))[..., None] \
            * b_c.astype(jnp.float32)[:, :, None, None, :]  # (B,C,nh,hd,ds)
        pa, pb = jax.lax.associative_scan(_assoc_combine, (a, bx), axis=1)
        hs = pa * h[:, None] + pb
        y = jnp.einsum("bchds,bcs->bchd", hs, c_c.astype(jnp.float32))
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(
        body, h0, (rs(dt), rs(xh), rs(b_t), rs(c_t)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, L, nh, xh.shape[-1])
    return y, h_last


def causal_conv1d(x: Array, w: Array, bias: Array, state: Array | None = None):
    """Depthwise causal conv. x: (B, L, C); w: (K, C); state: (B, K-1, C).

    Returns (y (B, L, C), new_state (B, K-1, C)).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k)
    )
    y = y + bias.astype(x.dtype)
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _softplus(x):
    return jax.nn.softplus(x)


# --------------------------------------------------------------------------
# Mamba1 (falcon-mamba-7b)
# --------------------------------------------------------------------------

def dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def make_mamba1(key, cfg: ModelConfig, dtype) -> dict:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    r = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real init for A; dt bias init so softplus(dt_bias) spans [1e-3, 1e-1]
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[5], (di,), jnp.float32)
        * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)
    )
    inv_dt = dt_init + jnp.log(-jnp.expm1(-dt_init))  # softplus^-1
    return {
        "in_proj": layers.dense_init(ks[0], d, (d, 2 * di), dtype),
        "conv_w": layers.truncated_normal(ks[1], (dc, di), (1.0 / dc) ** 0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(ks[2], di, (di, r + 2 * ds), dtype),
        "dt_proj": layers.truncated_normal(ks[3], (r, di), r ** -0.5, jnp.float32),
        "dt_bias": inv_dt,
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], di, (di, d), dtype),
    }


def mamba1_spec(cfg: ModelConfig) -> dict:
    return {
        "in_proj": P("embed", "mlp"),
        "conv_w": P(None, "mlp"),
        "conv_b": P("mlp"),
        "x_proj": P("mlp", None),
        "dt_proj": P(None, "mlp"),
        "dt_bias": P("mlp"),
        "a_log": P("mlp", None),
        "d_skip": P("mlp"),
        "out_proj": P("mlp", "embed"),
    }


def _mamba1_ssm_inputs(p, xc: Array, cfg: ModelConfig):
    """xc: conv output (B, L, di) -> (dt, b_t, c_t, a_mat); the decay and
    input tensors of size (L, di, ds) are built lazily inside the scan."""
    r, ds = dt_rank(cfg), cfg.ssm_state
    proj = layers.matmul(xc, p["x_proj"])
    dt_r, b_t, c_t = jnp.split(proj, [r, r + ds], axis=-1)
    dt = jnp.einsum("blr,rd->bld", dt_r.astype(jnp.float32), p["dt_proj"])
    dt = _softplus(dt + p["dt_bias"])                     # (B, L, di) fp32
    a_mat = -jnp.exp(p["a_log"])                          # (di, ds)
    return dt, b_t, c_t, a_mat


def apply_mamba1(
    p, x: Array, cfg: ModelConfig, *, use_kernel: bool = False
) -> Array:
    """Full-sequence Mamba1 mixer. x: (B, L, D)."""
    y, _ = _mamba1_scan(p, x, cfg, use_kernel=use_kernel)
    return y


def _mamba1_scan(
    p, x: Array, cfg: ModelConfig, *, use_kernel: bool = False
) -> tuple[Array, "Mamba1State"]:
    di = cfg.d_inner
    xz = layers.matmul(x, p["in_proj"])
    xr, z = jnp.split(xz, [di], axis=-1)
    xc, conv_state = causal_conv1d(xr, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt, b_t, c_t, a_mat = _mamba1_ssm_inputs(p, xc, cfg)
    h0 = jnp.zeros((x.shape[0], di, cfg.ssm_state), jnp.float32)
    L = x.shape[1]
    chunk = min(cfg.ssm_chunk, L)
    if use_kernel and L % chunk == 0:
        from repro.kernels.mamba_scan import ops as scan_ops

        a = jnp.exp(dt[..., None] * a_mat)
        bx = (dt * xc.astype(jnp.float32))[..., None] \
            * b_t.astype(jnp.float32)[:, :, None, :]
        hs, h_last = scan_ops.mamba_chunk_scan(a, bx, h0, chunk=chunk)
        y = jnp.einsum("blds,bls->bld", hs, c_t.astype(jnp.float32))
    elif L % chunk == 0:
        y, h_last = fused_chunked_scan_m1(dt, xc, b_t, c_t, a_mat, h0, chunk)
    else:
        a = jnp.exp(dt[..., None] * a_mat)
        bx = (dt * xc.astype(jnp.float32))[..., None] \
            * b_t.astype(jnp.float32)[:, :, None, :]
        hs, h_last = ref_scan(a, bx, h0)
        y = jnp.einsum("blds,bls->bld", hs, c_t.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = layers.matmul(y, p["out_proj"])
    return out, Mamba1State(conv=conv_state, ssm=h_last)


class Mamba1State(NamedTuple):
    conv: Array  # (B, K-1, di)
    ssm: Array   # (B, di, ds) fp32


def init_mamba1_state(batch: int, cfg: ModelConfig, dtype) -> Mamba1State:
    return Mamba1State(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )


def apply_mamba1_decode(
    p, x: Array, cfg: ModelConfig, state: Mamba1State
) -> tuple[Array, Mamba1State]:
    """x: (B, 1, D) — one-token state update (the SSM 'KV cache' step)."""
    di = cfg.d_inner
    xz = layers.matmul(x, p["in_proj"])
    xr, z = jnp.split(xz, [di], axis=-1)
    xc, conv_state = causal_conv1d(xr, p["conv_w"], p["conv_b"], state.conv)
    xc = jax.nn.silu(xc)
    dt, b_t, c_t, a_mat = _mamba1_ssm_inputs(p, xc, cfg)
    a = jnp.exp(dt[:, 0, :, None] * a_mat)                # (B, di, ds)
    bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
        * b_t[:, 0].astype(jnp.float32)[:, None, :]
    h = a * state.ssm + bx                                # (B, di, ds)
    y = jnp.einsum("bds,bs->bd", h, c_t[:, 0].astype(jnp.float32))
    y = y + xc[:, 0].astype(jnp.float32) * p["d_skip"]
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = layers.matmul(y, p["out_proj"])
    return out, Mamba1State(conv=conv_state, ssm=h)


# --------------------------------------------------------------------------
# Mamba2 / SSD (zamba2)
# --------------------------------------------------------------------------

def n_ssm_heads(cfg: ModelConfig) -> int:
    return cfg.d_inner // cfg.ssm_head_dim


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state  # x + B + C (n_groups = 1)


def make_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = n_ssm_heads(cfg)
    cd = conv_dim(cfg)
    ks = jax.random.split(key, 4)
    dt_init = jnp.exp(
        jax.random.uniform(ks[3], (nh,), jnp.float32)
        * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)
    )
    inv_dt = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        # z | x | B | C | dt
        "in_proj": layers.dense_init(ks[0], d, (d, 2 * di + 2 * ds + nh), dtype),
        "conv_w": layers.truncated_normal(ks[1], (dc, cd), (1.0 / dc) ** 0.5, dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "dt_bias": inv_dt,
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
        "out_proj": layers.dense_init(ks[2], di, (di, d), dtype),
    }


def mamba2_spec(cfg: ModelConfig) -> dict:
    return {
        "in_proj": P("embed", "mlp"),
        "conv_w": P(None, None),
        "conv_b": P(None),
        "dt_bias": P(None),
        "a_log": P(None),
        "d_skip": P(None),
        "norm": {"scale": P("mlp")},
        "out_proj": P("mlp", "embed"),
    }


def _mamba2_split(p, x: Array, cfg: ModelConfig):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, n_ssm_heads(cfg)
    zxbcdt = layers.matmul(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim(cfg)], axis=-1)
    return z, xbc, dt


def _mamba2_ssm_inputs(p, xbc: Array, dt_raw: Array, cfg: ModelConfig):
    """Returns (dt, xh, b_t, c_t, a_h) — decay built lazily in the scan."""
    di, ds, nh = cfg.d_inner, cfg.ssm_state, n_ssm_heads(cfg)
    hd = cfg.ssm_head_dim
    xr, b_t, c_t = jnp.split(xbc, [di, di + ds], axis=-1)
    bsz, L = xr.shape[0], xr.shape[1]
    xh = xr.reshape(bsz, L, nh, hd)
    dt = _softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B, L, nh)
    a_h = -jnp.exp(p["a_log"])                                  # (nh,)
    return dt, xh, b_t, c_t, a_h


def apply_mamba2(p, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Mamba2/SSD mixer. x: (B, L, D)."""
    y, _ = _mamba2_scan(p, x, cfg)
    return y


def _mamba2_scan(p, x: Array, cfg: ModelConfig):
    nh, hd, ds = n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    z, xbc, dt_raw = _mamba2_split(p, x, cfg)
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    dt, xh, b_t, c_t, a_h = _mamba2_ssm_inputs(p, xbc, dt_raw, cfg)
    h0 = jnp.zeros((x.shape[0], nh, hd, ds), jnp.float32)
    L = x.shape[1]
    chunk = min(cfg.ssm_chunk, L)
    if L % chunk == 0:
        y, h_last = fused_chunked_scan_m2(dt, xh, b_t, c_t, a_h, h0, chunk)
    else:
        a = jnp.exp(dt * a_h)[..., None, None]
        bx = (dt[..., None] * xh.astype(jnp.float32))[..., None] \
            * b_t.astype(jnp.float32)[:, :, None, None, :]
        hs, h_last = ref_scan(a, bx, h0)
        y = jnp.einsum("blhds,bls->blhd", hs, c_t.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(x.shape[0], L, cfg.d_inner)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = layers.apply_norm(p["norm"], y, "rmsnorm")
    out = layers.matmul(y, p["out_proj"])
    return out, Mamba2State(conv=conv_state, ssm=h_last)


class Mamba2State(NamedTuple):
    conv: Array  # (B, K-1, conv_dim)
    ssm: Array   # (B, nh, hd, ds) fp32


def init_mamba2_state(batch: int, cfg: ModelConfig, dtype) -> Mamba2State:
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
        ssm=jnp.zeros(
            (batch, n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    )


def apply_mamba2_decode(
    p, x: Array, cfg: ModelConfig, state: Mamba2State
) -> tuple[Array, Mamba2State]:
    z, xbc, dt_raw = _mamba2_split(p, x, cfg)
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], p["conv_b"], state.conv)
    xbc = jax.nn.silu(xbc)
    dt, xh, b_t, c_t, a_h = _mamba2_ssm_inputs(p, xbc, dt_raw, cfg)
    a = jnp.exp(dt[:, 0] * a_h)[..., None, None]          # (B,nh,1,1)
    bx = (dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32))[..., None] \
        * b_t[:, 0].astype(jnp.float32)[:, None, None, :]
    h = a * state.ssm + bx
    y = jnp.einsum("bhds,bs->bhd", h, c_t[:, 0].astype(jnp.float32))
    y = y + xh[:, 0].astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(x.shape[0], 1, cfg.d_inner).astype(x.dtype) * jax.nn.silu(z)
    y = layers.apply_norm(p["norm"], y, "rmsnorm")
    out = layers.matmul(y, p["out_proj"])
    return out, Mamba2State(conv=conv_state, ssm=h)
