"""Decoder LM assembly: dense / MoE / SSM / hybrid, train + prefill + decode.

Layer-pattern design
--------------------
Every assigned decoder arch is a repetition of a short *pattern* of block
kinds (period P), scanned `n_layers // P` times with `lax.scan` over stacked
parameters (fast compiles at 40–64 layers, O(1) HLO size in depth):

  dense archs           P=1  [attn+mlp]
  grok-1                P=1  [attn+moe]
  llama4-maverick       P=2  [attn+mlp, attn+moe]       (MoE every 2nd layer)
  falcon-mamba          P=1  [mamba1]
  zamba2                P=6  [mamba2 x6] + SHARED attn block (weights reused
                             across super-blocks — Zamba's defining trick)

Params for pattern position j are stacked over super-blocks; the shared
attention block is closed over (not scanned).  Activation-checkpoint policy
(`cfg.remat`) wraps the scan body.  MoE aux losses accumulate in the carry.

Decode threads per-kind caches through the same scan as scanned inputs and
re-collected outputs; prefill is `forward(..., return_caches=True)`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding
from repro.models import attention, frontends, layers, mamba, moe
from repro.models.attention import KVCache
from repro.models.config import ModelConfig

Array = jax.Array

ACT_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# Pattern derivation
# --------------------------------------------------------------------------

def layer_pattern(cfg: ModelConfig) -> tuple[tuple[str, ...], int]:
    """Return (pattern, n_super). pattern entries: dense|moe|mamba1|mamba2."""
    if cfg.is_hybrid:
        p = cfg.shared_attn_period
        assert cfg.n_layers % p == 0
        return tuple(["mamba2"] * p), cfg.n_layers // p
    if cfg.is_ssm:
        return ("mamba1",), cfg.n_layers
    if cfg.is_moe:
        period = cfg.moe_layer_period
        assert cfg.n_layers % period == 0
        mask = cfg.moe_layer_mask()[:period]
        return tuple("moe" if m else "dense" for m in mask), cfg.n_layers // period
    return ("dense",), cfg.n_layers


# --------------------------------------------------------------------------
# Per-kind block param constructors / specs / applications
# --------------------------------------------------------------------------

def _make_block(key, kind: str, cfg: ModelConfig, dtype) -> dict:
    if kind in ("dense", "moe"):
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": layers.make_norm(cfg.d_model, cfg.norm),
            "ln2": layers.make_norm(cfg.d_model, cfg.norm),
            "attn": attention.make_attention(k1, cfg, dtype),
        }
        if kind == "moe":
            p["moe"] = moe.make_moe(k2, cfg, dtype)
        else:
            p["mlp"] = layers.make_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        return p
    if kind == "mamba1":
        return {
            "ln": layers.make_norm(cfg.d_model, cfg.norm),
            "mixer": mamba.make_mamba1(key, cfg, dtype),
        }
    if kind == "mamba2":
        return {
            "ln": layers.make_norm(cfg.d_model, cfg.norm),
            "mixer": mamba.make_mamba2(key, cfg, dtype),
        }
    raise ValueError(kind)


def _block_spec(kind: str, cfg: ModelConfig) -> dict:
    if kind in ("dense", "moe"):
        s = {
            "ln1": layers.norm_spec(cfg.norm),
            "ln2": layers.norm_spec(cfg.norm),
            "attn": attention.attention_spec(cfg),
        }
        if kind == "moe":
            s["moe"] = moe.moe_spec(cfg)
        else:
            s["mlp"] = layers.mlp_spec()
        return s
    spec = mamba.mamba1_spec(cfg) if kind == "mamba1" else mamba.mamba2_spec(cfg)
    return {"ln": layers.norm_spec(cfg.norm), "mixer": spec}


def _zero_aux() -> moe.MoEAux:
    z = jnp.float32(0.0)
    return moe.MoEAux(z, z, jnp.zeros((1,), jnp.float32))


def _apply_block(
    p, kind: str, x: Array, cfg: ModelConfig, positions: Array,
    *, use_kernel: bool,
) -> tuple[Array, Optional[moe.MoEAux]]:
    if kind in ("dense", "moe"):
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        h = attention.self_attention(
            p["attn"], h, cfg, positions, use_kernel=use_kernel
        )
        x = sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed")
        h = layers.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            h, aux = moe.apply_moe(p["moe"], h, cfg)
        else:
            h, aux = layers.apply_mlp(p["mlp"], h, cfg.act), None
        return sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed"), aux
    h = layers.apply_norm(p["ln"], x, cfg.norm)
    if kind == "mamba1":
        h = mamba.apply_mamba1(p["mixer"], h, cfg, use_kernel=use_kernel)
    else:
        h = mamba.apply_mamba2(p["mixer"], h, cfg)
    return sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed"), None


# --------------------------------------------------------------------------
# Model construction
# --------------------------------------------------------------------------

def param_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def make_lm(key, cfg: ModelConfig) -> tuple[dict, dict]:
    """Returns (params, logical PartitionSpec tree of identical structure)."""
    dtype = param_dtype(cfg)
    pattern, n_super = layer_pattern(cfg)
    k_emb, k_blocks, k_shared, k_head, k_front = jax.random.split(key, 5)

    params: dict[str, Any] = {
        "embed": layers.make_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": layers.make_norm(cfg.d_model, cfg.norm),
    }
    specs: dict[str, Any] = {
        "embed": layers.embedding_spec(),
        "final_norm": layers.norm_spec(cfg.norm),
    }

    # stacked pattern-position params: blocks[j] has leading dim n_super
    blocks, bspecs = [], []
    pos_keys = jax.random.split(k_blocks, len(pattern))
    for j, kind in enumerate(pattern):
        lkeys = jax.random.split(pos_keys[j], n_super)
        stacked = jax.vmap(lambda k: _make_block(k, kind, cfg, dtype))(lkeys)
        blocks.append(stacked)
        spec = _block_spec(kind, cfg)
        bspecs.append(jax.tree.map(
            lambda s: P(None, *s), spec, is_leaf=lambda s: isinstance(s, P)
        ))
    params["blocks"] = blocks
    specs["blocks"] = bspecs

    if cfg.is_hybrid:  # zamba2's single shared attention block
        params["shared_attn"] = _make_block(k_shared, "dense", cfg, dtype)
        specs["shared_attn"] = _block_spec("dense", cfg)

    if not cfg.tie_embeddings:
        params["unembed"] = {
            "table": layers.truncated_normal(
                k_head, (cfg.vocab_size, cfg.d_model), cfg.d_model ** -0.5, dtype
            )
        }
        specs["unembed"] = layers.embedding_spec()

    if cfg.frontend:
        params["projector"] = frontends.make_projector(k_front, cfg, dtype)
        specs["projector"] = frontends.projector_spec(cfg)

    return params, specs


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------

def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


class ForwardOut(NamedTuple):
    logits: Array
    aux: moe.MoEAux
    caches: Any  # per-kind stacked caches when return_caches else None


def forward(
    params: dict,
    tokens: Array,
    cfg: ModelConfig,
    *,
    embeds: Optional[Array] = None,
    use_kernel: bool = False,
    return_caches: bool = False,
    cache_len: Optional[int] = None,
) -> ForwardOut:
    """tokens: (B, S) int32; embeds: (B, F, frontend_dim) for [audio]/[vlm]."""
    pattern, n_super = layer_pattern(cfg)
    b, s = tokens.shape

    x = layers.embed(params["embed"], tokens, ACT_DTYPE)
    if cfg.frontend and embeds is not None:
        prefix = frontends.apply_projector(
            params["projector"], embeds.astype(ACT_DTYPE), cfg
        )
        x = frontends.splice_prefix(x, prefix)
    x = sharding.constrain(x, "batch", sharding.seq_axis(), "embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    smax = cache_len or s
    _shared = params.get("shared_attn")

    n_experts = cfg.n_experts if cfg.is_moe else 1

    def super_block(x, block_params):
        lb = jnp.float32(0.0)
        zl = jnp.float32(0.0)
        load = jnp.zeros((n_experts,), jnp.float32)
        for j, kind in enumerate(pattern):
            x, aux = _apply_block(
                block_params[j], kind, x, cfg, positions, use_kernel=use_kernel
            )
            if aux is not None:
                lb = lb + aux.load_balance_loss
                zl = zl + aux.router_z_loss
                load = load + aux.expert_load
        if cfg.is_hybrid:
            x, _ = _apply_block(
                _shared, "dense", x, cfg, positions, use_kernel=use_kernel
            )
        return x, (lb, zl, load)

    body = _remat_wrap(super_block, cfg)

    def scan_body(carry, block_params):
        x, lb_acc, zl_acc = carry
        x, (lb, zl, load) = body(x, block_params)
        return (x, lb_acc + lb, zl_acc + zl), load

    (x, lb, zl), loads = jax.lax.scan(
        scan_body, (x, jnp.float32(0.0), jnp.float32(0.0)),
        tuple(params["blocks"]),
    )
    aux = moe.MoEAux(
        load_balance_loss=lb, router_z_loss=zl,
        expert_load=jnp.mean(loads, axis=0),
    )

    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(head, x)
    logits = sharding.constrain(logits, "batch", None, "vocab")

    caches = None
    if return_caches:
        caches = prefill_caches(params, tokens, cfg, smax, embeds=embeds)
    return ForwardOut(logits=logits, aux=aux, caches=caches)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def cross_entropy(logits: Array, labels: Array, mask: Array) -> Array:
    """logits (B,S,V) fp32, labels (B,S) int32, mask (B,S) {0,1}."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    *,
    use_kernel: bool = False,
    lb_coef: float = 0.01,
    z_coef: float = 1e-3,
) -> tuple[Array, dict]:
    out = forward(
        params, batch["tokens"], cfg,
        embeds=batch.get("embeds"), use_kernel=use_kernel,
    )
    ce = cross_entropy(out.logits, batch["labels"], batch["mask"])
    loss = ce
    metrics = {"ce": ce}
    if cfg.is_moe:
        loss = loss + lb_coef * out.aux.load_balance_loss \
            + z_coef * out.aux.router_z_loss
        metrics["lb_loss"] = out.aux.load_balance_loss
        metrics["z_loss"] = out.aux.router_z_loss
    metrics["loss"] = loss
    return loss, metrics


# --------------------------------------------------------------------------
# Decode: per-kind caches threaded through the layer scan
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeState:
    """Stacked per-pattern-position caches + shared-block caches."""

    caches: list[Any]            # caches[j]: stacked (n_super, ...) per kind
    shared_kv: Optional[KVCache]  # (n_super, ...) for the hybrid shared block
    length: Array                 # (B,) tokens decoded so far

    def tree_flatten(self):
        return (self.caches, self.shared_kv, self.length), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    DecodeState,
    lambda s: s.tree_flatten(),
    DecodeState.tree_unflatten,
)


def init_decode_state(batch: int, max_len: int, cfg: ModelConfig) -> DecodeState:
    pattern, n_super = layer_pattern(cfg)
    dtype = ACT_DTYPE
    if cfg.sliding_window is not None:  # ring cache: O(window) not O(context)
        max_len = min(max_len, cfg.sliding_window)

    def stack(make_one):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), make_one
        )

    caches = []
    for kind in pattern:
        if kind in ("dense", "moe"):
            one = KVCache(
                k=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                v=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                length=jnp.zeros((batch,), jnp.int32),
            )
        elif kind == "mamba1":
            one = mamba.init_mamba1_state(batch, cfg, dtype)
        else:
            one = mamba.init_mamba2_state(batch, cfg, dtype)
        caches.append(stack(one))

    shared_kv = None
    if cfg.is_hybrid:
        shared_kv = stack(KVCache(
            k=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            length=jnp.zeros((batch,), jnp.int32),
        ))
    return DecodeState(
        caches=caches, shared_kv=shared_kv,
        length=jnp.zeros((batch,), jnp.int32),
    )


def decode_state_specs(cfg: ModelConfig) -> DecodeState:
    """Logical PartitionSpecs matching init_decode_state's structure."""
    pattern, _ = layer_pattern(cfg)
    # kv claims `model` when head count divides; otherwise kv_seq shards the
    # cache's sequence dim over `model` (partial-softmax decode) — resolved
    # by the priority/conflict rules in dist.sharding.logical_to_mesh.
    kv_spec = KVCache(
        k=P(None, "batch", "kv_seq", "kv", None),
        v=P(None, "batch", "kv_seq", "kv", None),
        length=P(None, "batch"),
    )
    caches = []
    for kind in pattern:
        if kind in ("dense", "moe"):
            caches.append(kv_spec)
        elif kind == "mamba1":
            caches.append(mamba.Mamba1State(
                conv=P(None, "batch", None, "mlp"),
                ssm=P(None, "batch", "mlp", None),
            ))
        else:
            caches.append(mamba.Mamba2State(
                conv=P(None, "batch", None, None),
                ssm=P(None, "batch", None, None, None),
            ))
    return DecodeState(
        caches=caches,
        shared_kv=kv_spec if cfg.is_hybrid else None,
        length=P("batch"),
    )


def _decode_block(p, kind: str, x, cfg, cache):
    if kind in ("dense", "moe"):
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        h, cache = attention.self_attention_decode(p["attn"], h, cfg, cache)
        x = x + h
        h = layers.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            h, _ = moe.apply_moe(p["moe"], h, cfg)
        else:
            h = layers.apply_mlp(p["mlp"], h, cfg.act)
        return x + h, cache
    h = layers.apply_norm(p["ln"], x, cfg.norm)
    if kind == "mamba1":
        h, cache = mamba.apply_mamba1_decode(p["mixer"], h, cfg, cache)
    else:
        h, cache = mamba.apply_mamba2_decode(p["mixer"], h, cfg, cache)
    return x + h, cache


def decode_step(
    params: dict, token: Array, state: DecodeState, cfg: ModelConfig
) -> tuple[Array, DecodeState]:
    """token: (B, 1) int32 -> (logits (B, 1, V), new state)."""
    pattern, n_super = layer_pattern(cfg)
    x = layers.embed(params["embed"], token, ACT_DTYPE)
    x = sharding.constrain(x, "batch", sharding.seq_axis(), "embed")
    shared = params.get("shared_attn")

    def scan_body(x, scanned):
        block_params, caches, shared_kv = scanned
        new_caches = []
        for j, kind in enumerate(pattern):
            x, c = _decode_block(block_params[j], kind, x, cfg, caches[j])
            new_caches.append(c)
        if cfg.is_hybrid:
            x, shared_kv = _decode_block(shared, "dense", x, cfg, shared_kv)
        return x, (tuple(new_caches), shared_kv)

    x, (new_caches, new_shared) = jax.lax.scan(
        scan_body, x,
        (tuple(params["blocks"]), tuple(state.caches), state.shared_kv),
    )
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(head, x)
    logits = sharding.constrain(logits, "batch", None, "vocab")
    return logits, DecodeState(
        caches=list(new_caches), shared_kv=new_shared,
        length=state.length + 1,
    )


# --------------------------------------------------------------------------
# Prefill: run the full sequence once, collecting per-layer caches
# --------------------------------------------------------------------------

def prefill_caches(
    params: dict, tokens: Array, cfg: ModelConfig, max_len: int,
    *, embeds: Optional[Array] = None,
) -> DecodeState:
    """Build a DecodeState holding the full-sequence KV / SSM states.

    Implemented as a literal re-run of the blocks collecting K/V (attention)
    or final states (SSM) — correctness-first; serving fuses this with
    `forward` via `return_caches`.
    """
    pattern, n_super = layer_pattern(cfg)
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens, ACT_DTYPE)
    if cfg.frontend and embeds is not None:
        prefix = frontends.apply_projector(
            params["projector"], embeds.astype(ACT_DTYPE), cfg
        )
        x = frontends.splice_prefix(x, prefix)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    shared = params.get("shared_attn")
    lens = jnp.full((b,), s, jnp.int32)

    def pad_kv(k, v):
        pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
        return KVCache(
            k=jnp.pad(k, pad), v=jnp.pad(v, pad), length=lens
        )

    def attn_block_with_cache(p, x, kind):
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        q, k, v = attention.qkv_project(p["attn"], h, cfg, positions)
        o = attention.attend(
            q, k, v, causal=True, window=cfg.sliding_window,
            logit_cap=cfg.attn_logit_softcap,
        )
        h = layers.matmul(o, p["attn"]["wo"], "bshk,hkd->bsd")
        x = x + h
        h2 = layers.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            h2, _ = moe.apply_moe(p["moe"], h2, cfg)
        else:
            h2 = layers.apply_mlp(p["mlp"], h2, cfg.act)
        return x + h2, pad_kv(k, v)

    def mamba_block_with_state(p, x, kind):
        h = layers.apply_norm(p["ln"], x, cfg.norm)
        if kind == "mamba1":
            y, st = _mamba1_with_state(p["mixer"], h, cfg)
        else:
            y, st = _mamba2_with_state(p["mixer"], h, cfg)
        return x + y, st

    def scan_body(x, block_params):
        new_caches = []
        for j, kind in enumerate(pattern):
            if kind in ("dense", "moe"):
                x, c = attn_block_with_cache(block_params[j], x, kind)
            else:
                x, c = mamba_block_with_state(block_params[j], x, kind)
            new_caches.append(c)
        shared_c = None
        if cfg.is_hybrid:
            x, shared_c = attn_block_with_cache(shared, x, "dense")
        return x, (tuple(new_caches), shared_c)

    _, (caches, shared_kv) = jax.lax.scan(
        scan_body, x, tuple(params["blocks"])
    )
    return DecodeState(caches=list(caches), shared_kv=shared_kv, length=lens)


def _mamba1_with_state(p, x, cfg):
    return mamba._mamba1_scan(p, x, cfg)


def _mamba2_with_state(p, x, cfg):
    return mamba._mamba2_scan(p, x, cfg)
