"""Attention: GQA + RoPE (partial/theta), causal / sliding-window / cross.

Three lowerings of the same math:
  * `attend_ref`      — pure-jnp O(S^2) reference (oracle for everything);
  * `attend`          — production path: chunked flash attention via the
                        Pallas kernel on TPU, jnp fallback elsewhere;
  * `attend_decode`   — single-query attention against a KV cache.

All paths take fp32 softmax, bf16 matmuls with fp32 accumulation, support
GQA head-repetition without materializing repeated KV, sliding windows
(h2o-danube), and logit soft-capping (grok-1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


# --------------------------------------------------------------------------
# RoPE (rotary position embeddings), partial-rotary capable
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2  # rotated dims, even
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: Array, positions: Array, fraction: float, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv, rot = rope_frequencies(d, fraction, theta)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def softcap(logits: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def make_attention(key, cfg: ModelConfig, dtype) -> dict:
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": layers.dense_init(kq, d, (d, cfg.n_heads, hd), dtype),
        "wk": layers.dense_init(kk, d, (d, cfg.n_kv_heads, hd), dtype),
        "wv": layers.dense_init(kv, d, (d, cfg.n_kv_heads, hd), dtype),
        "wo": layers.dense_init(ko, cfg.n_heads * hd, (cfg.n_heads, hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def attention_spec(cfg: ModelConfig) -> dict:
    s = {
        "wq": P("embed", "heads", None),
        "wk": P("embed", "kv", None),
        "wv": P("embed", "kv", None),
        "wo": P("heads", None, "embed"),
    }
    if cfg.qkv_bias:
        s["bq"] = P("heads", None)
        s["bk"] = P("kv", None)
        s["bv"] = P("kv", None)
    return s


def qkv_project(p, x: Array, cfg: ModelConfig, positions: Array):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
    q = layers.matmul(x, p["wq"], "bsd,dhk->bshk")
    k = layers.matmul(x, p["wk"], "bsd,dhk->bshk")
    v = layers.matmul(x, p["wv"], "bsd,dhk->bshk")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.rope_fraction > 0:
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


# --------------------------------------------------------------------------
# Reference attention (oracle)
# --------------------------------------------------------------------------

def attend_ref(
    q: Array, k: Array, v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int | Array = 0,
) -> Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D). Returns (B, Sq, H, D).

    `q_offset`: absolute position of q[0] relative to k[0] (decode: Sk-1).
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qf = q.reshape(b, sq, kvh, rep, d)
    logits = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qf, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(d).astype(jnp.float32)
    logits = softcap(logits, logit_cap)

    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(b, sq, h, d)


# --------------------------------------------------------------------------
# Production attention: flash kernel on TPU, jnp elsewhere
# --------------------------------------------------------------------------

def attend(
    q: Array, k: Array, v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    use_kernel: bool = False,
) -> Array:
    """Training/prefill attention. On TPU targets the Pallas flash kernel is
    used (`repro.kernels.flash_attn`); the default jnp path lowers to the
    same fused-softmax HLO that XLA:TPU pattern-matches into flash."""
    if use_kernel:
        from repro.kernels.flash_attn import ops as flash_ops

        return flash_ops.flash_attention(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap
        )
    return attend_ref(q, k, v, causal=causal, window=window, logit_cap=logit_cap)


def attend_decode(
    q: Array, k_cache: Array, v_cache: Array, cache_len: Array,
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> Array:
    """One-token decode: q (B, 1, H, D) vs cache (B, Smax, KV, D).

    `cache_len` (B,) int32 — number of valid cache entries (includes the
    token being decoded, already written at cache_len-1).
    """
    b, _, h, d = q.shape
    smax, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    qf = q.reshape(b, kvh, rep, d)
    logits = jnp.einsum(
        "bgrd,bkgd->bgrk", qf, k_cache, preferred_element_type=jnp.float32
    ) / jnp.sqrt(d).astype(jnp.float32)
    logits = softcap(logits, logit_cap)
    k_pos = jnp.arange(smax)[None, :]
    mask = k_pos < cache_len[:, None]
    if window is not None:
        mask &= k_pos >= (cache_len[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bgrk,bkgd->bgrd", probs, v_cache)
    return out.reshape(b, 1, h, d)


# --------------------------------------------------------------------------
# Full block-level entry points
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array        # (B, Smax, KV, D)
    v: Array
    length: Array   # (B,) valid entries


def self_attention(
    p, x: Array, cfg: ModelConfig, positions: Array, *, use_kernel: bool = False
) -> Array:
    q, k, v = qkv_project(p, x, cfg, positions)
    o = attend(
        q, k, v,
        causal=True,
        window=cfg.sliding_window,
        logit_cap=cfg.attn_logit_softcap,
        use_kernel=use_kernel,
    )
    return layers.matmul(o, p["wo"], "bshk,hkd->bsd")


def self_attention_decode(
    p, x: Array, cfg: ModelConfig, cache: KVCache
) -> tuple[Array, KVCache]:
    """x: (B, 1, D). Appends to the cache then attends.

    Sliding-window archs use a RING cache of size `window`: the write slot
    wraps (`length % Smax`), all resident entries are in-window by
    construction, and RoPE is applied with absolute positions at write time
    so dot products stay relative-position-correct.  This is what keeps the
    long_500k decode cell O(window) instead of O(context) in HBM.
    """
    positions = cache.length[:, None]  # absolute position of the new token
    q, k, v = qkv_project(p, x, cfg, positions)
    b = x.shape[0]
    smax = cache.k.shape[1]
    ring = cfg.sliding_window is not None and smax <= cfg.sliding_window
    idx = cache.length % smax if ring else cache.length
    k_cache = cache.k.at[jnp.arange(b), idx].set(k[:, 0])
    v_cache = cache.v.at[jnp.arange(b), idx].set(v[:, 0])
    new_len = cache.length + 1
    if ring:
        valid = jnp.minimum(new_len, smax)
        o = attend_decode(
            q, k_cache, v_cache, valid,
            window=None,  # residency == window by construction
            logit_cap=cfg.attn_logit_softcap,
        )
    else:
        o = attend_decode(
            q, k_cache, v_cache, new_len,
            window=cfg.sliding_window,
            logit_cap=cfg.attn_logit_softcap,
        )
    out = layers.matmul(o, p["wo"], "bshk,hkd->bsd")
    return out, KVCache(k=k_cache, v=v_cache, length=new_len)


def cross_attention(
    p, x: Array, enc_kv: tuple[Array, Array], cfg: ModelConfig
) -> Array:
    """Decoder cross-attention over precomputed encoder K/V (seamless-m4t)."""
    q = layers.matmul(x, p["wq"], "bsd,dhk->bshk")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    k, v = enc_kv
    o = attend_ref(q, k, v, causal=False)
    return layers.matmul(o, p["wo"], "bshk,hkd->bsd")


def encode_kv(p, enc_out: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    k = layers.matmul(enc_out, p["wk"], "bsd,dhk->bshk")
    v = layers.matmul(enc_out, p["wv"], "bsd,dhk->bshk")
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return k, v
