"""Unified model configuration covering all ten assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                    # query heads (0 for attention-free)
    n_kv_heads: int                 # GQA KV heads
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- attention ---
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0      # partial rotary (glm4: 0.5, stablelm2: 0.25)
    sliding_window: Optional[int] = None  # SWA (h2o-danube)
    attn_logit_softcap: Optional[float] = None  # grok-1: 30.0
    qkv_bias: bool = False          # glm4 / stablelm2 use qkv bias

    # --- block layout ---
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"               # gated mlp activation: silu | gelu
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0       # top-k
    moe_layer_period: int = 1       # every k-th layer is MoE (llama4: 2)
    n_shared_experts: int = 0       # llama4: 1 shared expert
    capacity_factor: float = 1.25
    moe_groups: int = 0             # dispatch groups (0 = auto from sharding)

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_variant: str = "mamba1"     # mamba1 | mamba2
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64          # mamba2 head dim
    ssm_chunk: int = 256            # chunked-scan block length

    # --- hybrid (zamba2): shared attn+mlp block every k ssm layers ---
    shared_attn_period: int = 0

    # --- encoder-decoder (seamless-m4t) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontend stub ---
    frontend: Optional[str] = None  # vision | audio
    frontend_dim: int = 0           # dim of the precomputed patch/frame embeds
    frontend_len: int = 0           # number of prefix embeddings

    # --- numerics / memory ---
    param_dtype: str = "bfloat16"   # storage dtype of the weights
    optimizer_dtype: str = "float32"  # adam moment dtype (bf16 for 300B+ MoE)
    remat: str = "full"             # none | full | dots (activation ckpt policy)

    def __post_init__(self):
        if self.n_heads and self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and self.shared_attn_period == 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.shared_attn_period > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def moe_layer_mask(self) -> tuple[bool, ...]:
        """Which layers carry experts (True) vs a dense MLP."""
        if not self.is_moe:
            return tuple(False for _ in range(self.n_layers))
        # llama4-style interleave: layers (period-1, 2*period-1, ...) are MoE
        return tuple(
            (i % self.moe_layer_period) == self.moe_layer_period - 1
            for i in range(self.n_layers)
        )
