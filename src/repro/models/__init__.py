from repro.models.config import ModelConfig
from repro.models import lm

__all__ = ["ModelConfig", "lm"]
