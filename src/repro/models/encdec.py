"""Encoder-decoder backbone (seamless-m4t-large-v2).

Speech frontend is a stub (precomputed frame embeddings -> linear projector,
`frontends.py`); the assigned backbone is the 24L encoder + 24L decoder
transformer.  Encoder blocks are bidirectional self-attention; decoder blocks
are causal self-attention + cross-attention + MLP.  Decode threads a
self-attention KV cache and *precomputed* cross-attention K/V (computed once
per sequence at prefill — the standard enc-dec serving structure).

Positions use RoPE to match the repo-wide attention stack (the published
model uses relative position bias; recorded as a backbone deviation in
DESIGN.md — it does not change shapes, sharding, or FLOPs).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding
from repro.models import attention, frontends, layers
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.lm import ACT_DTYPE

Array = jax.Array


def _make_enc_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.make_norm(cfg.d_model, cfg.norm),
        "ln2": layers.make_norm(cfg.d_model, cfg.norm),
        "attn": attention.make_attention(k1, cfg, dtype),
        "mlp": layers.make_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _enc_block_spec(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.norm_spec(cfg.norm),
        "ln2": layers.norm_spec(cfg.norm),
        "attn": attention.attention_spec(cfg),
        "mlp": layers.mlp_spec(),
    }


def _make_dec_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.make_norm(cfg.d_model, cfg.norm),
        "ln2": layers.make_norm(cfg.d_model, cfg.norm),
        "ln3": layers.make_norm(cfg.d_model, cfg.norm),
        "attn": attention.make_attention(k1, cfg, dtype),
        "cross": attention.make_attention(k2, cfg, dtype),
        "mlp": layers.make_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_spec(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.norm_spec(cfg.norm),
        "ln2": layers.norm_spec(cfg.norm),
        "ln3": layers.norm_spec(cfg.norm),
        "attn": attention.attention_spec(cfg),
        "cross": attention.attention_spec(cfg),
        "mlp": layers.mlp_spec(),
    }


def make_encdec(key, cfg: ModelConfig) -> tuple[dict, dict]:
    from repro.models.lm import param_dtype

    dtype = param_dtype(cfg)
    k_emb, k_enc, k_dec, k_front, k_head = jax.random.split(key, 5)

    def stack(k, n, make_fn):
        return jax.vmap(make_fn)(jax.random.split(k, n))

    params = {
        "embed": layers.make_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "projector": frontends.make_projector(k_front, cfg, dtype),
        "enc_blocks": stack(
            k_enc, cfg.n_encoder_layers, lambda k: _make_enc_block(k, cfg, dtype)
        ),
        "enc_norm": layers.make_norm(cfg.d_model, cfg.norm),
        "dec_blocks": stack(
            k_dec, cfg.n_layers, lambda k: _make_dec_block(k, cfg, dtype)
        ),
        "final_norm": layers.make_norm(cfg.d_model, cfg.norm),
    }
    specs = {
        "embed": layers.embedding_spec(),
        "projector": frontends.projector_spec(cfg),
        "enc_blocks": jax.tree.map(
            lambda s: P(None, *s), _enc_block_spec(cfg),
            is_leaf=lambda s: isinstance(s, P),
        ),
        "enc_norm": layers.norm_spec(cfg.norm),
        "dec_blocks": jax.tree.map(
            lambda s: P(None, *s), _dec_block_spec(cfg),
            is_leaf=lambda s: isinstance(s, P),
        ),
        "final_norm": layers.norm_spec(cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {
            "table": layers.truncated_normal(
                k_head, (cfg.vocab_size, cfg.d_model), cfg.d_model ** -0.5, dtype
            )
        }
        specs["unembed"] = layers.embedding_spec()
    return params, specs


# --------------------------------------------------------------------------
# Encoder
# --------------------------------------------------------------------------

def encode(
    params: dict, embeds: Array, cfg: ModelConfig, *, use_kernel: bool = False
) -> Array:
    """embeds: (B, F, frontend_dim) -> encoder output (B, F, D)."""
    x = frontends.apply_projector(
        params["projector"], embeds.astype(ACT_DTYPE), cfg
    )
    x = sharding.constrain(x, "batch", sharding.seq_axis(), "embed")
    b, f = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(f)[None, :], (b, f))

    def body(x, p):
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        q, k, v = attention.qkv_project(p["attn"], h, cfg, positions)
        o = attention.attend(
            q, k, v, causal=False, use_kernel=use_kernel,
            logit_cap=cfg.attn_logit_softcap,
        )
        h = layers.matmul(o, p["attn"]["wo"], "bshk,hkd->bsd")
        x = sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed")
        h = layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln2"], x, cfg.norm),
                             cfg.act)
        return sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed"), None

    from repro.models.lm import _remat_wrap

    wrapped = _remat_wrap(lambda x, p: body(x, p)[0], cfg)
    x, _ = jax.lax.scan(lambda c, p: (wrapped(c, p), None), x,
                        params["enc_blocks"])
    return layers.apply_norm(params["enc_norm"], x, cfg.norm)


# --------------------------------------------------------------------------
# Decoder (teacher-forced training forward)
# --------------------------------------------------------------------------

def forward(
    params: dict,
    tokens: Array,
    embeds: Array,
    cfg: ModelConfig,
    *,
    use_kernel: bool = False,
    enc_out: Optional[Array] = None,
) -> Array:
    """tokens: (B, S) decoder input; embeds: (B, F, fd) frames -> logits."""
    if enc_out is None:
        enc_out = encode(params, embeds, cfg, use_kernel=use_kernel)
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens, ACT_DTYPE)
    x = sharding.constrain(x, "batch", sharding.seq_axis(), "embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, p):
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        h = attention.self_attention(p["attn"], h, cfg, positions,
                                     use_kernel=use_kernel)
        x = sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed")
        h = layers.apply_norm(p["ln2"], x, cfg.norm)
        kv = attention.encode_kv(p["cross"], enc_out, cfg)
        h = attention.cross_attention(p["cross"], h, kv, cfg)
        x = sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed")
        h = layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln3"], x, cfg.norm),
                             cfg.act)
        return sharding.constrain(x + h, "batch", sharding.seq_axis(), "embed")

    from repro.models.lm import _remat_wrap

    wrapped = _remat_wrap(body, cfg)
    x, _ = jax.lax.scan(lambda c, p: (wrapped(c, p), None), x,
                        params["dec_blocks"])
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(head, x)
    return sharding.constrain(logits, "batch", None, "vocab")


def encdec_loss(params, batch, cfg: ModelConfig, *, use_kernel=False):
    logits = forward(params, batch["tokens"], batch["embeds"], cfg,
                     use_kernel=use_kernel)
    from repro.models.lm import cross_entropy

    ce = cross_entropy(logits, batch["labels"], batch["mask"])
    return ce, {"ce": ce, "loss": ce}


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

class EncDecState(NamedTuple):
    self_kv: KVCache      # stacked (L, B, Smax, KV, D)
    cross_k: Array        # (L, B, F, KV, D) — precomputed at prefill
    cross_v: Array
    length: Array         # (B,)


def init_encdec_state(
    params: dict, embeds: Array, cfg: ModelConfig, max_len: int
) -> EncDecState:
    """Run the encoder once and precompute per-layer cross K/V."""
    enc_out = encode(params, embeds, cfg)
    b = enc_out.shape[0]

    def layer_kv(p):
        return attention.encode_kv(p["cross"], enc_out, cfg)

    ck, cv = jax.vmap(layer_kv)(params["dec_blocks"])
    kv = KVCache(
        k=jnp.zeros(
            (cfg.n_layers, b, max_len, cfg.n_kv_heads, cfg.head_dim), ACT_DTYPE
        ),
        v=jnp.zeros(
            (cfg.n_layers, b, max_len, cfg.n_kv_heads, cfg.head_dim), ACT_DTYPE
        ),
        length=jnp.zeros((cfg.n_layers, b), jnp.int32),
    )
    return EncDecState(
        self_kv=kv, cross_k=ck, cross_v=cv,
        length=jnp.zeros((b,), jnp.int32),
    )


def encdec_state_specs(cfg: ModelConfig) -> EncDecState:
    return EncDecState(
        self_kv=KVCache(
            k=P(None, "batch", None, "kv", None),
            v=P(None, "batch", None, "kv", None),
            length=P(None, "batch"),
        ),
        cross_k=P(None, "batch", None, "kv", None),
        cross_v=P(None, "batch", None, "kv", None),
        length=P("batch"),
    )


def decode_step(
    params: dict, token: Array, state: EncDecState, cfg: ModelConfig
) -> tuple[Array, EncDecState]:
    x = layers.embed(params["embed"], token, ACT_DTYPE)
    x = sharding.constrain(x, "batch", sharding.seq_axis(), "embed")

    def body(x, scanned):
        p, kv, ck, cv = scanned
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        h, kv = attention.self_attention_decode(p["attn"], h, cfg, kv)
        x = x + h
        h = layers.apply_norm(p["ln2"], x, cfg.norm)
        h = attention.cross_attention(p["cross"], h, (ck, cv), cfg)
        x = x + h
        h = layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln3"], x, cfg.norm),
                             cfg.act)
        return x + h, kv

    x, new_kv = jax.lax.scan(
        body, x,
        (params["dec_blocks"], state.self_kv, state.cross_k, state.cross_v),
    )
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(head, x)
    logits = sharding.constrain(logits, "batch", None, "vocab")
    return logits, EncDecState(
        self_kv=new_kv, cross_k=state.cross_k, cross_v=state.cross_v,
        length=state.length + 1,
    )
