"""Modality frontend STUBS (per assignment: [audio]/[vlm] backbones only).

The ViT / speech encoder themselves are out of scope — `input_specs()`
supplies *precomputed* patch/frame embeddings of shape
(batch, frontend_len, frontend_dim).  What IS part of the assigned backbone
is the learned projector that maps frontend embeddings into the LM embedding
space (internvl2: 2-layer MLP projector; seamless: linear frame projector),
so that is implemented and trained.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


def make_projector(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    fd, d = cfg.frontend_dim, cfg.d_model
    if cfg.frontend == "vision":  # internvl2: norm + 2-layer GELU MLP
        return {
            "norm": layers.make_norm(fd, "layernorm"),
            "w1": layers.dense_init(k1, fd, (fd, d), dtype),
            "b1": jnp.zeros((d,), dtype),
            "w2": layers.dense_init(k2, d, (d, d), dtype),
            "b2": jnp.zeros((d,), dtype),
        }
    # audio (seamless): single linear projection of fbank-frame features
    return {
        "w1": layers.dense_init(k1, fd, (fd, d), dtype),
        "b1": jnp.zeros((d,), dtype),
    }


def projector_spec(cfg: ModelConfig) -> dict:
    if cfg.frontend == "vision":
        return {
            "norm": layers.norm_spec("layernorm"),
            "w1": P(None, "embed"),
            "b1": P("embed"),
            "w2": P("embed", "embed"),
            "b2": P("embed"),
        }
    return {"w1": P(None, "embed"), "b1": P("embed")}


def apply_projector(p, embeds: Array, cfg: ModelConfig) -> Array:
    """embeds: (B, F, frontend_dim) -> (B, F, d_model)."""
    x = embeds
    if cfg.frontend == "vision":
        x = layers.apply_norm(p["norm"], x, "layernorm")
        x = layers.matmul(x, p["w1"]) + p["b1"].astype(x.dtype)
        x = jax.nn.gelu(x)
        x = layers.matmul(x, p["w2"]) + p["b2"].astype(x.dtype)
        return x
    return layers.matmul(x, p["w1"]) + p["b1"].astype(x.dtype)


def splice_prefix(token_embeds: Array, prefix: Array) -> Array:
    """Replace the first F positions of the token embedding stream with the
    projected modality prefix (the stub contract used by input_specs)."""
    f = prefix.shape[1]
    return jnp.concatenate([prefix, token_embeds[:, f:]], axis=1)
