"""Mixture-of-Experts layer: top-k router + capacity-based dense dispatch.

Covers the two assigned MoE archs:
  * llama4-maverick — 128 experts, top-1, + 1 shared expert, MoE every 2nd layer
  * grok-1          — 8 experts, top-2

Dispatch is the dense einsum formulation (combine/dispatch one-hot tensors):
it is deterministic-shape (capacity-bounded), EP-shardable along the expert
axis via the logical "expert" rule, and lowers to all-to-all when experts are
sharded.  Aux losses: load-balancing (Switch-style) + router z-loss.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


class MoEAux(NamedTuple):
    load_balance_loss: Array
    router_z_loss: Array
    expert_load: Array  # (E,) fraction of tokens routed per expert


def make_moe(key, cfg: ModelConfig, dtype) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": layers.dense_init(kr, d, (d, e), jnp.float32),
        "wi": layers.dense_init(k1, d, (e, d, f), dtype),
        "wg": layers.dense_init(k2, d, (e, d, f), dtype),
        "wo": layers.dense_init(k3, f, (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.make_mlp(ks, d, f * cfg.n_shared_experts, dtype)
    return p


def moe_spec(cfg: ModelConfig) -> dict:
    s = {
        "router": P("embed", None),
        "wi": P("expert", "embed", "mlp"),
        "wg": P("expert", "embed", "mlp"),
        "wo": P("expert", "mlp", "embed"),
    }
    if cfg.n_shared_experts:
        s["shared"] = layers.mlp_spec()
    return s


DEFAULT_GROUP_TOKENS = 4096


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.n_experts_active * cfg.capacity_factor
              / cfg.n_experts)
    return max(cap, 1)


def n_groups(t: int, cfg: ModelConfig) -> int:
    """GShard-style dispatch groups: the one-hot dispatch einsum is
    O(T x E·cap x D) with cap ∝ T — QUADRATIC in tokens if done globally
    (a 1M-token grok prefill would cost 3e19 dispatch FLOPs, 100x the
    experts themselves).  Grouping tokens into ~4k-token dispatch groups
    bounds it to O(T x group x k·cf x D), the standard TPU formulation."""
    if cfg.moe_groups > 0:
        g = cfg.moe_groups
    else:
        g = max(t // DEFAULT_GROUP_TOKENS, 1)
    while t % g:
        g -= 1
    return g


def _moe_group(p, xt: Array, cfg: ModelConfig):
    """Capacity-bounded top-k dispatch within one token group.

    xt: (Tg, D) -> (out (Tg, D), f_e (E,), lb (), zl ())
    """
    e, k = cfg.n_experts, cfg.n_experts_active
    t = xt.shape[0]
    cap = _capacity(t, cfg)

    # --- router (fp32) ---
    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"],
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (Tg, k)
    if k > 1:  # renormalize top-k gates (grok-1 style)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- aux losses: E * sum_e(f_e * p_e) + router z-loss ---
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (Tg, k, E)
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(f_e * p_e)
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- capacity-bounded position assignment (slot-0 choices first) ---
    flat_e = expert_idx.T.reshape(-1)            # (k*Tg,) slot-major
    flat_g = gate_vals.T.reshape(-1)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)          # (kTg, E)
    pos_in_e = jnp.cumsum(oh, axis=0) * oh - 1
    pos = jnp.sum(pos_in_e * oh, axis=-1)                    # (kTg,)
    keep = pos < cap
    flat_g = jnp.where(keep, flat_g, 0.0)
    pos = jnp.where(keep, pos, cap)              # overflow -> dropped scatter

    # --- dispatch: (E, cap, D) expert inputs ---
    tok_ids = jnp.tile(jnp.arange(t), k)
    disp = jnp.zeros((e, cap + 1, t), dtype=xt.dtype)
    disp = disp.at[flat_e, pos, tok_ids].add(1.0)[:, :cap, :]  # (E, cap, Tg)
    expert_in = jnp.einsum("ect,td->ecd", disp, xt)

    # --- expert MLPs (batched einsum over E) ---
    g = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"].astype(xt.dtype),
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"].astype(xt.dtype),
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    a = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    expert_out = jnp.einsum("ecf,efd->ecd", a * u, p["wo"].astype(xt.dtype),
                            preferred_element_type=jnp.float32).astype(xt.dtype)

    # --- combine: weighted gather back to tokens ---
    comb = jnp.zeros((e, cap + 1, t), dtype=jnp.float32)
    comb = comb.at[flat_e, pos, tok_ids].add(flat_g)[:, :cap, :]
    out = jnp.einsum("ect,ecd->td", comb.astype(xt.dtype), expert_out)
    return out, f_e, lb, zl


def apply_moe(p, x: Array, cfg: ModelConfig) -> tuple[Array, MoEAux]:
    """x: (B, S, D) -> (B, S, D) + aux losses (grouped dispatch)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    g = n_groups(t, cfg)

    if g == 1:
        out, f_e, lb, zl = _moe_group(p, xt, cfg)
    else:
        xg = xt.reshape(g, t // g, d)
        out, f_e, lb, zl = jax.vmap(
            lambda xi: _moe_group(p, xi, cfg))(xg)
        out = out.reshape(t, d)
        f_e, lb, zl = jnp.mean(f_e, 0), jnp.mean(lb), jnp.mean(zl)

    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], xt, cfg.act)

    aux = MoEAux(load_balance_loss=lb, router_z_loss=zl, expert_load=f_e)
    return out.reshape(b, s, d), aux
