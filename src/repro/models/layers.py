"""Parameter-dict building blocks: norms, embeddings, gated MLPs.

Conventions
-----------
* Params are nested dicts of `jax.Array`; every creator takes an RNG key and
  returns (params, spec) where spec mirrors the structure with
  `jax.sharding.PartitionSpec` leaves using LOGICAL axis names — resolved to
  mesh axes by `repro.dist.sharding`.
* Weights are stored in `cfg.param_dtype`; matmuls run in bfloat16 with fp32
  accumulation (`preferred_element_type`), norms/softmax in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array

# Logical axis names (resolved in repro.dist.sharding.AXIS_RULES):
#   "batch"  -> ("pod", "data")     "vocab"  -> "model"
#   "embed"  -> None                "heads"  -> "model"
#   "mlp"    -> "model"             "kv"     -> "model" (when divisible)
#   "expert" -> "model" (EP)        "seq"    -> None (or "data" for long ctx)


def truncated_normal(key, shape, scale, dtype):
    """He-style init, fp32 draw then cast."""
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (x * scale).astype(dtype)


def dense_init(key, in_dim: int, shape, dtype) -> Array:
    return truncated_normal(key, shape, (1.0 / in_dim) ** 0.5, dtype)


def make_norm(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_spec(kind: str):
    if kind == "rmsnorm":
        return {"scale": P(None)}
    return {"scale": P(None), "bias": P(None)}


def apply_norm(p, x: Array, kind: str, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * rms * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def matmul(x: Array, w: Array, spec: str | None = None) -> Array:
    """bf16 matmul with fp32 accumulation over the last axis of x."""
    return jnp.einsum(
        spec or "...d,df->...f",
        x,
        w.astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------

def make_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, (d_model, d_ff), dtype),   # gate
        "wg": dense_init(k2, d_model, (d_model, d_ff), dtype),   # up
        "wo": dense_init(k3, d_ff, (d_ff, d_model), dtype),
    }


def mlp_spec() -> dict:
    return {
        "wi": P("embed", "mlp"),
        "wg": P("embed", "mlp"),
        "wo": P("mlp", "embed"),
    }


def apply_mlp(p, x: Array, act: str) -> Array:
    g = matmul(x, p["wi"])
    u = matmul(x, p["wg"])
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return matmul(a * u, p["wo"])


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def make_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embedding_spec() -> dict:
    return {"table": P("vocab", "embed")}


def embed(p, tokens: Array, dtype) -> Array:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def unembed(p, x: Array) -> Array:
    """Logits in fp32 (softmax stability); vocab dim stays sharded."""
    return jnp.einsum(
        "...d,vd->...v", x, p["table"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
