"""seamless-m4t-large-v2 [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (MHA kv=16) d_ff=8192 vocab=256206.  Speech frontend is a
STUB: input_specs() supplies precomputed 160-dim fbank-frame embeddings; the
linear frame projector IS part of the backbone.  [arXiv:2308.11596; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    is_encoder_decoder=True, n_encoder_layers=24,
    frontend="audio", frontend_dim=160, frontend_len=1536,
    norm="layernorm", act="gelu",
)

SMOKE = ModelConfig(
    name="seamless-m4t-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    is_encoder_decoder=True, n_encoder_layers=2,
    frontend="audio", frontend_dim=20, frontend_len=24,
    norm="layernorm", act="gelu",
)
