"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072; 8 experts top-2 every layer; 30.0 attention logit softcap.
bf16 optimizer moments (DESIGN.md §6).
[hf:xai-org/grok-1; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    n_experts=8, n_experts_active=2, moe_layer_period=1,
    attn_logit_softcap=30.0,
    norm="rmsnorm", act="gelu",
    optimizer_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="grok-1-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    n_experts=4, n_experts_active=2, moe_layer_period=1,
    attn_logit_softcap=30.0,
    norm="rmsnorm", act="gelu",
)
