"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560, ssm_state=64,
plus ONE shared attention+MLP block (32H kv=32, d_ff=10240) applied after
every 6 Mamba2 layers with reused weights (Zamba's defining trick).
vocab=32000.  [arXiv:2411.15242; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_variant="mamba2", ssm_expand=2,
    ssm_conv=4, ssm_head_dim=64, ssm_chunk=256,
    shared_attn_period=6,
    norm="rmsnorm", act="gelu",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    ssm_state=8, ssm_variant="mamba2", ssm_expand=2,
    ssm_conv=4, ssm_head_dim=16, ssm_chunk=8,
    shared_attn_period=2,
    norm="rmsnorm", act="gelu",
)
