"""Architecture registry: one module per assigned arch.

Each module defines CONFIG (the exact published dims) and SMOKE (a reduced
same-family variant for CPU tests).  `get("glm4-9b")`, `smoke("glm4-9b")`.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "glm4-9b",
    "h2o-danube-1.8b",
    "llama3.2-3b",
    "stablelm-1.6b",
    "llama4-maverick-400b-a17b",
    "grok-1-314b",
    "seamless-m4t-large-v2",
    "zamba2-2.7b",
    "falcon-mamba-7b",
    "internvl2-2b",
)

_MOD = {
    "glm4-9b": "glm4_9b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "llama3.2-3b": "llama3_2_3b",
    "stablelm-1.6b": "stablelm_1_6b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "grok-1-314b": "grok_1_314b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-2.7b": "zamba2_2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-2b": "internvl2_2b",
}


def _module(arch: str):
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MOD)}")
    return importlib.import_module(f"repro.configs.{_MOD[arch]}")


def get(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE
