"""stablelm-1.6b [dense] — 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352.  Partial rotary (25%), LayerNorm, QKV bias.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab_size=100352,
    rope_theta=10_000.0, rope_fraction=0.25, qkv_bias=True,
    norm="layernorm", act="silu",
)

SMOKE = ModelConfig(
    name="stablelm-1.6b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    rope_fraction=0.25, qkv_bias=True,
    norm="layernorm", act="silu",
)
