"""internvl2-2b [vlm] — InternLM2-1.8B backbone: 24L d_model=2048 16H
(GQA kv=8) d_ff=8192 vocab=92553.  InternViT frontend is a STUB:
input_specs() supplies 256 precomputed 1024-dim patch embeddings; the
2-layer MLP projector IS part of the backbone.  [arXiv:2404.16821; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend="vision", frontend_dim=1024, frontend_len=256,
    norm="rmsnorm", act="silu",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    frontend="vision", frontend_dim=32, frontend_len=8,
    norm="rmsnorm", act="silu",
)
