"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE (partial, GLM uses half-rotary), GQA with 2 KV heads, QKV bias.
[hf:THUDM/glm-4-9b; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=151552,
    rope_theta=10_000.0, rope_fraction=0.5, qkv_bias=True,
    norm="rmsnorm", act="silu",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    rope_theta=10_000.0, rope_fraction=0.5, qkv_bias=True,
    norm="rmsnorm", act="silu",
)
