"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048; MoE 128 experts top-1 + 1 shared expert, MoE every
2nd layer (interleaved with dense).  bf16 optimizer moments to fit the
16 GB/chip x 512 envelope (documented in DESIGN.md §6).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    n_experts=128, n_experts_active=1, moe_layer_period=2,
    n_shared_experts=1, capacity_factor=1.25,
    rope_theta=500_000.0,
    norm="rmsnorm", act="silu",
    optimizer_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    n_experts=4, n_experts_active=1, moe_layer_period=2,
    n_shared_experts=1,
    norm="rmsnorm", act="silu",
)
