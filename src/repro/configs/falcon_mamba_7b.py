"""falcon-mamba-7b [ssm] — 64 Mamba1 layers, d_model=4096 (attn-free),
d_inner=8192, ssm_state=16, conv=4, vocab=65024.  [arXiv:2410.05355;
unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_variant="mamba1", ssm_expand=2,
    ssm_conv=4, ssm_chunk=256,
    norm="rmsnorm", act="silu",
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=256,
    ssm_state=8, ssm_variant="mamba1", ssm_expand=2,
    ssm_conv=4, ssm_chunk=8,
    norm="rmsnorm", act="silu",
)
