"""Predictor bank for the reconfiguration controller (DESIGN.md §12).

The paper's central claim is not "a KF can drive reconfiguration" but "a KF
predicts next-epoch demand *better than naive predictors*, so the network
reacts without thrashing".  Reproducing that claim needs the naive
predictors as first-class citizens of the same controller: this module
generalizes the epoch-boundary step

    counters -> normalize -> Kalman step -> binarize -> hysteresis machine

into a *bank* of predictors sharing one traced program.  Which predictor
drives the hysteresis machine is selected by a traced tensor
(`PredictorPolicy.kind`), never a Python branch, so the whole ablation grid
(predictor x scenario x workload x seed) batches into the simulator's ONE
compiled program (`sim.trace_count() == 1`) and the default KF path stays
bitwise-identical to `tests/golden_cycle_engine.json`.

Predictor kinds (paper Fig. 9/10 ablation axis):

  * ``kf``         — the paper's filter: scalar-state KF over the 3
                     normalized NoC observations; the signal binarizes the
                     one-step prediction `A x_k` (== the posterior for the
                     paper's random-walk A = I, bitwise).
  * ``ema``        — exponential moving average of the mean observation
                     with traced smoothing factor α.
  * ``last``       — last-value predictor: next epoch == this epoch's mean
                     observation (the "naive" baseline of the paper's
                     comparison).
  * ``always_on``  — constant boost request (upper envelope of reactive
                     boosting; the hysteresis revert rule still cycles it).
  * ``always_off`` — never request a boost (== the static fair split).

Every predictor's state advances every epoch regardless of `kind` (the
selection applies only to the emitted signal), which is what keeps the
program branch-free; the extra EMA arithmetic is two fused scalar ops per
epoch — noise next to the cycle scan.

Since the placement layer (DESIGN.md §17) the emitted signal drives up to
TWO levers: the VC bandwidth boost (`ModePolicy.bw_enable`) and compute
relocation (`ModePolicy.place_enable` selecting the placement stream's
boosted class plan).  The bank is lever-agnostic — it predicts demand;
which levers the prediction pulls is the allocator's `control` setting.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kalman

Array = jax.Array

# Predictor-kind encoding for the traced selector.  Order is load-bearing:
# `step` stacks the candidate signals in this order and `jnp.take`s by kind.
KF = 0
EMA = 1
LAST = 2
ALWAYS_ON = 3
ALWAYS_OFF = 4

PREDICTORS: dict[str, int] = {
    "kf": KF,
    "ema": EMA,
    "last": LAST,
    "always_on": ALWAYS_ON,
    "always_off": ALWAYS_OFF,
}


class PredictorPolicy(NamedTuple):
    """Traced predictor selection: which bank member drives the hysteresis
    machine, plus the naive predictors' parameters.

    Leaves may carry a leading batch dimension when stacked for
    `sim.simulate_batch` (exactly like `allocator.ModePolicy`, which embeds
    one of these).
    """

    kind: Array            # () int32 in [0, 5) — see PREDICTORS
    ema_alpha: Array       # () float32 — EMA smoothing factor
    threshold: Array       # () float32 — binarization threshold (paper: 0.0)
    guard: Array           # () bool — self-healing gate armed (DESIGN.md §16)
    nis_threshold: Array   # () float32 — innovation-gate NIS reject level
    watchdog_limit: Array  # () int32 — consecutive rejects before unhealthy
    cov_limit: Array       # () float32 — tr(P) divergence-watchdog ceiling


def predictor_policy(
    name: str = "kf",
    ema_alpha: float = 0.5,
    threshold: float = 0.0,
    guard: bool = False,
    nis_threshold: float = 50.0,
    watchdog_limit: int = 3,
    cov_limit: float = 1e4,
) -> PredictorPolicy:
    """Build the traced selector for one predictor by name.

    `guard=True` arms the self-healing layer (innovation gate + divergence
    watchdog + covariance reset); with the default `guard=False` every
    gated `where` selects the unguarded value, so the emitted state and
    signal are bitwise those of the pre-guard implementation.
    """
    if name not in PREDICTORS:
        raise ValueError(
            f"unknown predictor {name!r}; expected one of {sorted(PREDICTORS)}"
        )
    if not 0.0 < ema_alpha <= 1.0:
        raise ValueError(f"ema_alpha={ema_alpha} outside (0, 1]")
    if nis_threshold <= 0.0:
        raise ValueError(f"nis_threshold={nis_threshold} must be positive")
    if watchdog_limit < 1:
        raise ValueError(f"watchdog_limit={watchdog_limit} must be >= 1")
    if cov_limit <= 0.0:
        raise ValueError(f"cov_limit={cov_limit} must be positive")
    return PredictorPolicy(
        kind=jnp.int32(PREDICTORS[name]),
        ema_alpha=jnp.float32(ema_alpha),
        threshold=jnp.float32(threshold),
        guard=jnp.asarray(bool(guard)),
        nis_threshold=jnp.float32(nis_threshold),
        watchdog_limit=jnp.int32(watchdog_limit),
        cov_limit=jnp.float32(cov_limit),
    )


class PredictorState(NamedTuple):
    """Carry for the whole bank: every member's state advances each epoch."""

    kf: kalman.KalmanState  # x (1,), p (1, 1)
    ema: Array              # () float32 — EMA of the mean observation
    reject_run: Array       # () int32 — consecutive innovation-gate rejects
    healthy: Array          # () bool — watchdog verdict after this epoch;
    #                         the allocator's degraded-mode fallback reads it
    #                         (always True when the guard is disarmed)


def init_state(dtype=jnp.float32) -> PredictorState:
    """Zero state — the KF member is exactly `kalman.init_state(1)`."""
    return PredictorState(
        kf=kalman.init_state(1, dtype=dtype),
        ema=jnp.zeros((), dtype),
        reject_run=jnp.int32(0),
        healthy=jnp.asarray(True),
    )


class KFInternals(NamedTuple):
    """Flight-recorder view of one epoch-boundary filter step (obs probes,
    DESIGN.md §14): everything the paper's Fig. 4-style narrative needs to
    explain WHY the signal flipped."""

    innovation: Array  # (m,) z - H x^  — surprise vs the filter's forecast
    gain: Array        # (m,) Kalman gain row K[0] that weighted it
    cov_trace: Array   # () tr(P_k) — posterior uncertainty
    x_pred: Array      # () one-step demand prediction A x_k (the signal's
                       #    pre-binarization value for the KF member)
    nis: Array         # () normalized innovation squared of the epoch
    rejected: Array    # () int32 {0,1} — innovation gate coasted this epoch
    reset: Array       # () int32 {0,1} — covariance reset fired this epoch
    healthy: Array     # () int32 {0,1} — watchdog verdict (1 = healthy)


def step_probed(
    pp: PredictorPolicy,
    kf_params: kalman.KalmanParams,
    state: PredictorState,
    z: Array,
) -> tuple[PredictorState, Array, KFInternals]:
    """`step` plus the KF internals of the epoch (see KFInternals).

    The extra outputs are pure functions of values `step` already
    computes (the gain recomputation CSEs against the measurement
    update), so the (state, signal) pair is bitwise that of `step` —
    which is in fact implemented as this function minus the internals.

    Self-healing layer (DESIGN.md §16), armed by `pp.guard`:

      * innovation gate — an epoch whose observation is non-finite or
        whose NIS exceeds `pp.nis_threshold` is REJECTED: the filter
        coasts on the a-priori state (the time update still ran, so
        uncertainty keeps growing) instead of ingesting the corruption.
      * divergence watchdog — `pp.watchdog_limit` consecutive rejects,
        or a posterior covariance trace that is non-finite or above
        `pp.cov_limit`, marks the filter UNHEALTHY; the allocator reads
        `PredictorState.healthy` and falls back to the fair split.
      * covariance reset — on the epoch the reject run first hits the
        limit (or on a bad covariance), P snaps back to the init prior
        and any non-finite state components are zeroed, so the filter
        re-converges from scratch once observations clean up.

    Every guard effect routes through `jnp.where(pp.guard, ...)`: with
    the guard disarmed the emitted state, signal, and legacy internals
    are bitwise those of the unguarded step.
    """
    kf_post, kf_prior, innovation = kalman.step(kf_params, state.kf, z)
    zbar = jnp.mean(z)
    ema = pp.ema_alpha * zbar + (1.0 - pp.ema_alpha) * state.ema

    # --- innovation gate ---------------------------------------------------
    nis = kalman.innovation_nis(kf_params, kf_prior, z)
    z_finite = jnp.all(jnp.isfinite(z))
    # NaN NIS compares False against the threshold, hence the explicit
    # finiteness term: a NaN observation must always reject.
    reject = pp.guard & (~z_finite | (nis > pp.nis_threshold))
    kf_x = jnp.where(reject, kf_prior.x, kf_post.x)
    kf_p = jnp.where(reject, kf_prior.p, kf_post.p)

    # --- divergence watchdog + covariance reset ----------------------------
    reject_run = jnp.where(reject, state.reject_run + 1, jnp.int32(0))
    cov_tr = jnp.trace(kf_p)
    cov_bad = ~jnp.isfinite(cov_tr) | (cov_tr > pp.cov_limit)
    run_bad = reject_run >= pp.watchdog_limit
    do_reset = pp.guard & ((reject_run == pp.watchdog_limit) | cov_bad)
    n = kf_params.state_dim
    kf_x = jnp.where(
        do_reset, jnp.where(jnp.isfinite(kf_x), kf_x, 0.0), kf_x
    )
    kf_p = jnp.where(do_reset, jnp.eye(n, dtype=kf_p.dtype), kf_p)
    healthy = ~pp.guard | ~(run_bad | cov_bad)
    kf_state = kalman.KalmanState(x=kf_x, p=kf_p)

    x_pred = kalman.one_step_prediction(kf_params, kf_state)[0]
    sig_kf = kalman.binarize(x_pred, pp.threshold)
    sig_ema = kalman.binarize(ema, pp.threshold)
    sig_last = kalman.binarize(zbar, pp.threshold)
    candidates = jnp.stack(
        [sig_kf, sig_ema, sig_last, jnp.int32(1), jnp.int32(0)]
    )
    signal = jnp.take(candidates, pp.kind)
    internals = KFInternals(
        innovation=innovation,
        gain=kalman.kalman_gain(kf_params, kf_prior)[0],
        cov_trace=jnp.trace(kf_state.p),
        x_pred=x_pred,
        nis=nis,
        rejected=reject.astype(jnp.int32),
        reset=do_reset.astype(jnp.int32),
        healthy=healthy.astype(jnp.int32),
    )
    new_state = PredictorState(
        kf=kf_state, ema=ema, reject_run=reject_run, healthy=healthy
    )
    return new_state, signal, internals


def step(
    pp: PredictorPolicy,
    kf_params: kalman.KalmanParams,
    state: PredictorState,
    z: Array,
) -> tuple[PredictorState, Array]:
    """Advance the bank one epoch and emit the selected binary signal.

    z: (m,) normalized observations (the same vector the KF consumes).
    Returns (new_state, signal) with signal a () int32 in {0, 1}.

    Bitwise contract: with ``kind == KF`` the emitted signal is exactly the
    legacy `binarize(kalman.step(...).x[0])` — the one-step prediction
    `A x_k` equals the posterior elementwise for the paper's A = I, and the
    `jnp.take` selection is an identity on the chosen lane.
    """
    new_state, signal, _ = step_probed(pp, kf_params, state, z)
    return new_state, signal
