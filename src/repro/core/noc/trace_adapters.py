"""HLO-cost -> chiplet NoC demand adapter (DESIGN.md §15).

The first non-synthetic workload family: instead of Markov-modulated
Bernoulli stand-ins for ISPASS benchmarks, demand rows are derived from
what THIS repo's own models actually move through memory.  For each
serving phase we lower the real step function (`repro.launch.specs`
prefill/decode builders over `repro.models` architectures) with
`jax.jit(step).lower(...)` and read XLA's `cost_analysis()` (via
`repro.launch.hlo_cost.xla_cost_analysis`, which normalizes the
list-vs-dict drift across jax versions).  A phase's FLOPs and bytes-moved
then map to chiplet NoC injection through a roofline argument:

    cycles      = max(flops / peak_flops_per_cycle,
                      bytes / peak_hbm_bytes_per_cycle)
    bytes/cycle = bytes / cycles
    intensity   = (bytes/cycle) / peak_hbm_bytes_per_cycle   in (0, 1]
    gpu rate    = peak_rate * intensity        packets/node/cycle

so a memory-bound phase (decode: every token re-reads the weights and KV
cache) saturates the fabric at `peak_rate` — calibrated to the simulated
network's contention knee, the same ~0.38 regime the synthetic BFS bursts
hit — while a compute-bound phase (prefill: hundreds of tokens amortize
each weight read) injects at a small fraction of it.  ``sync`` epochs
(request-wave barriers / queue drains) carry zero GPU fabric demand; the
CPU class keeps its stable omnetpp-like 0.12 throughout.

Rows are emitted deterministic (``gpu_rate_lo == gpu_rate_hi``, burst
phase pinned low) so the replayed trace is a pure function of the HLO —
no Markov dynamics — and the result is packaged as a
`traffic.RecordedTrace`, making an LLM-serving demand stream a
first-class sweep workload via `traffic.register_workload`.

This module imports `repro.launch` / `repro.models` lazily inside the
phase builders: the core NoC package must stay importable without pulling
the model stack.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.noc.traffic import RecordedTrace, WorkloadProfile


@dataclasses.dataclass(frozen=True)
class ChipletRoofline:
    """The GPU chiplet's machine balance, in per-cycle units.

    Table-1-scale defaults: a 2-SM GPU chiplet sustains 256 MAC-flops per
    cycle; its share of MC ingress is one 64-byte line per cycle.  Machine
    balance is therefore 4 flops/byte — phases with lower arithmetic
    intensity are memory-bound and saturate the fabric.  ``peak_rate`` is
    the injection rate a fully memory-bound phase maps to: 0.38
    packets/node/cycle puts 14 GPU tiles at rho ~ 0.95 of the 8 pkt/cycle
    MC ingress, the queueing knee where VC allocation matters (the same
    regime the synthetic BFS bursts are tuned to).
    """

    peak_flops_per_cycle: float = 256.0
    peak_hbm_bytes_per_cycle: float = 64.0
    peak_rate: float = 0.38
    cpu_rate: float = 0.12

    def intensity(self, flops: float, bytes_moved: float) -> float:
        """Memory-boundedness of a phase in (0, 1]: bytes/cycle fraction."""
        if bytes_moved <= 0.0:
            return 0.0
        cycles = max(flops / self.peak_flops_per_cycle,
                     bytes_moved / self.peak_hbm_bytes_per_cycle)
        if cycles <= 0.0:
            return 0.0
        return (bytes_moved / cycles) / self.peak_hbm_bytes_per_cycle

    def gpu_rate(self, flops: float, bytes_moved: float) -> float:
        return self.peak_rate * self.intensity(flops, bytes_moved)


# The model the serving phases are lowered from: a small but real
# attention LM (repro.models.lm) so the CI adapter path stays cheap
# (lowering only — nothing executes) while the HLO still contains the
# full prefill/decode structure (QKV matmuls, KV-cache update, logits).
# d_model=768 puts prefill at arithmetic intensity ~22 flops/byte —
# compute-bound under the 4 flops/byte machine balance (intensity ~0.18,
# rate ~0.07: the calm regime) — while decode stays at ~0.7 flops/byte,
# fully memory-bound (rate = peak 0.38).  That contrast is the property
# the schedule geometry relies on, asserted by
# tests/test_traffic_source.py.
def _tiny_serving_config():
    from repro.models.config import ModelConfig

    return ModelConfig(name="noc-hlo-tiny", n_layers=2, d_model=768,
                       n_heads=8, n_kv_heads=4, d_ff=3072, vocab_size=512)


def step_cost(kind: str, cfg=None, *, seq: int = 256,
              batch: int = 4) -> dict:
    """FLOPs / bytes-moved of one real step, from XLA's cost model.

    kind — "prefill" (forward over `seq` prompt tokens) or "decode" (one
    new token against a `seq`-deep KV cache).  `cfg` defaults to the tiny
    serving config.  Nothing is executed: the step is lowered with
    abstract (ShapeDtypeStruct) inputs and costed symbolically.
    """
    import jax

    from repro.launch import specs
    from repro.launch.hlo_cost import xla_cost_analysis

    if cfg is None:
        cfg = _tiny_serving_config()
    cell = specs.ShapeCell(f"adapter_{kind}", seq, batch, kind)
    params = specs.abstract_params(cfg)
    if kind == "prefill":
        step = specs.make_prefill_step(cfg)
        lowered = jax.jit(step).lower(params, specs.batch_struct(cfg, cell))
    elif kind == "decode":
        step = specs.make_serve_step(cfg)
        token, state = specs.abstract_decode_inputs(cfg, cell)
        lowered = jax.jit(step).lower(params, token, state)
    else:
        raise ValueError(f"unknown phase kind {kind!r}; expected "
                         "'prefill' or 'decode'")
    cost = xla_cost_analysis(lowered)
    if not cost.get("flops") and not cost.get("bytes accessed"):
        # some jax versions only cost the compiled executable
        cost = xla_cost_analysis(lowered.compile())
    return {
        "kind": kind,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "seq": seq,
        "batch": batch,
        "model": cfg.name,
    }


# Default serving schedule: four request waves, each
# [prefill 12][decode 10][sync 2][decode 6] epochs — prompt ingestion
# (compute-bound, low fabric demand), a token-generation burst
# (memory-bound, saturating), an inter-wave barrier/queue drain, and the
# wave's decode tail.  120 epochs at the canonical run length; the arc
# shape matches the hysteresis-aware geometry the predictor gate is sized
# against (traffic.shift_scenario): the sync gap lands past the hold
# window, so reactive predictors un-boost on it and pay the lockout for
# the second decode burst while the KF's posterior rides the gap.
SERVE_SCHEDULE: tuple[tuple[str, int], ...] = (
    ("prefill", 12), ("decode", 10), ("sync", 2), ("decode", 6),
) * 4


def demand_from_costs(
    phase_costs: dict,
    schedule: tuple[tuple[str, int], ...] = SERVE_SCHEDULE,
    roofline: ChipletRoofline = ChipletRoofline(),
    name: str = "hlo_serve",
) -> RecordedTrace:
    """Assemble per-epoch demand rows from per-phase HLO costs.

    phase_costs — {phase_name: cost dict from `step_cost`}; the schedule
    may additionally reference the builtin zero-demand phase "sync".
    Rows are deterministic: rate_lo == rate_hi, Markov phase pinned low.
    """
    rates = {"sync": 0.0}
    for phase, cost in phase_costs.items():
        rates[phase] = roofline.gpu_rate(cost["flops"], cost["bytes"])
    n_epochs = sum(n for _, n in schedule)
    gpu = np.empty((n_epochs,), np.float32)
    pos = 0
    for phase, n in schedule:
        if phase not in rates:
            raise ValueError(
                f"schedule phase {phase!r} has no cost entry; have "
                f"{sorted(rates)}"
            )
        gpu[pos:pos + n] = rates[phase]
        pos += n
    rows = WorkloadProfile(
        gpu_rate_lo=gpu,
        gpu_rate_hi=gpu.copy(),
        p_enter=np.zeros((n_epochs,), np.float32),
        p_exit=np.ones((n_epochs,), np.float32),
        cpu_rate=np.full((n_epochs,), roofline.cpu_rate, np.float32),
    )
    meta = {
        "adapter": "hlo_cost",
        "roofline": dataclasses.asdict(roofline),
        "schedule": [[p, int(n)] for p, n in schedule],
        "phases": {
            p: dict(c, rate=float(rates[p]),
                    intensity=float(roofline.intensity(c["flops"],
                                                       c["bytes"])))
            for p, c in phase_costs.items()
        },
    }
    return RecordedTrace(demand=rows, fit="exact", name=name, meta=meta)


def hlo_serving_trace(
    cfg=None,
    schedule: tuple[tuple[str, int], ...] = SERVE_SCHEDULE,
    roofline: ChipletRoofline = ChipletRoofline(),
    *,
    seq: int = 256,
    prefill_batch: int = 2,
    decode_batch: int = 4,
    name: str = "hlo_serve",
) -> RecordedTrace:
    """The end-to-end adapter: lower this repo's own prefill/decode steps,
    cost them, and emit the serving-demand trace."""
    costs = {
        "prefill": step_cost("prefill", cfg, seq=seq, batch=prefill_batch),
        "decode": step_cost("decode", cfg, seq=seq, batch=decode_batch),
    }
    return demand_from_costs(costs, schedule, roofline, name=name)


def register_hlo_workload(name: str = "HLO_SERVE", overwrite: bool = False,
                          **kwargs) -> RecordedTrace:
    """Build the serving trace and register it as a named sweep workload."""
    from repro.core.noc.traffic import register_workload

    trace = hlo_serving_trace(name=name.lower(), **kwargs)
    register_workload(name, trace, overwrite=overwrite)
    return trace
