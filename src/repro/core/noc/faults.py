"""Traced fault-injection streams for the NoC simulator (DESIGN.md §16).

The paper's controller must "react in real-time" — which presupposes it
survives runtime disturbances: links flap, routers brown out, memory
controllers stall, and the telemetry the KF ingests can be corrupted.
This module models those disturbances as *data*, never as program
structure: a `FaultSchedule` (the fault-domain sibling of
`traffic.ScenarioSchedule`) materializes to a `FaultStream` — per-epoch
mask rows delivered to `sim._simulate_impl` through the epoch scan `xs`
exactly like the demand rows and RNG streams — so faulty and healthy
configurations share the simulator's ONE compiled program
(`sim.trace_count() == 1` is preserved; a healthy run threads the
identity stream from `healthy_stream`).

Fault semantics (consumed by `router.router_cycle` / the fused lane
kernel / the epoch-boundary KF step):

  * link    — `link_ok[e, r, p]` False suppresses grants through output
              port `p` of router `r`: the masked link is never granted,
              in-flight flits back-pressure in their VCs (they never
              vanish).  With a neighbor table, the reverse direction of
              each masked link is masked too (a dead link is dead both
              ways).
  * router  — `router_ok[e, r]` False suppresses EVERY grant at router
              `r` (a brownout: no traversal, no ejection); upstream
              credit stalls propagate the back-pressure.
  * mc      — `mc_ok[e, r]` False freezes MC service at router `r`:
              timers stop, the queue keeps filling until `mc_queue_cap`
              back-pressures the fabric.
  * telem   — `telem_mode[e]` corrupts the normalized observation vector
              BEFORE the predictor bank sees it: 1 drops it to the
              normalization floor (-1), 2 adds `telem_mag[e]` (a spike),
              3 replaces it with NaN.  Mode 0 selects the clean vector
              bit-for-bit, so a healthy epoch is value-identical to the
              pre-fault program.

Faults only ever SUPPRESS (masks are AND-ed into existing gates), never
enable — padded-lane garbage conventions in the lane engine stay safe by
construction.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc.topology import N_PORTS, PORT_E, PORT_L, PORT_N, PORT_S, PORT_W

Array = jax.Array

# default router count of the paper topology (6x6 mesh); callers with a
# custom topology pass n_routers/neighbor explicitly.
DEFAULT_R = 36

# telemetry-corruption modes (telem_mode values)
TELEM_OK, TELEM_DROP, TELEM_SPIKE, TELEM_NAN = range(4)

_KINDS = ("link", "router", "mc", "telem")
_NONLOCAL_PORTS = (PORT_N, PORT_E, PORT_S, PORT_W)


class FaultStream(NamedTuple):
    """Per-epoch fault masks (a JAX pytree; leading axis = n_epochs, E).

    Consumed by the epoch scan as `xs`: each epoch body receives one
    (R, P) link row, (R,) router/MC rows and the scalar telemetry mode.
    Leaves may carry an extra leading batch dimension when stacked for
    `sim.simulate_batch` (exactly like `traffic.WorkloadProfile`).
    """

    link_ok: Array     # (E, R, P) bool — grant allowed through port p
    router_ok: Array   # (E, R) bool — router grants anything at all
    mc_ok: Array       # (E, R) bool — MC service ticks
    telem_mode: Array  # (E,) int32 — TELEM_* corruption mode
    telem_mag: Array   # (E,) float32 — spike magnitude (mode TELEM_SPIKE)


class FaultEvent(NamedTuple):
    """One fault arc: governs epochs in [start, stop) (run fractions).

    kind     — "link" | "router" | "mc" | "telem".
    routers  — affected router ids (empty = every router) for the
               physical kinds; ignored for "telem".
    ports    — affected output ports for kind="link" (empty = all four
               mesh ports; the Local port is never maskable — ejection
               faults are router brownouts).
    period   — 0 = solid fault; > 0 = transient flapping: the fault is
               active for `period` epochs, then released for `period`,
               repeating across [start, stop).
    mode/mag — telemetry corruption mode and spike magnitude.
    """

    start: float
    stop: float
    kind: str
    routers: tuple[int, ...] = ()
    ports: tuple[int, ...] = ()
    period: int = 0
    mode: int = TELEM_DROP
    mag: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A piecewise fault program (sibling of `traffic.ScenarioSchedule`).

    ``materialize(n_epochs)`` lowers the schedule to a `FaultStream` with
    exact epoch boundaries: epoch ``e`` is inside an event iff
    ``round(start * n_epochs) <= e < round(stop * n_epochs)`` (and, for
    flapping events, the epoch falls in an active half-period).
    """

    events: tuple[FaultEvent, ...]

    def __post_init__(self):
        for ev in self.events:
            if ev.kind not in _KINDS:
                raise ValueError(
                    f"unknown fault kind {ev.kind!r}; expected one of {_KINDS}"
                )
            if not 0.0 <= ev.start < ev.stop <= 1.0:
                raise ValueError(
                    f"fault event window [{ev.start}, {ev.stop}) outside [0, 1]"
                )
            if ev.period < 0:
                raise ValueError(f"fault period {ev.period} must be >= 0")
            if ev.kind == "telem":
                if ev.mode not in (TELEM_DROP, TELEM_SPIKE, TELEM_NAN):
                    raise ValueError(
                        f"telem fault mode {ev.mode} not in "
                        f"{{TELEM_DROP, TELEM_SPIKE, TELEM_NAN}}"
                    )
            if ev.kind == "link":
                bad = [p for p in ev.ports if p not in _NONLOCAL_PORTS]
                if bad:
                    raise ValueError(
                        f"link fault ports {bad} invalid: only the four mesh "
                        f"ports {_NONLOCAL_PORTS} can be masked"
                    )

    def materialize(
        self,
        n_epochs: int,
        n_routers: int = DEFAULT_R,
        n_ports: int = N_PORTS,
        neighbor: np.ndarray | None = None,
        opposite: np.ndarray | None = None,
    ) -> FaultStream:
        link_ok = np.ones((n_epochs, n_routers, n_ports), bool)
        router_ok = np.ones((n_epochs, n_routers), bool)
        mc_ok = np.ones((n_epochs, n_routers), bool)
        telem_mode = np.zeros((n_epochs,), np.int32)
        telem_mag = np.zeros((n_epochs,), np.float32)

        for ev in self.events:
            lo = int(round(ev.start * n_epochs))
            hi = int(round(ev.stop * n_epochs))
            epochs = np.arange(lo, hi)
            if ev.period > 0:  # transient flap: period on, period off
                epochs = epochs[((epochs - lo) // ev.period) % 2 == 0]
            if epochs.size == 0:
                continue
            routers = (
                np.arange(n_routers)
                if not ev.routers
                else np.asarray(ev.routers, np.int64)
            )
            if routers.size and (routers.min() < 0 or routers.max() >= n_routers):
                raise ValueError(
                    f"fault routers {tuple(ev.routers)} outside [0, {n_routers})"
                )
            if ev.kind == "telem":
                telem_mode[epochs] = ev.mode
                telem_mag[epochs] = np.float32(ev.mag)
            elif ev.kind == "router":
                router_ok[np.ix_(epochs, routers)] = False
            elif ev.kind == "mc":
                mc_ok[np.ix_(epochs, routers)] = False
            else:  # link
                ports = ev.ports or _NONLOCAL_PORTS
                for p in ports:
                    link_ok[np.ix_(epochs, routers, [p])] = False
                    if neighbor is not None:
                        # a dead link is dead both ways: mask the reverse
                        # direction at each downstream neighbor too
                        opp = (
                            np.asarray(opposite)
                            if opposite is not None
                            else np.asarray([PORT_S, PORT_W, PORT_N, PORT_E,
                                             PORT_L])
                        )
                        for r in routers:
                            nb = int(np.asarray(neighbor)[r, p])
                            if nb >= 0:
                                link_ok[np.ix_(epochs, [nb], [int(opp[p])])] \
                                    = False
        return FaultStream(
            link_ok=jnp.asarray(link_ok),
            router_ok=jnp.asarray(router_ok),
            mc_ok=jnp.asarray(mc_ok),
            telem_mode=jnp.asarray(telem_mode),
            telem_mag=jnp.asarray(telem_mag),
        )


def healthy_stream(
    n_epochs: int, n_routers: int = DEFAULT_R, n_ports: int = N_PORTS
) -> FaultStream:
    """The identity fault stream: every mask passes, telemetry clean.

    This is what every healthy run threads through the epoch scan, which
    is what keeps faulty x healthy configurations on one compiled program
    — and, because every fault gate is an AND / a mode-0 `where`, the
    healthy program's VALUES are bit-for-bit the pre-fault program's.
    """
    return FaultSchedule(()).materialize(n_epochs, n_routers, n_ports)


# ---------------------------------------------------------------------------
# Fault scenario library + registry (the fault-domain SCENARIOS dict).
# Windows are phased against traffic.SCENARIOS["SHIFT_PATH_BFS"]'s four
# 30-epoch kernel arcs (PATH, PATH, BFS, BFS on the canonical 120 epochs).
# ---------------------------------------------------------------------------

FAULTS: dict[str, FaultSchedule] = {
    # transient link flaps on the links feeding top-row MCs 2 and 3
    # (routers 8/9 port N and the reverse direction), flapping in
    # 2-epoch bursts across the BFS half of the run.
    "FLAP_BFS": FaultSchedule((
        FaultEvent(0.55, 0.80, "link", routers=(8, 9), ports=(PORT_N,),
                   period=2),
    )),
    # a center-of-mesh router brownout during the second PATH burst: no
    # grants at routers 14/15/20/21 for ~12 epochs.
    "BROWNOUT": FaultSchedule((
        FaultEvent(0.30, 0.40, "router", routers=(14, 15, 20, 21)),
    )),
    # pure telemetry corruption, network healthy: NaNs across the shift
    # onto BFS, a +8 spike mid-burst, a dropped-to-floor window late.
    "TELEM_GLITCH": FaultSchedule((
        FaultEvent(0.50, 0.60, "telem", mode=TELEM_NAN),
        FaultEvent(0.70, 0.75, "telem", mode=TELEM_SPIKE, mag=8.0),
        FaultEvent(0.85, 0.90, "telem", mode=TELEM_DROP),
    )),
    # the compound case: link flaps spanning the PATH->BFS shift while
    # the telemetry NaNs out right at the shift point.
    "FLAP_DURING_SHIFT": FaultSchedule((
        FaultEvent(0.45, 0.65, "link", routers=(8, 9), ports=(PORT_N,),
                   period=3),
        FaultEvent(0.50, 0.55, "telem", mode=TELEM_NAN),
    )),
}


def register_faults(
    name: str, schedule: FaultSchedule, overwrite: bool = False
) -> None:
    """Register a named fault scenario (shares the `--faults` namespace)."""
    if not isinstance(schedule, FaultSchedule):
        raise TypeError(
            f"fault scenario {name!r} must be a FaultSchedule, got "
            f"{type(schedule).__name__}"
        )
    if not overwrite and name in FAULTS:
        raise ValueError(
            f"fault scenario {name!r} already exists; pass overwrite=True"
        )
    FAULTS[name] = schedule


def lookup_faults(name: str) -> FaultSchedule:
    if name in FAULTS:
        return FAULTS[name]
    near = difflib.get_close_matches(name, sorted(FAULTS), n=3, cutoff=0.4)
    hint = f"; did you mean {near}?" if near else ""
    raise ValueError(
        f"unknown fault scenario {name!r}{hint} "
        f"(known: {sorted(FAULTS)})"
    )


# The union accepted by resolve_faults: a scenario name, a schedule, a
# pre-materialized stream, or None (healthy).
FaultSourceLike = str | FaultSchedule | FaultStream | None


def resolve_faults(
    source: FaultSourceLike,
    n_epochs: int,
    n_routers: int = DEFAULT_R,
    n_ports: int = N_PORTS,
    neighbor: np.ndarray | None = None,
    opposite: np.ndarray | None = None,
) -> FaultStream:
    """Lower any fault source to the canonical per-epoch `FaultStream`.

    The ONE resolution path the simulator entry points call (mirroring
    `traffic.resolve_source`); the result is shape-validated so every
    source kind feeds the simulator the same program shape.
    """
    if source is None:
        stream = healthy_stream(n_epochs, n_routers, n_ports)
    elif isinstance(source, str):
        stream = lookup_faults(source).materialize(
            n_epochs, n_routers, n_ports, neighbor, opposite
        )
    elif isinstance(source, FaultSchedule):
        stream = source.materialize(
            n_epochs, n_routers, n_ports, neighbor, opposite
        )
    elif isinstance(source, FaultStream):
        stream = source
    else:
        raise TypeError(
            f"cannot resolve fault source of type {type(source).__name__}; "
            "expected a scenario name, FaultSchedule, FaultStream, or None"
        )
    expect = {
        "link_ok": (n_epochs, n_routers, n_ports),
        "router_ok": (n_epochs, n_routers),
        "mc_ok": (n_epochs, n_routers),
        "telem_mode": (n_epochs,),
        "telem_mag": (n_epochs,),
    }
    for f, shape in expect.items():
        leaf = getattr(stream, f)
        if tuple(leaf.shape) != shape:
            raise ValueError(
                f"fault stream leaf {f!r} has shape {tuple(leaf.shape)}, "
                f"expected {shape}"
            )
    return stream
