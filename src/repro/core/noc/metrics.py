"""IPC proxy models for the NoC simulation (DESIGN.md §2).

The paper reports absolute IPC from GPGPU-sim + an x86 CMP simulator.  Those
simulators are not available offline, so we use documented proxies whose
*relative* behaviour matches the mechanisms the paper describes:

* **GPU IPC** — GPUs are throughput machines: IPC tracks the fraction of
  issued memory transactions the network+DRAM can complete per epoch
  (`served / demand`).  Congestion or MC backlog => fraction drops => IPC
  drops, exactly the Fig. 4 correlation (injection spike -> stalls -> IPC dip).

* **CPU IPC** — CPUs are latency machines (low TLP): IPC follows an
  Amdahl-style penalty in average round-trip latency beyond the no-load
  latency `L0`:  `1 / (1 + k * max(0, lat - L0))`.

Both proxies are normalized to (0, 1]; figures therefore report *normalized*
IPC, and EXPERIMENTS.md validates orderings/deltas, not absolute values.
"""
from __future__ import annotations

import jax.numpy as jnp

GPU_BASE_IPC = 1.0
CPU_NOLOAD_LAT = 14.0
# omnetpp has low MLP: IPC degrades gently with added memory latency
CPU_LAT_SENSITIVITY = 0.01


def gpu_ipc_proxy(served, demand):
    """Served/demand completion fraction, capped at 1.

    Zero-demand epochs (reachable in the low phase of sparse workloads:
    14 tiles x rate_lo x epoch_len < 1 expected packet) mean the GPU issued
    nothing — it is idle, not stalled — so they score the base IPC instead
    of the silent 0 the old `served / max(demand, 1)` clamp produced.  For
    any positive demand the divisor is exact (the old clamp also deflated
    fractional demands, which integer counters never produce but trace
    replays / unit tests can).
    """
    frac = jnp.minimum(served / jnp.maximum(demand, 1e-9), 1.0)
    return GPU_BASE_IPC * jnp.where(demand > 0, frac, 1.0)


def cpu_ipc_proxy(avg_latency):
    pen = jnp.maximum(avg_latency - CPU_NOLOAD_LAT, 0.0)
    return 1.0 / (1.0 + CPU_LAT_SENSITIVITY * pen)
