"""Synthetic CPU/GPU chiplet traffic (paper §4.1 workloads, Fig. 4 dynamics).

The paper drives GPU chiplets with ISPASS2009/Rodinia benchmarks (PATH, LIB,
STO, MUM, BFS, LPS) and CPU chiplets with SPEC 2006 (omnetpp).  Those traces
are a data gate offline, so we model each benchmark as a Markov-modulated
Bernoulli injection process whose parameters are chosen to match the paper's
qualitative description:

  * GPU injection varies strongly over time (bursty phases, Fig. 4);
  * CPU injection is comparatively stable;
  * benchmarks differ in mean demand and burstiness (BFS the burstiest —
    it shows the largest KF gain in Fig. 10).

Each profile defines (rate_lo, rate_hi, p_enter_burst, p_exit_burst) for GPU
nodes, in packets/node/cycle on the request subnet.  Rates are per GPU
*chiplet* (2 SMs per tile, Table 1).

``WorkloadProfile`` is a JAX pytree whose leaves are *rate scalars*, not a
static hashable: the simulator traces over the rates, so every workload
shares one compiled program (DESIGN.md §4).  Profile names live in the
``PROFILES`` dict keys.  ``stack_profiles`` builds the batched (B,)-leaf
profile pytree consumed by ``sim.simulate_batch``.

TrafficSource protocol (DESIGN.md §15)
--------------------------------------
Every demand input implements one protocol: ``epoch_demand(n_epochs)``
lowers the source to the canonical ``EpochDemand`` — a ``WorkloadProfile``
whose leaves are ``(n_epochs,)`` float32 rows of ``(rate_lo, rate_hi,
p_enter, p_exit, cpu_rate)``, exactly the pytree the simulator consumes
through its epoch scan ``xs``.  Three implementations ship here:

  * ``WorkloadProfile``   — stationary rates, broadcast across epochs;
  * ``ScenarioSchedule``  — piecewise synthetic programs (DESIGN.md §12):
    each ``Segment`` is a base profile, optionally ramping into another
    and/or pinning the Markov burst phase;
  * ``RecordedTrace``     — replayed per-epoch demand rows captured from a
    previous run (`repro.obs.recorder.TraceRecorder`), loaded from the
    versioned npz trace schema, or synthesized by the HLO-cost adapter
    (`repro.core.noc.trace_adapters`) — with tile/stretch fit controls so
    the trace length need not match ``n_epochs``.

``resolve_source`` is the one lowering path the simulator entry points
call; because every source lowers to the same per-epoch-xs pytree, all
source kinds share the simulator's ONE compiled program.  Names resolve
through the workload registry: ``PROFILES`` and ``SCENARIOS`` plus
anything added via ``register_workload`` / ``register_trace`` (recorded
trace files become first-class sweep workloads).  ``materialize`` is the
deprecated pre-§15 spelling of ``resolve_source`` and accepts the same
inputs for one more release.
"""
from __future__ import annotations

import dataclasses
import difflib
import json

from typing import Iterable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class WorkloadProfile(NamedTuple):
    """Markov-modulated Bernoulli injection parameters (a JAX pytree).

    Leaves may be Python floats (single run) or (B,) arrays (batched sweep).
    """

    gpu_rate_lo: float | Array
    gpu_rate_hi: float | Array
    p_enter: float | Array      # low -> high phase transition prob per cycle
    p_exit: float | Array       # high -> low
    # omnetpp is memory-heavy: 14 CPU tiles x 0.12 ~= 1.7 pkt/cycle of
    # stable demand — a meaningful share of the ~8 pkt/cycle MC ingress,
    # so CPU and GPU classes genuinely contend during GPU bursts.
    cpu_rate: float | Array = 0.12

    def epoch_demand(self, n_epochs: int) -> "WorkloadProfile":
        """TrafficSource: broadcast stationary rates across the epoch axis.

        Scalar leaves become constant ``(n_epochs,)`` float32 rows — the
        same float32 values the scalar-leaf trace consumed, so the lowering
        is value-invisible (pinned by tests/test_predictor_ablation.py).
        Already-per-epoch leaves pass through after a length check, so a
        materialized ``EpochDemand`` is itself a valid source.
        """

        def lower(x):
            x = jnp.asarray(x, jnp.float32)
            if x.ndim == 0:
                return jnp.broadcast_to(x, (n_epochs,))
            if x.shape != (n_epochs,):
                raise ValueError(
                    f"per-epoch profile leaf has shape {x.shape}, expected "
                    f"({n_epochs},)"
                )
            return x

        return jax.tree.map(lower, self)


# Burstiness/demand ordering mirrors the paper's figures: BFS and MUM show the
# biggest dynamic swings; LIB/PATH are moderate; STO/LPS have high mean load.
# High-phase aggregate offered load (14 GPU tiles x rate_hi) is tuned to
# exceed the network's ejection/link capacity near the MCs so that bursts
# genuinely contend for VCs and switch slots (paper Fig. 4 shows saturating
# spikes), while the low phase is comfortably under capacity.
# Burst dwell times are program phases: thousands of cycles (several KF
# epochs), matching the paper's 5k/10k-cycle hysteresis constants.
# High-phase loads put the network at rho ~ 0.85-0.97 of the 8 pkt/cycle MC
# ingress capacity: the queueing-delay regime where buffer (VC) allocation
# and switch priority actually move throughput (via the MSHR feedback loop),
# rather than a hard-saturated regime where only link capacity matters.
PROFILES: dict[str, WorkloadProfile] = {
    "PATH": WorkloadProfile(0.06, 0.31, 0.00020, 0.00040),
    "LIB": WorkloadProfile(0.08, 0.33, 0.00025, 0.00035),
    "STO": WorkloadProfile(0.12, 0.36, 0.00030, 0.00028),
    "MUM": WorkloadProfile(0.04, 0.38, 0.00025, 0.00020),
    "BFS": WorkloadProfile(0.03, 0.40, 0.00030, 0.00012),
    "LPS": WorkloadProfile(0.10, 0.35, 0.00028, 0.00030),
}


def stack_profiles(profiles: Iterable[WorkloadProfile]) -> WorkloadProfile:
    """Stack profiles into one pytree with (B,) float32 leaves (vmap axis 0)."""
    rows = list(profiles)
    return jax.tree.map(
        lambda *xs: jnp.asarray(xs, jnp.float32), *rows
    )


def init_phase() -> Array:
    """Global burst phase: 0 = low, 1 = high.

    GPU kernels execute in lock-step program phases across the chiplets, so
    the burst phase is shared by all GPU tiles (Fig. 4 shows coherent,
    workload-wide spikes) — per-tile Bernoulli draws still decorrelate the
    individual packet injections.
    """
    return jnp.int32(0)


def step_phase_u(profile: WorkloadProfile, phase: Array, u: Array) -> Array:
    """Advance the global Markov burst phase given a pre-drawn uniform `u`.

    The cycle engine precomputes its whole epoch's uniforms in one batched
    draw (DESIGN.md §11); `u` here must be `jax.random.uniform(key, ())` for
    the cycle's key so the split is value-identical to drawing in the loop.
    """
    enter = (phase == 0) & (u < profile.p_enter)
    exit_ = (phase == 1) & (u < profile.p_exit)
    return jnp.where(enter, 1, jnp.where(exit_, 0, phase)).astype(jnp.int32)


def step_phase(profile: WorkloadProfile, phase: Array, key: Array) -> Array:
    """Advance the global Markov burst phase by one cycle."""
    return step_phase_u(profile, phase, jax.random.uniform(key, ()))


def injection_rates(
    profile: WorkloadProfile, node_type: Array, phase: Array
) -> Array:
    """Offered load (prob of generating a request this cycle) per node.

    ``node_type`` is TRACED data since the placement layer (DESIGN.md
    §17): the simulator passes the per-epoch virtual class `ntype_e`
    derived from the placement stream, so relocating a tile moves its
    offered load with it; static runs pass rows that equal the topology
    constants bit-for-bit.
    """
    gpu_rate = jnp.where(phase == 1, profile.gpu_rate_hi, profile.gpu_rate_lo)
    rates = jnp.where(node_type == 1, gpu_rate, 0.0)          # GPU tiles
    rates = jnp.where(node_type == 0, profile.cpu_rate, rates)  # CPU tiles
    return rates  # MC tiles inject only replies, handled by the MC model


def pick_mc_dest(key: Array, shape, mc_ids: Array) -> Array:
    """Uniformly choose a destination MC for each generated request."""
    idx = jax.random.randint(key, shape, 0, mc_ids.shape[0])
    return mc_ids[idx]


# ---------------------------------------------------------------------------
# Scenario schedules: piecewise workload programs (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _resolve_profile(p: str | WorkloadProfile) -> WorkloadProfile:
    return PROFILES[p] if isinstance(p, str) else p


class Segment(NamedTuple):
    """One piece of a scenario: governs epochs in [start, next start).

    start      — fraction of the run in [0, 1) where this segment begins
                 (fractional so one schedule serves any ``n_epochs``).
    profile    — base injection parameters (name or WorkloadProfile).
    ramp_to    — if set, rates interpolate linearly from ``profile`` to this
                 across the segment (a rate ramp).
    pin_phase  — None leaves the Markov burst phase free; 0/1 force the
                 phase low/high via (p_enter, p_exit) = (0,1)/(1,0), making
                 burst timing deterministic to within one cycle.
    """

    start: float
    profile: str | WorkloadProfile
    ramp_to: str | WorkloadProfile | None = None
    pin_phase: int | None = None


@dataclasses.dataclass(frozen=True)
class ScenarioSchedule:
    """A piecewise-constant (or ramped) workload program.

    ``materialize(n_epochs)`` lowers the schedule to a ``WorkloadProfile``
    with ``(n_epochs,)`` float32 leaves — one parameter row per epoch —
    which the simulator consumes through its epoch scan ``xs``.  Epoch
    boundaries are exact: epoch ``e`` is governed by the last segment with
    ``round(start * n_epochs) <= e``.
    """

    segments: tuple[Segment, ...]

    def __post_init__(self):
        if not self.segments:
            raise ValueError("ScenarioSchedule needs at least one segment")
        starts = [s.start for s in self.segments]
        if starts != sorted(starts):
            raise ValueError(f"segment starts must be sorted, got {starts}")
        if starts[0] != 0.0:
            raise ValueError(f"first segment must start at 0.0, got {starts[0]}")
        for s in self.segments:
            if not 0.0 <= s.start < 1.0:
                raise ValueError(f"segment start {s.start} outside [0, 1)")
            if s.pin_phase not in (None, 0, 1):
                raise ValueError(f"pin_phase must be None/0/1, got {s.pin_phase}")

    def materialize(self, n_epochs: int) -> WorkloadProfile:
        bounds = [int(round(s.start * n_epochs)) for s in self.segments]
        bounds.append(n_epochs)
        rows = {f: np.empty((n_epochs,), np.float32)
                for f in WorkloadProfile._fields}
        for seg, lo, hi in zip(self.segments, bounds, bounds[1:]):
            if hi <= lo:
                continue  # segment collapsed at this n_epochs resolution
            base = _resolve_profile(seg.profile)
            tgt = _resolve_profile(seg.ramp_to) if seg.ramp_to is not None else None
            # t in [0, 1] across the segment's epochs (0/1 at its endpoints)
            t = (np.arange(hi - lo, dtype=np.float32)
                 / max(hi - lo - 1, 1))
            for f in WorkloadProfile._fields:
                a = np.float32(getattr(base, f))
                row = a + t * (np.float32(getattr(tgt, f)) - a) if tgt is not None \
                    else np.full((hi - lo,), a, np.float32)
                rows[f][lo:hi] = row
            if seg.pin_phase is not None:
                rows["p_enter"][lo:hi] = 1.0 if seg.pin_phase == 1 else 0.0
                rows["p_exit"][lo:hi] = 0.0 if seg.pin_phase == 1 else 1.0
        return WorkloadProfile(**{
            f: jnp.asarray(rows[f]) for f in WorkloadProfile._fields
        })

    def epoch_demand(self, n_epochs: int) -> WorkloadProfile:
        """TrafficSource: lower the schedule to per-epoch demand rows."""
        return self.materialize(n_epochs)


def materialize(
    workload: "TrafficSourceLike", n_epochs: int
) -> WorkloadProfile:
    """Deprecated pre-§15 spelling of :func:`resolve_source`.

    Kept for one release so existing callers (and the old ad-hoc
    ``str | WorkloadProfile | ScenarioSchedule`` union) keep working; new
    code should call ``resolve_source`` directly, which also accepts
    ``RecordedTrace`` and anything else implementing ``TrafficSource``.
    """
    return resolve_source(workload, n_epochs)


def phase_shift(
    a: str | WorkloadProfile = "PATH",
    b: str | WorkloadProfile = "BFS",
    at: float = 0.5,
) -> ScenarioSchedule:
    """Piecewise workload switch: run ``a``, then ``b`` from fraction ``at``
    (the SHIFT-style compute-relocation scenario, e.g. PATH -> BFS mid-run)."""
    return ScenarioSchedule((Segment(0.0, a), Segment(at, b)))


def shift_scenario(
    a: str | WorkloadProfile = "PATH",
    b: str | WorkloadProfile = "BFS",
    dip_scale: float = 0.0,
) -> ScenarioSchedule:
    """The predictor-ablation gate scenario: a program phase shift (``a``
    then ``b`` mid-run) whose programs execute as deterministic kernel-phase
    arcs — calm, a long burst, a short inter-kernel gap ("dip"), and a
    second burst — pinned via the Markov phase so the comparison is
    reproducible across seeds.

    The arc geometry — per 30-epoch arc (canonical 120-epoch run):
    [calm 12][burst 10][dip 2][burst 6] — is sized against the paper's
    hysteresis constants (hold 10 epochs, revert 20) and the simulator's
    observation dynamics (the dip's first epoch reads saturated counters
    while the burst backlog drains; only its second epoch reads low) so
    that *prediction quality*, not hysteresis smoothing, decides the score:

      * the observational dip epoch lands 11 epochs after the burst onset
        — past the hold — so a reactive predictor (last-value, or EMA at
        the textbook α=0.5, since ``dip_scale=0`` drives every observation
        to −1) is FREE to un-boost on it and then pays the hold lockout
        for the entire second burst, while the KF's posterior rides the
        one-epoch gap;
      * the boosted burst span (~18 epochs) stays inside the 20-epoch
        revert budget, so the revert rule and its hold shadow land in the
        calm window (harmless) rather than mid-burst — the paper-tuned
        filter (q=1e-3) takes ~10 calm epochs to release, which the
        12-epoch calm absorbs exactly.
    """
    arcs = []
    for arc, prof in ((0, a), (30, a), (60, b), (90, b)):
        base = _resolve_profile(prof)
        arcs += [
            Segment(arc / 120, base, pin_phase=0),                 # calm 12
            Segment((arc + 12) / 120, base, pin_phase=1),          # burst 10
            Segment((arc + 22) / 120, scale_rates(base, dip_scale),
                    pin_phase=0),                                  # dip 2
            Segment((arc + 24) / 120, base, pin_phase=1),          # burst 6
        ]
    return ScenarioSchedule(tuple(arcs))


def scale_rates(p: str | WorkloadProfile, scale: float) -> WorkloadProfile:
    """Scale a profile's GPU injection rates (phase dynamics untouched)."""
    p = _resolve_profile(p)
    return p._replace(
        gpu_rate_lo=float(p.gpu_rate_lo) * scale,
        gpu_rate_hi=float(p.gpu_rate_hi) * scale,
    )


def rate_ramp(
    base: str | WorkloadProfile = "LIB",
    lo_scale: float = 0.5,
    hi_scale: float = 1.5,
) -> ScenarioSchedule:
    """Linear offered-load ramp from ``lo_scale`` x to ``hi_scale`` x the
    base profile's GPU rates across the whole run."""
    base = _resolve_profile(base)
    return ScenarioSchedule((
        Segment(0.0, scale_rates(base, lo_scale),
                ramp_to=scale_rates(base, hi_scale)),
    ))


def program_mix(
    programs: tuple[str | WorkloadProfile, ...] = ("PATH", "STO", "BFS"),
    repeats: int = 2,
) -> ScenarioSchedule:
    """Time-multiplexed multi-program mix: the programs run back-to-back in
    equal slices, the whole sequence repeated ``repeats`` times."""
    n = len(programs) * repeats
    segs = tuple(
        Segment(i / n, programs[i % len(programs)]) for i in range(n)
    )
    return ScenarioSchedule(segs)


def burst_train(
    base: str | WorkloadProfile = "BFS",
    calm: int = 8,
    burst: int = 10,
    dip: int = 1,
) -> ScenarioSchedule:
    """Deterministic burst train with mid-burst micro-dips, on a 64-slot
    fractional grid: ``calm`` slots pinned low, then a burst of ``burst``
    slots pinned high broken by a ``dip``-slot pinned-low notch, repeating.

    A reporting scenario, NOT the ablation gate: its notches land inside
    the hysteresis hold window, so every predictor rides them and the
    measured predictor spread is within noise (see the committed
    `noc_ablation` rows — last-value even noses ahead).  The gate scenario
    is `shift_scenario`, whose dip geometry is sized against the hold and
    revert constants so prediction quality actually separates.
    """
    base = _resolve_profile(base)
    if calm + burst + dip + burst > 64:
        raise ValueError("one burst unit must fit the 64-slot grid")
    segs, pos = [], 0
    while pos < 64:
        for length, pin in ((calm, 0), (burst, 1), (dip, 0), (burst, 1)):
            if pos >= 64:
                break
            segs.append(Segment(pos / 64, base, pin_phase=pin))
            pos += length
    return ScenarioSchedule(tuple(segs))


# Scenario library (DESIGN.md §12).  Names share the SweepSpec.workload
# namespace with PROFILES and resolve through `lookup_workload`.
SCENARIOS: dict[str, ScenarioSchedule] = {
    # SHIFT-style program relocation (moderate PATH, then bursty BFS) with
    # deterministic kernel-phase arcs — the predictor-ablation gate.
    "SHIFT_PATH_BFS": shift_scenario("PATH", "BFS"),
    # the plain mid-run workload switch, Markov phases left free
    "SHIFT_SMOOTH": phase_shift("PATH", "BFS", at=0.5),
    # offered-load ramp through the contention knee
    "RAMP_LIB": rate_ramp("LIB", 0.5, 1.5),
    # time-multiplexed multi-program mix
    "MIX_PATH_STO_BFS": program_mix(("PATH", "STO", "BFS"), repeats=2),
    # deterministic burst train with micro-dips (ablation stressor)
    "BURSTS_BFS": burst_train("BFS"),
}


# ---------------------------------------------------------------------------
# TrafficSource protocol, recorded traces, and the workload registry
# (DESIGN.md §15)
# ---------------------------------------------------------------------------

@runtime_checkable
class TrafficSource(Protocol):
    """Anything that can lower itself to per-epoch demand rows.

    ``epoch_demand(n_epochs)`` must return an ``EpochDemand``: a
    ``WorkloadProfile`` whose five leaves are ``(n_epochs,)`` float32 rows.
    ``resolve_source`` validates that contract after the call, so custom
    sources cannot silently feed the simulator a second program shape.
    """

    def epoch_demand(self, n_epochs: int) -> WorkloadProfile:
        ...


# The canonical lowered form: a WorkloadProfile whose leaves are
# (n_epochs,) float32 rows — one parameter row per epoch, consumed by the
# simulator's epoch scan as `xs`.  An alias, not a subclass: EpochDemand
# must remain pytree-identical to WorkloadProfile so every source kind
# shares the simulator's single compiled program.
EpochDemand = WorkloadProfile

# Versioned npz trace schema (DESIGN.md §15).  A trace file is a plain
# npz (no pickling) with:
#   schema          — the literal "noc_demand_trace"
#   schema_version  — int, currently 1
#   name            — short trace name (informational)
#   meta_json       — JSON dict of provenance (recorder config, adapter
#                     parameters, source workload, ...)
#   demand_<field>  — (T,) float32 row per WorkloadProfile field
TRACE_SCHEMA = "noc_demand_trace"
TRACE_SCHEMA_VERSION = 1

_FIT_MODES = ("exact", "tile", "stretch")


@dataclasses.dataclass(frozen=True)
class RecordedTrace:
    """A replayed per-epoch demand trace (TrafficSource implementation).

    ``demand`` holds the recorded rows as a ``WorkloadProfile`` of ``(T,)``
    float32 numpy leaves.  ``fit`` controls how a trace of length ``T`` is
    fitted to a run of ``n_epochs`` epochs:

      * ``"exact"``   — require ``T == n_epochs`` (the bitwise-replay mode);
      * ``"tile"``    — repeat the trace cyclically (epoch ``e`` reads row
                        ``e % T``);
      * ``"stretch"`` — linearly resample the rows onto ``n_epochs`` points
                        (preserves the trace's shape, not its timing).

    When ``T == n_epochs`` every mode passes the rows through untouched,
    so a trace recorded from a run replays bitwise-identical to that run
    regardless of ``fit``.
    """

    demand: WorkloadProfile
    fit: str = "exact"
    name: str = "trace"
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.fit not in _FIT_MODES:
            raise ValueError(
                f"fit must be one of {_FIT_MODES}, got {self.fit!r}"
            )
        rows = {}
        length = None
        for f in WorkloadProfile._fields:
            row = np.asarray(getattr(self.demand, f), np.float32)
            if row.ndim == 0:
                raise ValueError(
                    f"RecordedTrace leaf {f!r} is a scalar; recorded demand "
                    "must be per-epoch (T,) rows — use WorkloadProfile for "
                    "stationary sources"
                )
            if row.ndim != 1:
                raise ValueError(
                    f"RecordedTrace leaf {f!r} has shape {row.shape}, "
                    "expected (T,)"
                )
            if length is None:
                length = row.shape[0]
            elif row.shape[0] != length:
                raise ValueError(
                    f"RecordedTrace leaves disagree on length: {f!r} has "
                    f"{row.shape[0]}, expected {length}"
                )
            rows[f] = row
        if length == 0:
            raise ValueError("RecordedTrace needs at least one epoch row")
        object.__setattr__(self, "demand", WorkloadProfile(**rows))

    @property
    def n_epochs_recorded(self) -> int:
        return int(np.asarray(self.demand.gpu_rate_lo).shape[0])

    def epoch_demand(self, n_epochs: int) -> WorkloadProfile:
        """TrafficSource: fit the recorded rows to ``n_epochs`` epochs."""
        T = self.n_epochs_recorded
        if T == n_epochs:
            rows = {f: np.asarray(getattr(self.demand, f))
                    for f in WorkloadProfile._fields}
        elif self.fit == "exact":
            raise ValueError(
                f"trace {self.name!r} has {T} recorded epochs but the run "
                f"wants {n_epochs}; use fit='tile' or fit='stretch' to "
                "adapt it"
            )
        elif self.fit == "tile":
            idx = np.arange(n_epochs) % T
            rows = {f: np.asarray(getattr(self.demand, f))[idx]
                    for f in WorkloadProfile._fields}
        else:  # stretch: linear resample onto n_epochs sample points
            src = np.linspace(0.0, 1.0, T, dtype=np.float64)
            dst = np.linspace(0.0, 1.0, n_epochs, dtype=np.float64)
            rows = {
                f: np.interp(
                    dst, src, np.asarray(getattr(self.demand, f), np.float64)
                ).astype(np.float32)
                for f in WorkloadProfile._fields
            }
        return WorkloadProfile(**{
            f: jnp.asarray(rows[f], jnp.float32)
            for f in WorkloadProfile._fields
        })

    def with_fit(self, fit: str) -> "RecordedTrace":
        return dataclasses.replace(self, fit=fit)

    def save(self, path) -> None:
        """Write the trace as a versioned npz file (no pickling)."""
        payload = {
            "schema": TRACE_SCHEMA,
            "schema_version": np.int64(TRACE_SCHEMA_VERSION),
            "name": self.name,
            "meta_json": json.dumps(self.meta, sort_keys=True),
        }
        for f in WorkloadProfile._fields:
            payload[f"demand_{f}"] = np.asarray(
                getattr(self.demand, f), np.float32
            )
        np.savez(path, **payload)

    @classmethod
    def load(cls, path, fit: str = "exact") -> "RecordedTrace":
        """Load a trace written by :meth:`save` (schema-validated)."""
        with np.load(path, allow_pickle=False) as data:
            problems = validate_trace_npz(data)
            if problems:
                raise ValueError(
                    f"{path}: not a valid {TRACE_SCHEMA} file: "
                    + "; ".join(problems)
                )
            demand = WorkloadProfile(**{
                f: np.asarray(data[f"demand_{f}"], np.float32)
                for f in WorkloadProfile._fields
            })
            name = str(np.asarray(data["name"]).item())
            meta = json.loads(str(np.asarray(data["meta_json"]).item()))
        return cls(demand=demand, fit=fit, name=name, meta=meta)


def validate_trace_npz(data) -> list[str]:
    """Return schema problems for an opened npz mapping ([] when valid)."""
    problems = []
    keys = set(getattr(data, "files", data.keys()))
    for key in ("schema", "schema_version", "name", "meta_json"):
        if key not in keys:
            problems.append(f"missing key {key!r}")
    if "schema" in keys:
        schema = str(np.asarray(data["schema"]).item())
        if schema != TRACE_SCHEMA:
            problems.append(f"schema is {schema!r}, expected {TRACE_SCHEMA!r}")
    if "schema_version" in keys:
        version = int(np.asarray(data["schema_version"]).item())
        if version > TRACE_SCHEMA_VERSION:
            problems.append(
                f"schema_version {version} is newer than supported "
                f"{TRACE_SCHEMA_VERSION}"
            )
    length = None
    for f in WorkloadProfile._fields:
        key = f"demand_{f}"
        if key not in keys:
            problems.append(f"missing key {key!r}")
            continue
        row = np.asarray(data[key])
        if row.ndim != 1 or row.shape[0] == 0:
            problems.append(f"{key} has shape {row.shape}, expected (T,)")
        elif length is None:
            length = row.shape[0]
        elif row.shape[0] != length:
            problems.append(
                f"{key} has length {row.shape[0]}, expected {length}"
            )
        if row.size and not np.all(np.isfinite(row)):
            problems.append(f"{key} contains non-finite values")
        elif row.size and np.any(row < 0):
            # negative demand rows would silently invert injection gates
            # downstream; reject them at the schema boundary
            problems.append(f"{key} contains negative values")
    if "meta_json" in keys:
        try:
            meta = json.loads(str(np.asarray(data["meta_json"]).item()))
            if not isinstance(meta, dict):
                problems.append("meta_json is not a JSON object")
        except (json.JSONDecodeError, ValueError):
            problems.append("meta_json is not valid JSON")
    return problems


# Workload registry: names registered here share the SweepSpec.workload
# namespace with PROFILES and SCENARIOS and win on collision (so a
# registered trace can shadow a builtin for an experiment).
_REGISTRY: dict[str, "TrafficSource"] = {}


def register_workload(
    name: str, source: "TrafficSource", overwrite: bool = False
) -> None:
    """Register a named workload (any TrafficSource, e.g. a RecordedTrace).

    Refuses to shadow an existing registered/builtin name unless
    ``overwrite=True``.
    """
    if not isinstance(source, TrafficSource):
        raise TypeError(
            f"source for {name!r} does not implement TrafficSource "
            "(needs an epoch_demand(n_epochs) method)"
        )
    if not overwrite and (
        name in _REGISTRY or name in PROFILES or name in SCENARIOS
    ):
        raise ValueError(
            f"workload {name!r} already exists; pass overwrite=True to "
            "replace it"
        )
    _REGISTRY[name] = source


def register_trace(
    name: str, path, fit: str = "exact", overwrite: bool = False
) -> RecordedTrace:
    """Load a trace file and register it as a named workload."""
    trace = RecordedTrace.load(path, fit=fit)
    register_workload(name, trace, overwrite=overwrite)
    return trace


def unregister_workload(name: str) -> None:
    """Remove a registered workload (builtins are untouchable)."""
    _REGISTRY.pop(name, None)


def lookup_workload(name: str) -> "TrafficSource":
    """Resolve a workload name from the registry, PROFILES, or SCENARIOS.

    Unknown names raise ``ValueError`` listing close matches across all
    three namespaces (registered traces included).
    """
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name in PROFILES:
        return PROFILES[name]
    if name in SCENARIOS:
        return SCENARIOS[name]
    known = sorted({*PROFILES, *SCENARIOS, *_REGISTRY})
    near = difflib.get_close_matches(name, known, n=3, cutoff=0.4)
    hint = f"; did you mean {near}?" if near else ""
    raise ValueError(
        f"unknown workload {name!r}{hint} (known workloads: {known})"
    )


def resolve_source(source: "TrafficSourceLike", n_epochs: int) -> EpochDemand:
    """Lower any demand source to the canonical EpochDemand pytree.

    The ONE resolution path used by ``simulate`` / ``simulate_with_trace``
    / ``simulate_batch`` / ``sweep``:

      * ``str``           — resolved via :func:`lookup_workload` (registry,
                            PROFILES, SCENARIOS);
      * ``TrafficSource`` — anything with ``epoch_demand(n_epochs)``:
                            ``WorkloadProfile``, ``ScenarioSchedule``,
                            ``RecordedTrace``, or a custom source;
      * bare 5-tuples     — deprecation shim for the pre-§15 union: coerced
                            to ``WorkloadProfile`` for one release.

    The result is validated to have exactly ``(n_epochs,)`` float32 leaves,
    so every source kind feeds the simulator the same program shape.
    """
    if isinstance(source, str):
        source = lookup_workload(source)
    if not isinstance(source, TrafficSource):
        if isinstance(source, tuple) and len(source) == len(
            WorkloadProfile._fields
        ):
            # pre-§15 callers could pass any profile-shaped tuple
            source = WorkloadProfile(*source)
        else:
            raise TypeError(
                f"cannot resolve demand source of type "
                f"{type(source).__name__}; expected a workload name, "
                "WorkloadProfile, ScenarioSchedule, RecordedTrace, or any "
                "TrafficSource"
            )
    demand = source.epoch_demand(n_epochs)
    for f in WorkloadProfile._fields:
        leaf = getattr(demand, f)
        if tuple(leaf.shape) != (n_epochs,) or leaf.dtype != jnp.float32:
            raise ValueError(
                f"source {type(source).__name__} produced leaf {f!r} with "
                f"shape {leaf.shape} dtype {leaf.dtype}; EpochDemand needs "
                f"({n_epochs},) float32"
            )
        # value gate: a NaN/inf or negative demand row fed to the sim
        # would silently poison injection gates and every KF observation
        # downstream — reject it here, at the ONE resolution path
        row = np.asarray(leaf)
        if not np.all(np.isfinite(row)):
            raise ValueError(
                f"source {type(source).__name__} produced non-finite demand "
                f"in leaf {f!r}"
            )
        if np.any(row < 0):
            raise ValueError(
                f"source {type(source).__name__} produced negative demand "
                f"in leaf {f!r}"
            )
    return demand


# The union accepted by resolve_source (and, transitionally, the old
# entry-point signatures): a workload name or any TrafficSource.
TrafficSourceLike = str | WorkloadProfile | ScenarioSchedule | RecordedTrace
