"""Synthetic CPU/GPU chiplet traffic (paper §4.1 workloads, Fig. 4 dynamics).

The paper drives GPU chiplets with ISPASS2009/Rodinia benchmarks (PATH, LIB,
STO, MUM, BFS, LPS) and CPU chiplets with SPEC 2006 (omnetpp).  Those traces
are a data gate offline, so we model each benchmark as a Markov-modulated
Bernoulli injection process whose parameters are chosen to match the paper's
qualitative description:

  * GPU injection varies strongly over time (bursty phases, Fig. 4);
  * CPU injection is comparatively stable;
  * benchmarks differ in mean demand and burstiness (BFS the burstiest —
    it shows the largest KF gain in Fig. 10).

Each profile defines (rate_lo, rate_hi, p_enter_burst, p_exit_burst) for GPU
nodes, in packets/node/cycle on the request subnet.  Rates are per GPU
*chiplet* (2 SMs per tile, Table 1).

``WorkloadProfile`` is a JAX pytree whose leaves are *rate scalars*, not a
static hashable: the simulator traces over the rates, so every workload
shares one compiled program (DESIGN.md §4).  Profile names live in the
``PROFILES`` dict keys.  ``stack_profiles`` builds the batched (B,)-leaf
profile pytree consumed by ``sim.simulate_batch``.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class WorkloadProfile(NamedTuple):
    """Markov-modulated Bernoulli injection parameters (a JAX pytree).

    Leaves may be Python floats (single run) or (B,) arrays (batched sweep).
    """

    gpu_rate_lo: float | Array
    gpu_rate_hi: float | Array
    p_enter: float | Array      # low -> high phase transition prob per cycle
    p_exit: float | Array       # high -> low
    # omnetpp is memory-heavy: 14 CPU tiles x 0.12 ~= 1.7 pkt/cycle of
    # stable demand — a meaningful share of the ~8 pkt/cycle MC ingress,
    # so CPU and GPU classes genuinely contend during GPU bursts.
    cpu_rate: float | Array = 0.12


# Burstiness/demand ordering mirrors the paper's figures: BFS and MUM show the
# biggest dynamic swings; LIB/PATH are moderate; STO/LPS have high mean load.
# High-phase aggregate offered load (14 GPU tiles x rate_hi) is tuned to
# exceed the network's ejection/link capacity near the MCs so that bursts
# genuinely contend for VCs and switch slots (paper Fig. 4 shows saturating
# spikes), while the low phase is comfortably under capacity.
# Burst dwell times are program phases: thousands of cycles (several KF
# epochs), matching the paper's 5k/10k-cycle hysteresis constants.
# High-phase loads put the network at rho ~ 0.85-0.97 of the 8 pkt/cycle MC
# ingress capacity: the queueing-delay regime where buffer (VC) allocation
# and switch priority actually move throughput (via the MSHR feedback loop),
# rather than a hard-saturated regime where only link capacity matters.
PROFILES: dict[str, WorkloadProfile] = {
    "PATH": WorkloadProfile(0.06, 0.31, 0.00020, 0.00040),
    "LIB": WorkloadProfile(0.08, 0.33, 0.00025, 0.00035),
    "STO": WorkloadProfile(0.12, 0.36, 0.00030, 0.00028),
    "MUM": WorkloadProfile(0.04, 0.38, 0.00025, 0.00020),
    "BFS": WorkloadProfile(0.03, 0.40, 0.00030, 0.00012),
    "LPS": WorkloadProfile(0.10, 0.35, 0.00028, 0.00030),
}


def stack_profiles(profiles: Iterable[WorkloadProfile]) -> WorkloadProfile:
    """Stack profiles into one pytree with (B,) float32 leaves (vmap axis 0)."""
    rows = list(profiles)
    return jax.tree.map(
        lambda *xs: jnp.asarray(xs, jnp.float32), *rows
    )


def init_phase() -> Array:
    """Global burst phase: 0 = low, 1 = high.

    GPU kernels execute in lock-step program phases across the chiplets, so
    the burst phase is shared by all GPU tiles (Fig. 4 shows coherent,
    workload-wide spikes) — per-tile Bernoulli draws still decorrelate the
    individual packet injections.
    """
    return jnp.int32(0)


def step_phase_u(profile: WorkloadProfile, phase: Array, u: Array) -> Array:
    """Advance the global Markov burst phase given a pre-drawn uniform `u`.

    The cycle engine precomputes its whole epoch's uniforms in one batched
    draw (DESIGN.md §11); `u` here must be `jax.random.uniform(key, ())` for
    the cycle's key so the split is value-identical to drawing in the loop.
    """
    enter = (phase == 0) & (u < profile.p_enter)
    exit_ = (phase == 1) & (u < profile.p_exit)
    return jnp.where(enter, 1, jnp.where(exit_, 0, phase)).astype(jnp.int32)


def step_phase(profile: WorkloadProfile, phase: Array, key: Array) -> Array:
    """Advance the global Markov burst phase by one cycle."""
    return step_phase_u(profile, phase, jax.random.uniform(key, ()))


def injection_rates(
    profile: WorkloadProfile, node_type: Array, phase: Array
) -> Array:
    """Offered load (prob of generating a request this cycle) per node."""
    gpu_rate = jnp.where(phase == 1, profile.gpu_rate_hi, profile.gpu_rate_lo)
    rates = jnp.where(node_type == 1, gpu_rate, 0.0)          # GPU tiles
    rates = jnp.where(node_type == 0, profile.cpu_rate, rates)  # CPU tiles
    return rates  # MC tiles inject only replies, handled by the MC model


def pick_mc_dest(key: Array, shape, mc_ids: Array) -> Array:
    """Uniformly choose a destination MC for each generated request."""
    idx = jax.random.randint(key, shape, 0, mc_ids.shape[0])
    return mc_ids[idx]
