"""6x6 mesh topology, XY routing tables, node-type placement (paper Table 1).

Everything here is precomputed with numpy into constant int32 tables that the
jitted cycle loop indexes with gathers — no control flow at trace time.

Ports: 0=N, 1=E, 2=S, 3=W, 4=Local.  Router id r = y * W + x.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

N_PORTS = 5
PORT_N, PORT_E, PORT_S, PORT_W, PORT_L = range(5)
OPPOSITE = np.array([PORT_S, PORT_W, PORT_N, PORT_E, PORT_L], dtype=np.int32)

# node types
NT_CPU, NT_GPU, NT_MC = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class Topology:
    width: int
    height: int
    n_routers: int
    # (R, R) int32: output port at router i for a packet destined to j (XY).
    route: np.ndarray
    # (R, P) int32: neighbor router id through port p (-1 if none/local).
    neighbor: np.ndarray
    # (P,) int32: the input port on the downstream router for our output port.
    opposite: np.ndarray
    # (R,) int32 node type per router: 0=CPU, 1=GPU, 2=MC.
    node_type: np.ndarray
    # (n_mc,) router ids hosting memory controllers.
    mc_ids: np.ndarray


def _xy_route(width: int, height: int) -> np.ndarray:
    n = width * height
    route = np.full((n, n), PORT_L, dtype=np.int32)
    for src in range(n):
        sx, sy = src % width, src // width
        for dst in range(n):
            dx, dy = dst % width, dst // width
            if dx > sx:
                route[src, dst] = PORT_E
            elif dx < sx:
                route[src, dst] = PORT_W
            elif dy > sy:
                route[src, dst] = PORT_S
            elif dy < sy:
                route[src, dst] = PORT_N
            else:
                route[src, dst] = PORT_L
    return route


def _neighbors(width: int, height: int) -> np.ndarray:
    n = width * height
    nb = np.full((n, N_PORTS), -1, dtype=np.int32)
    for r in range(n):
        x, y = r % width, r // width
        if y > 0:
            nb[r, PORT_N] = r - width
        if x < width - 1:
            nb[r, PORT_E] = r + 1
        if y < height - 1:
            nb[r, PORT_S] = r + width
        if x > 0:
            nb[r, PORT_W] = r - 1
    return nb


# Router ids are packed into lane metadata words (kernels/noc_cycle/lanes.py
# uses a 6-bit source field, and the fused lane layout pads routers to 64
# lanes), so any topology must fit in 64 routers.
MAX_ROUTERS = 64


def validate_topology_args(width: int, height: int, n_mc: int) -> None:
    """Reject grids that cannot host the MC rows or the CPU/GPU tiling.

    Raises ValueError with an actionable message instead of silently
    mis-placing MCs (the old behavior backfilled colliding MC columns
    from row 0, scrambling the placement).
    """
    for name, val in (("width", width), ("height", height), ("n_mc", n_mc)):
        if not isinstance(val, int) or isinstance(val, bool):
            raise ValueError(f"{name} must be an int, got {val!r}")
    if width < 2 or height < 2:
        raise ValueError(
            f"mesh needs width >= 2 and height >= 2 (got {width}x{height}): "
            "MCs live on distinct top and bottom rows and XY routing needs "
            "both dimensions"
        )
    if n_mc < 1:
        raise ValueError(f"n_mc must be >= 1, got {n_mc}")
    # bottom row hosts the larger half of an odd split
    if n_mc - n_mc // 2 > width:
        raise ValueError(
            f"n_mc={n_mc} does not fit on the top+bottom rows of a "
            f"width-{width} mesh (max {2 * width}); widen the mesh or drop MCs"
        )
    if width * height - n_mc < 2:
        raise ValueError(
            f"{width}x{height} mesh with n_mc={n_mc} leaves "
            f"{width * height - n_mc} non-MC tile(s); need >= 2 so both a GPU "
            "and a CPU chiplet exist"
        )
    if width * height > MAX_ROUTERS:
        raise ValueError(
            f"{width}x{height} mesh has {width * height} routers; the packed "
            f"lane layout caps at {MAX_ROUTERS} (6-bit router ids in lane "
            "metadata). Use a smaller grid."
        )


@functools.lru_cache(maxsize=None)
def make_topology(width: int = 6, height: int = 6, n_mc: int = 8) -> Topology:
    """Paper Table 1: 6x6 shared 2D mesh; 8 GDDR5 MCs; CPU/GPU chiplet tiles.

    MCs sit on the top and bottom rows (the usual GPGPU-sim placement);
    remaining tiles alternate GPU / CPU chiplets (14 + 14 on the 6x6).
    Non-default grids are validated by `validate_topology_args` — with the
    per-row MC count capped at `width`, the evenly-spread columns below are
    always distinct, so the placement is exact (no silent backfilling).
    """
    validate_topology_args(width, height, n_mc)
    n = width * height
    node_type = np.empty((n,), dtype=np.int32)
    # spread MCs evenly over top and bottom rows
    per_row = n_mc // 2
    top_cols = np.linspace(0, width - 1, per_row).round().astype(int)
    bot_cols = np.linspace(0, width - 1, n_mc - per_row).round().astype(int)
    mc_ids = sorted(
        {int(c) for c in top_cols} | {int((height - 1) * width + c) for c in bot_cols}
    )
    assert len(mc_ids) == n_mc, (width, height, n_mc, mc_ids)
    mc_ids = np.asarray(mc_ids, dtype=np.int32)

    flip = 0
    for r in range(n):
        if r in mc_ids:
            node_type[r] = NT_MC
        else:
            node_type[r] = NT_GPU if flip else NT_CPU
            flip ^= 1

    return Topology(
        width=width,
        height=height,
        n_routers=n,
        route=_xy_route(width, height),
        neighbor=_neighbors(width, height),
        opposite=OPPOSITE,
        node_type=node_type,
        mc_ids=mc_ids,
    )
