"""Traced compute-placement streams for the NoC simulator (DESIGN.md §17).

The paper's controller only reallocates VCs/bandwidth; SHIFT (PAPERS.md)
relocates *compute* across chiplets when communication dominates.  This
module makes that possible by turning the injection source→node binding
— previously the static `Topology.node_type` numpy constants baked into
the trace — into per-epoch DATA: a `PlacementSchedule` (the
placement-domain sibling of `faults.FaultSchedule`) materializes to a
`PlacementStream` of per-epoch `(E, R)` node-class rows delivered to
`sim._simulate_impl` through the epoch scan `xs` exactly like the fault
masks, so relocated and static configurations share the simulator's ONE
compiled program (`sim.trace_count() == 1`; a static run threads the
identity stream from `static_placement`).

Each stream carries TWO class plans per epoch, mirroring how the VC
allocator carries masks0/masks1:

  * ``cls0`` — the base plan: which node class (NT_CPU / NT_GPU) each
               non-MC tile hosts when the placement controller is idle.
  * ``cls1`` — the boosted plan: the relocated layout the controller
               switches to while the KF-driven hysteresis machine holds
               config 1 (gated by `ModePolicy.place_enable`).

MC tiles are physical — memory controllers never relocate — so MC rows
always carry NT_MC and the simulator re-asserts that with a `where` on
the static `is_mc` mask.  The identity stream sets both plans to the
topology's own `node_type`, which makes every derived quantity
(`is_gpu`, `node_cls`, `req_sub`, injection gates) select bit-for-bit
the pre-refactor constants: static placement is bitwise-unchanged by
construction.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc.topology import NT_CPU, NT_GPU, NT_MC, Topology, make_topology

Array = jax.Array

_SLOTS = ("base", "boost")


class PlacementStream(NamedTuple):
    """Per-epoch node-class plans (a JAX pytree; leading axis = E).

    Consumed by the epoch scan as `xs`: each epoch body receives one
    (R,) base row and one (R,) boosted row; the traced policy picks
    between them.  Leaves may carry an extra leading batch dimension
    when stacked for `sim.simulate_batch` (like `faults.FaultStream`).
    """

    cls0: Array  # (E, R) int32 — base node class per router (NT_*)
    cls1: Array  # (E, R) int32 — boosted/relocated node class per router


class PlacementEvent(NamedTuple):
    """One relocation arc: governs epochs in [start, stop) (run fractions).

    plan — name of a registered plan builder (`PLAN_BUILDERS`): the
           (R,) layout written over the affected window.
    slot — "boost" writes the layout into ``cls1`` (the controller
           relocates only while the KF holds config 1); "base" writes
           ``cls0`` (a forced, scheduled migration à la SHIFT,
           independent of the controller).
    """

    start: float
    stop: float
    plan: str = "gpu_near_mc"
    slot: str = "boost"


def _plan_identity(topo: Topology) -> np.ndarray:
    return np.asarray(topo.node_type, np.int32).copy()


def _plan_gpu_near_mc(topo: Topology) -> np.ndarray:
    """Relocate the GPU class onto the non-MC tiles nearest the MCs.

    Keeps the GPU/CPU tile counts of the base layout (14 + 14 on the
    6x6) and ranks non-MC tiles by Manhattan distance to the closest
    MC (ties broken by router id, deterministically).  Shorter
    request/reply paths for the memory-bound class is the mechanism
    behind the joint >= bandwidth-only GPU-IPC gate in fig_placement.
    """
    nt = np.asarray(topo.node_type, np.int32)
    n_gpu = int((nt == NT_GPU).sum())
    w = topo.width
    ids = np.arange(topo.n_routers)
    xy = np.stack([ids % w, ids // w], axis=1)
    mc_xy = xy[np.asarray(topo.mc_ids)]
    dist = np.abs(xy[:, None, :] - mc_xy[None, :, :]).sum(-1).min(-1)
    non_mc = ids[nt != NT_MC]
    order = non_mc[np.lexsort((non_mc, dist[non_mc]))]
    plan = nt.copy()
    plan[order[:n_gpu]] = NT_GPU
    plan[order[n_gpu:]] = NT_CPU
    return plan


def _plan_swap_classes(topo: Topology) -> np.ndarray:
    """Swap the GPU and CPU classes on every non-MC tile."""
    nt = np.asarray(topo.node_type, np.int32)
    plan = nt.copy()
    plan[nt == NT_GPU] = NT_CPU
    plan[nt == NT_CPU] = NT_GPU
    return plan


# (R,) layout builders an event's `plan` names.  Builders only ever
# reassign non-MC tiles between NT_CPU/NT_GPU; MC rows stay NT_MC.
PLAN_BUILDERS: dict[str, Callable[[Topology], np.ndarray]] = {
    "identity": _plan_identity,
    "gpu_near_mc": _plan_gpu_near_mc,
    "swap_classes": _plan_swap_classes,
}


@dataclasses.dataclass(frozen=True)
class PlacementSchedule:
    """A piecewise relocation program (sibling of `faults.FaultSchedule`).

    ``materialize(n_epochs, topology)`` lowers the schedule to a
    `PlacementStream` with exact epoch boundaries: epoch ``e`` is inside
    an event iff ``round(start * n_epochs) <= e < round(stop * n_epochs)``.
    Outside every event both plans are the topology's base layout.
    """

    events: tuple[PlacementEvent, ...]

    def __post_init__(self):
        for ev in self.events:
            if ev.plan not in PLAN_BUILDERS:
                raise ValueError(
                    f"unknown placement plan {ev.plan!r}; expected one of "
                    f"{sorted(PLAN_BUILDERS)}"
                )
            if ev.slot not in _SLOTS:
                raise ValueError(
                    f"placement slot {ev.slot!r} must be one of {_SLOTS}"
                )
            if not 0.0 <= ev.start < ev.stop <= 1.0:
                raise ValueError(
                    f"placement event window [{ev.start}, {ev.stop}) "
                    "outside [0, 1]"
                )

    def materialize(
        self, n_epochs: int, topology: Topology | None = None
    ) -> PlacementStream:
        topo = topology if topology is not None else make_topology()
        base = _plan_identity(topo)
        cls0 = np.tile(base, (n_epochs, 1))
        cls1 = np.tile(base, (n_epochs, 1))
        for ev in self.events:
            lo = int(round(ev.start * n_epochs))
            hi = int(round(ev.stop * n_epochs))
            if hi <= lo:
                continue
            plan = PLAN_BUILDERS[ev.plan](topo)
            if plan.shape != base.shape:
                raise ValueError(
                    f"plan {ev.plan!r} built shape {plan.shape} for a "
                    f"{topo.n_routers}-router topology"
                )
            target = cls1 if ev.slot == "boost" else cls0
            target[lo:hi] = plan
        return PlacementStream(cls0=jnp.asarray(cls0), cls1=jnp.asarray(cls1))


def static_placement(
    n_epochs: int, topology: Topology | None = None
) -> PlacementStream:
    """The identity placement stream: both plans = the topology layout.

    This is what every placement-free run threads through the epoch
    scan, which is what keeps relocated x static configurations on one
    compiled program — and, because every derived node-class quantity is
    a select against these rows, the static program's VALUES are
    bit-for-bit the pre-placement program's.
    """
    return PlacementSchedule(()).materialize(n_epochs, topology)


# ---------------------------------------------------------------------------
# Placement scenario library + registry (the placement-domain FAULTS dict).
# ---------------------------------------------------------------------------

PLACEMENTS: dict[str, PlacementSchedule] = {
    # the KF-gated relocation of record: while the controller holds the
    # boost config, GPU compute sits on the tiles nearest the MCs.
    "GPU_NEAR_MC": PlacementSchedule((
        PlacementEvent(0.0, 1.0, "gpu_near_mc", "boost"),
    )),
    # forced static relocation: the near-MC layout is the base plan for
    # the whole run, independent of the controller (ablation baseline).
    "GPU_NEAR_MC_ALWAYS": PlacementSchedule((
        PlacementEvent(0.0, 1.0, "gpu_near_mc", "base"),
    )),
    # a scheduled SHIFT-style migration timeline: mid-run the base plan
    # swaps every GPU/CPU tile (exercises the relocation trace channel).
    "SWAP_MID": PlacementSchedule((
        PlacementEvent(0.5, 1.0, "swap_classes", "base"),
    )),
}


def register_placement(
    name: str, schedule: PlacementSchedule, overwrite: bool = False
) -> None:
    """Register a named placement scenario (shares the `--placement` namespace)."""
    if not isinstance(schedule, PlacementSchedule):
        raise TypeError(
            f"placement scenario {name!r} must be a PlacementSchedule, got "
            f"{type(schedule).__name__}"
        )
    if not overwrite and name in PLACEMENTS:
        raise ValueError(
            f"placement scenario {name!r} already exists; pass overwrite=True"
        )
    PLACEMENTS[name] = schedule


def lookup_placement(name: str) -> PlacementSchedule:
    if name in PLACEMENTS:
        return PLACEMENTS[name]
    near = difflib.get_close_matches(name, sorted(PLACEMENTS), n=3, cutoff=0.4)
    hint = f"; did you mean {near}?" if near else ""
    raise ValueError(
        f"unknown placement scenario {name!r}{hint} "
        f"(known: {sorted(PLACEMENTS)})"
    )


# The union accepted by resolve_placement: a scenario name, a schedule, a
# pre-materialized stream, or None (identity/static placement).
PlacementSourceLike = str | PlacementSchedule | PlacementStream | None


def resolve_placement(
    source: PlacementSourceLike,
    n_epochs: int,
    topology: Topology | None = None,
) -> PlacementStream:
    """Lower any placement source to the canonical per-epoch stream.

    The ONE resolution path the simulator entry points call (mirroring
    `faults.resolve_faults`); the result is shape-validated so every
    source kind feeds the simulator the same program shape.
    """
    topo = topology if topology is not None else make_topology()
    if source is None:
        stream = static_placement(n_epochs, topo)
    elif isinstance(source, str):
        stream = lookup_placement(source).materialize(n_epochs, topo)
    elif isinstance(source, PlacementSchedule):
        stream = source.materialize(n_epochs, topo)
    elif isinstance(source, PlacementStream):
        stream = source
    else:
        raise TypeError(
            f"cannot resolve placement source of type {type(source).__name__}; "
            "expected a scenario name, PlacementSchedule, PlacementStream, "
            "or None"
        )
    expect = {
        "cls0": (n_epochs, topo.n_routers),
        "cls1": (n_epochs, topo.n_routers),
    }
    for f, shape in expect.items():
        leaf = getattr(stream, f)
        if tuple(leaf.shape) != shape:
            raise ValueError(
                f"placement stream leaf {f!r} has shape {tuple(leaf.shape)}, "
                f"expected {shape}"
            )
    return stream
