from repro.core.noc.topology import Topology, make_topology
from repro.core.noc.sim import NoCConfig, SimResult, simulate

__all__ = ["Topology", "make_topology", "NoCConfig", "SimResult", "simulate"]
