"""Flit-level router microarchitecture, fully vectorized over (subnet, router).

Models the paper's network (Fig. 6): per-input-port VC FIFOs with credit flow
control, XY routing, VC allocation at the downstream router constrained by
the class partition (Fig. 7), and switch allocation that is either
round-robin or the KF-triggered 2:1 GPU-priority pattern (Fig. 8).

Packed-lane state layout (DESIGN.md §11) — every subnet's buffers live in one
(S, R, P, V, B) block with narrow dtypes on the scan-bound hot loop:

  buf_meta  : (S, R, P, V, B) int16 — dest | src << 6 | cls << 12
  buf_binj  : (S, R, P, V, B) int32 — injection timestamp (network latency)
  head, count : (S, R, P, V)  int8
  rr_ptr      : (S, R, P)     int8  per-output RR pointer over P*V requesters

Generation timestamps (the old `buf_birth` chain: source queue -> request ->
MC queue -> reply) were carried end-to-end but never consumed by any counter
or metric — every latency figure uses the injection stamp `binj` (network
time, Fig. 11).  The dead chain was eliminated: on the memory-bound cycle
loop it cost a full int32 buffer in every peek/select/write.  Reintroduce a
`buf_birth` alongside a round-trip-latency metric if one is ever needed.

All packets are single-flit (DESIGN.md §8.2); B is the per-VC buffer depth
(paper: 4).  One traversal per output port and at most one per input port per
cycle (a crossbar has one input per port).

The cycle engine is SCATTER-FREE: every buffer write site has a unique,
statically-known source (the link into input port p of router r can only be
driven by `neighbor[r, p]`'s output port `opposite[p]`), so each update is a
dense masked `where` over the full state block instead of an XLA scatter.
XLA:CPU executes scatters as serial per-update loops, which made the old
formulation the dominant cost of the batched sweep; the dense form vectorizes
on CPU and maps directly onto accelerator lanes.

`arbitrate` is the pure switch-allocation inner loop (VC allocation +
per-output RR arbitration + grant filtering), shared by the default jnp path
and the Pallas kernel in `repro.kernels.noc_cycle` (which must agree with it
bitwise — see tests/test_cycle_engine.py).  This whole module is also the
per-stage ORACLE for the fused full-cycle lane kernel
(`repro.kernels.noc_cycle.fused`, DESIGN.md §13): `router_cycle` and
`inject_all` each have a lane twin (`router_stage_lanes`, `inject_lanes`)
that must reproduce them bitwise — including the garbage-value conventions
on ungranted outputs — so any semantic change here must land in the twin in
the same commit.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.noc.topology import N_PORTS, PORT_L, Topology

Array = jax.Array
BIG = jnp.int32(1 << 20)

# meta packing: dest | src << 6 | cls << 12 (needs R <= 64, cls in {0, 1})
META_SRC_SHIFT = 6
META_CLS_SHIFT = 12


def pack_meta(dest: Array, src: Array, cls: Array) -> Array:
    """Pack (dest, src, cls) into one int16 word (values are < 64 / < 64 / 1b)."""
    word = dest + (src << META_SRC_SHIFT) + (cls << META_CLS_SHIFT)
    return word.astype(jnp.int16)


def unpack_meta(meta: Array) -> tuple[Array, Array, Array]:
    """Inverse of `pack_meta`; returns int32 (dest, src, cls)."""
    w = meta.astype(jnp.int32)
    dest = w & ((1 << META_SRC_SHIFT) - 1)
    src = (w >> META_SRC_SHIFT) & ((1 << (META_CLS_SHIFT - META_SRC_SHIFT)) - 1)
    cls = w >> META_CLS_SHIFT
    return dest, src, cls


class SubnetState(NamedTuple):
    buf_meta: Array   # (S, R, P, V, B) int16 — dest | src<<6 | cls<<12
    buf_binj: Array   # (S, R, P, V, B) int32 injection timestamp (Fig. 11)
    head: Array       # (S, R, P, V) int8
    count: Array      # (S, R, P, V) int8
    rr_ptr: Array     # (S, R, P) int8 round-robin pointer over P*V index


class CycleEvents(NamedTuple):
    """Per-cycle outputs consumed by metrics / the MC model."""

    # ejected-at-local packets (<= 1 ejection/router/cycle per subnet)
    eject_valid: Array   # (S, R) bool
    eject_src: Array     # (S, R) int32
    eject_cls: Array     # (S, R) int32
    eject_binj: Array    # (S, R) int32 injection timestamp
    moved: Array         # () int32 — switch traversals this cycle (utilization)
    dram_block_gpu: Array  # () int32 — GPU ejections blocked by a full MC queue
    dram_block_cpu: Array  # () int32 — CPU ejections blocked by a full MC queue
    # flight-recorder probes (repro.obs, DESIGN.md §14): per-router switch
    # allocation outcomes, summed over output ports.  Dead code (free) when
    # probes are off; appended last so positional consumers stay valid.
    grant_cnt: Array     # (S, R) int32 — outputs granted this cycle
    deny_cnt: Array      # (S, R) int32 — requested outputs refused this cycle


class Arbitration(NamedTuple):
    """Outputs of the switch-allocation inner loop (shapes lead with `...`)."""

    grant: Array    # (..., O) bool — output port fires this cycle
    winner: Array   # (..., O) int32 — flat P*V requester index per output
    down_vc: Array  # (..., O) int32 — downstream VC granted to the winner
    deq: Array      # (..., P*V) bool — head packet pops this cycle
    new_rr: Array   # (..., O) int32 — advanced round-robin pointer
    any_req: Array  # (..., O) bool — some head packet wants this output
    w_cls: Array    # (..., O) int32 — class of the winning packet


def arbitrate(
    valid: Array,        # (..., P*V) bool — head packet present
    cls: Array,          # (..., P*V) int32 — head packet class (0/1)
    out_port: Array,     # (..., P*V) int32 — desired output port (XY route)
    rr_ptr: Array,       # (..., O) int32 — per-output RR pointer
    down_count: Array,   # (..., O, V) int32 — VC occupancy at the downstream
    down_exists: Array,  # (..., O) bool — a link exists through this output
    gpu_vc_mask: Array,  # (..., V) bool — VCs GPU packets may occupy
    cpu_vc_mask: Array,  # (..., V) bool
    sa_pref: Array,      # (...,) int32: -1 round-robin, else preferred class
    accept: Array,       # (...,) bool — ejection credit at the local sink
    active: Array,       # (...,) bool — link active (4-subnet: half width)
    *,
    depth: int,
) -> Arbitration:
    """One switch-allocation step: per (…, out_port) pick one (in_port, vc).

    Pure dense math (no gather/scatter): this is the function the Pallas
    `noc_cycle` kernel reimplements over a flattened lane axis, and the two
    must agree bitwise on every output.
    """
    PV = valid.shape[-1]
    oid = jnp.arange(N_PORTS, dtype=jnp.int32)
    pv = jnp.arange(PV, dtype=jnp.int32)
    pv16 = jnp.arange(PV, dtype=jnp.int16)
    big16 = jnp.int16(PV * (2 * PV + 1))  # > any live packed key

    # requester matrix + round-robin key relative to the per-output pointer.
    # The (..., PV, O) intermediates dominate this function's memory traffic,
    # so the key math runs in int16 (max packed value PV*(2PV)+PV-1 << 2^15).
    req = valid[..., :, None] & (out_port[..., :, None] == oid)   # (...,PV,O)
    # KF=1: prefer the pattern class first (paper Fig. 8, 2 GPU : 1 CPU);
    # the penalty is per requester (no O axis needed)
    is_pref = (cls == sa_pref[..., None]) | (sa_pref[..., None] < 0)
    penalty = jnp.where(is_pref, jnp.int16(0), jnp.int16(PV))     # (..., PV)
    key = (pv16[:, None] - rr_ptr.astype(jnp.int16)[..., None, :]) % PV
    key = key + penalty[..., :, None]
    # packed min == argmin (ties break to the lowest pv, like argmin)
    packed = jnp.where(req, key * PV + pv16[:, None], big16)
    m = jnp.min(packed, axis=-2).astype(jnp.int32)                # (..., O)
    winner = m % PV
    any_req = jnp.any(req, axis=-2)                               # (..., O)

    w_onehot = pv == winner[..., None]                            # (...,O,PV)
    w_cls = jnp.sum(jnp.where(w_onehot, cls[..., None, :], 0), axis=-1)

    # --- output-side credit check: first free VC the winner's class may use
    allowed = jnp.where((w_cls == 1)[..., None], gpu_vc_mask[..., None, :],
                        cpu_vc_mask[..., None, :])                # (...,O,V)
    has_space = (down_count < depth) & allowed
    down_vc = jnp.argmax(has_space, axis=-1).astype(jnp.int32)
    credit_ok = jnp.any(has_space, axis=-1)                       # (..., O)

    is_local = oid == PORT_L
    eject_ok = is_local & accept[..., None]
    link_ok = (~is_local) & down_exists & credit_ok
    grant = any_req & (eject_ok | link_ok) & active[..., None]    # (..., O)

    # --- one traversal per input port: keep the lowest-output grant per port
    w_port = winner // (PV // N_PORTS)                            # (..., O)
    rank = jnp.where(grant, oid, BIG)
    pmatch = w_port[..., None, :] == oid[:, None]                 # (...,P,O)
    min_rank = jnp.min(jnp.where(pmatch, rank[..., None, :], BIG), axis=-1)
    sel = jnp.sum(jnp.where(pmatch, min_rank[..., :, None], 0), axis=-2)
    grant = grant & (rank == sel)

    deq = jnp.any(w_onehot & grant[..., None], axis=-2)           # (...,PV)
    new_rr = jnp.where(grant, (winner + 1) % PV, rr_ptr)
    return Arbitration(grant, winner, down_vc, deq, new_rr, any_req, w_cls)


def router_cycle(
    state: SubnetState,
    topo_route: Array,      # (R, R) int32 device copy of topology.route
    topo_neighbor: Array,   # (R, P)
    topo_opposite: Array,   # (P,)
    gpu_vc_mask: Array,     # (S, V) bool — VCs GPU packets may occupy
    cpu_vc_mask: Array,     # (S, V) bool
    sa_pref_class: Array,   # () int32: -1 round-robin, else preferred class
    mc_can_accept: Array,   # (S, R) bool — ejection credit at local sink
    active: Array,          # (S,) bool — link active this cycle
    arbitrate_fn: Callable[..., Arbitration] = arbitrate,
    link_ok: Array | None = None,    # (R, P) bool — fault mask: port usable
    router_ok: Array | None = None,  # (R,) bool — fault mask: router granting
) -> tuple[SubnetState, CycleEvents]:
    """Advance every router of every subnet by one cycle.

    Fault masks (DESIGN.md §16) only ever AND into existing gates: a
    False ``link_ok[r, p]`` makes port p of router r look like a
    non-existent link (its head packets are never granted and
    back-pressure in place), a False ``router_ok[r]`` suppresses every
    grant at router r including local ejection (a brownout).  ``None``
    (or all-True) masks leave the program's values bit-for-bit unchanged.
    """
    S, R, P, V, B = state.buf_meta.shape
    ar = jnp.arange(R)

    # --- peek head-of-line packets -> (S, R, P, V) fields
    hidx = state.head.astype(jnp.int32)[..., None]
    meta = jnp.take_along_axis(state.buf_meta, hidx, axis=4)[..., 0]
    binj = jnp.take_along_axis(state.buf_binj, hidx, axis=4)[..., 0]
    dest, _, cls = unpack_meta(meta)
    valid = state.count > 0

    # --- route computation: desired output port of each head packet
    out_port = topo_route[ar[:, None, None], dest]                # (S,R,P,V)

    # --- downstream VC occupancy through each output (static-index gather)
    nb_safe = jnp.maximum(topo_neighbor, 0)                       # (R, O)
    opp_b = jnp.broadcast_to(topo_opposite[None, :], (R, N_PORTS))
    down_count = state.count[:, nb_safe, opp_b, :].astype(jnp.int32)
    usable = topo_neighbor >= 0
    if link_ok is not None:
        usable = usable & link_ok
    down_exists = jnp.broadcast_to(usable, (S, R, N_PORTS))
    granting = jnp.broadcast_to(active[:, None], (S, R))
    if router_ok is not None:
        granting = granting & router_ok[None, :]

    arb = arbitrate_fn(
        valid.reshape(S, R, P * V),
        cls.reshape(S, R, P * V),
        out_port.reshape(S, R, P * V),
        state.rr_ptr.astype(jnp.int32),
        down_count,
        down_exists,
        gpu_vc_mask[:, None, :],
        cpu_vc_mask[:, None, :],
        jnp.broadcast_to(sa_pref_class, (S, R)),
        mc_can_accept,
        granting,
        depth=B,
    )

    # --- apply: dequeue winners, advance RR pointers past them
    deq = arb.deq.reshape(S, R, P, V)
    head2 = jnp.where(deq, (state.head + 1) % B, state.head)
    count2 = state.count - deq.astype(jnp.int8)
    rr2 = arb.new_rr.astype(state.rr_ptr.dtype)

    # --- gather winner packet fields (S, R, O) — one-hot reduction over the
    # requester axis (vectorizes; dynamic gather at these indices does not)
    w_onehot = jnp.arange(P * V) == arb.winner[..., None]         # (S,R,O,PV)

    def gsel(x):  # x: (S, R, P, V) int — select the winner's field per output
        return jnp.sum(
            jnp.where(w_onehot, x.reshape(S, R, 1, P * V), 0), axis=-1,
            dtype=x.dtype,  # one-hot: a single term survives, no overflow
        )

    w_meta = gsel(meta.astype(jnp.int32))
    w_binj = gsel(binj)
    wd, ws, _ = unpack_meta(w_meta)

    # --- ejections: only the Local output column can eject (<=1 per router)
    ej = arb.grant[..., PORT_L]                                   # (S, R)
    blocked_local = arb.any_req[..., PORT_L] & ~mc_can_accept
    blocked_cls = arb.w_cls[..., PORT_L]
    events = CycleEvents(
        eject_valid=ej,
        eject_src=ws[..., PORT_L],
        eject_cls=arb.w_cls[..., PORT_L],
        eject_binj=w_binj[..., PORT_L],
        moved=jnp.sum(arb.grant.astype(jnp.int32)),
        dram_block_gpu=jnp.sum(
            (blocked_local & (blocked_cls == 1)).astype(jnp.int32)
        ),
        dram_block_cpu=jnp.sum(
            (blocked_local & (blocked_cls == 0)).astype(jnp.int32)
        ),
        grant_cnt=jnp.sum(arb.grant.astype(jnp.int32), axis=-1),
        deny_cnt=jnp.sum(
            (arb.any_req & ~arb.grant).astype(jnp.int32), axis=-1
        ),
    )

    # --- link traversals as a dense pull: input port p of router r can only
    # be driven by neighbor[r, p] through its output port opposite[p], so the
    # old scatter-enqueue is a static-index gather + masked where.
    lk = arb.grant & (jnp.arange(N_PORTS) != PORT_L)              # (S, R, O)

    def up(x):  # value at the (unique) upstream driver of each (r, p) input
        return x[:, nb_safe, opp_b]

    in_ok = up(lk) & (topo_neighbor >= 0)                         # (S, R, P)
    in_meta = up(w_meta)
    in_binj = up(w_binj)
    in_vc = up(arb.down_vc)

    tail = ((head2 + count2) % B).astype(jnp.int32)               # (S,R,P,V)
    vmask = in_ok[..., None] & (in_vc[..., None] == jnp.arange(V))
    bmask = vmask[..., None] & (tail[..., None] == jnp.arange(B))
    state3 = SubnetState(
        buf_meta=jnp.where(
            bmask, in_meta[..., None, None].astype(jnp.int16), state.buf_meta
        ),
        buf_binj=jnp.where(bmask, in_binj[..., None, None], state.buf_binj),
        head=head2,
        count=count2 + vmask.astype(jnp.int8),
        rr_ptr=rr2,
    )
    return state3, events


def inject_all(
    state: SubnetState,
    want: Array,         # (S, R) bool — one injection attempt per (subnet, router)
    dest: Array, src: Array, cls: Array,   # (S, R) int32 packet fields
    binj: Array,                           # (S, R) int32 injection timestamp
    gpu_vc_mask: Array, cpu_vc_mask: Array,  # (S, V) bool class VC partition
) -> tuple[SubnetState, Array]:
    """Inject at the Local input port of every (subnet, router) at once.

    Returns (state, accepted (S, R) bool).  Dense formulation of the old
    per-subnet scatter inject: pick the first free VC the class may use and
    write the tail slot with a masked where.
    """
    S, R, P, V, B = state.buf_meta.shape
    local_count = state.count[:, :, PORT_L]                       # (S, R, V)
    allowed = jnp.where(cls[..., None] == 1, gpu_vc_mask[:, None, :],
                        cpu_vc_mask[:, None, :])
    has_space = (local_count < B) & allowed
    vc = jnp.argmax(has_space, axis=-1).astype(jnp.int32)
    ok = want & jnp.any(has_space, axis=-1)

    head_l = state.head[:, :, PORT_L]
    tail = ((head_l + local_count) % B).astype(jnp.int32)         # (S, R, V)
    vmask = ok[..., None] & (vc[..., None] == jnp.arange(V))      # (S, R, V)
    bmask = vmask[..., None] & (tail[..., None] == jnp.arange(B))
    meta = pack_meta(dest, src, cls)

    def wr(buf, val):
        val = jnp.asarray(val).astype(buf.dtype)
        new_local = jnp.where(bmask, val[..., None, None], buf[:, :, PORT_L])
        return buf.at[:, :, PORT_L].set(new_local)

    state = state._replace(
        buf_meta=wr(state.buf_meta, meta),
        buf_binj=wr(state.buf_binj, binj),
        count=state.count.at[:, :, PORT_L].set(
            local_count + vmask.astype(jnp.int8)
        ),
    )
    return state, ok


def device_tables(topo: Topology):
    """Move topology tables onto device once per simulation.

    Since the placement layer (DESIGN.md §17) the static `node_type`
    table only seeds the physical `is_mc` mask — per-epoch node classes
    come from the traced placement stream, and routing/neighbor tables
    stay position-only (relocation moves a tile's CLASS, not its router).
    """
    assert topo.n_routers <= 64, "meta packing assumes router ids fit 6 bits"
    return (
        jnp.asarray(topo.route),
        jnp.asarray(topo.neighbor),
        jnp.asarray(topo.opposite),
        jnp.asarray(topo.node_type),
        jnp.asarray(topo.mc_ids),
    )
