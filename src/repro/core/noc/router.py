"""Flit-level router microarchitecture, fully vectorized over routers.

Models one subnet of the paper's network (Fig. 6): per-input-port VC FIFOs
with credit flow control, XY routing, VC allocation at the downstream router
constrained by the class partition (Fig. 7), and switch allocation that is
either round-robin or the KF-triggered 2:1 GPU-priority pattern (Fig. 8).

State layout (one subnet):
  buf_dest / buf_src / buf_cls / buf_birth : (R, P, V, B) int32 ring FIFOs
  head, count                              : (R, P, V)    int32
  rr_ptr                                   : (R, P)       int32  per-output RR pointer

All packets are single-flit (DESIGN.md §8.2); B is the per-VC buffer depth
(paper: 4).  One traversal per output port and at most one per input port per
cycle (a crossbar has one input per port).

The cycle function is pure: (state, masks, rng) -> (state, events); `sim.py`
wraps it in `lax.scan`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.noc.topology import N_PORTS, PORT_L, Topology

Array = jax.Array
BIG = jnp.int32(1 << 20)


class SubnetState(NamedTuple):
    buf_dest: Array   # (R, P, V, B)
    buf_src: Array
    buf_cls: Array
    buf_birth: Array  # generation timestamp (round-trip latency)
    buf_binj: Array   # injection timestamp (network latency, Fig. 11)
    head: Array       # (R, P, V)
    count: Array      # (R, P, V)
    rr_ptr: Array     # (R, P) round-robin pointer over P*V requester index


class CycleEvents(NamedTuple):
    """Per-cycle outputs consumed by metrics / the MC model."""

    # ejected-at-local packets, one slot per router (<=1 ejection/router/cycle)
    eject_valid: Array   # (R,) bool
    eject_dest: Array    # (R,) int32 (== router id when valid)
    eject_src: Array     # (R,)
    eject_cls: Array     # (R,)
    eject_birth: Array   # (R,) generation timestamp
    eject_binj: Array    # (R,) injection timestamp
    moved: Array         # () int32 — switch traversals this cycle (utilization)
    dram_block_gpu: Array  # () int32 — GPU ejections blocked by a full MC queue
    dram_block_cpu: Array  # () int32 — CPU ejections blocked by a full MC queue


def _peek_heads(state: SubnetState):
    """Gather head-of-line packet fields -> (R, P, V) each + validity."""
    idx = state.head[..., None]  # (R,P,V,1)
    dest = jnp.take_along_axis(state.buf_dest, idx, axis=3)[..., 0]
    src = jnp.take_along_axis(state.buf_src, idx, axis=3)[..., 0]
    cls = jnp.take_along_axis(state.buf_cls, idx, axis=3)[..., 0]
    birth = jnp.take_along_axis(state.buf_birth, idx, axis=3)[..., 0]
    binj = jnp.take_along_axis(state.buf_binj, idx, axis=3)[..., 0]
    valid = state.count > 0
    return dest, src, cls, birth, binj, valid


def _dequeue(state: SubnetState, deq_mask: Array) -> SubnetState:
    """deq_mask: (R, P, V) bool — pop head where True."""
    depth = state.buf_dest.shape[3]
    new_head = jnp.where(deq_mask, (state.head + 1) % depth, state.head)
    new_count = state.count - deq_mask.astype(jnp.int32)
    return state._replace(head=new_head, count=new_count)


def _enqueue_at(
    state: SubnetState,
    r: Array, p: Array, v: Array,          # (K,) flat target coordinates
    dest: Array, src: Array, cls: Array, birth: Array, binj: Array,
    valid: Array,                           # (K,) bool
) -> SubnetState:
    """Scatter-enqueue K packets at (r, p, v). Targets are unique when valid."""
    depth = state.buf_dest.shape[3]
    tail = (state.head[r, p, v] + state.count[r, p, v]) % depth
    # invalid writes get an out-of-bounds slot index: JAX scatter drops them,
    # so they can never race with a valid write to the same FIFO slot.
    tail = jnp.where(valid, tail, depth)

    def scat(buf, val):
        return buf.at[r, p, v, tail].set(val, mode="drop")

    state = state._replace(
        buf_dest=scat(state.buf_dest, dest),
        buf_src=scat(state.buf_src, src),
        buf_cls=scat(state.buf_cls, cls),
        buf_birth=scat(state.buf_birth, birth),
        buf_binj=scat(state.buf_binj, binj),
        count=state.count.at[r, p, v].add(valid.astype(jnp.int32)),
    )
    return state


def free_vc_for_class(
    count: Array, cls_allowed_mask: Array, depth: int
) -> tuple[Array, Array]:
    """Pick the lowest-index allowed VC with space at each (R, P).

    count: (R, P, V); cls_allowed_mask: (R, P, V) bool (class partition).
    Returns (vc_index (R,P) int32, available (R,P) bool).
    """
    has_space = (count < depth) & cls_allowed_mask
    vc = jnp.argmax(has_space, axis=-1).astype(jnp.int32)
    return vc, jnp.any(has_space, axis=-1)


def router_cycle(
    state: SubnetState,
    topo_route: Array,      # (R, R) int32 device copy of topology.route
    topo_neighbor: Array,   # (R, P)
    topo_opposite: Array,   # (P,)
    gpu_vc_mask: Array,     # (V,) bool — VCs GPU packets may occupy
    cpu_vc_mask: Array,     # (V,) bool
    sa_pref_class: Array,   # () int32: -1 round-robin, else preferred class
    mc_can_accept: Array,   # (R,) bool — ejection credit at local sink
    active: Array,          # () bool — link active this cycle (4-subnet: half width)
) -> tuple[SubnetState, CycleEvents]:
    R, P, V, B = state.buf_dest.shape
    dest, src, cls, birth, binj, valid = _peek_heads(state)  # (R,P,V)

    # --- route computation: desired output port of each head packet
    out_port = topo_route[jnp.arange(R)[:, None, None], dest]   # (R,P,V)

    # --- switch allocation: per (router, out_port), pick one (in_port, vc)
    flat = valid.reshape(R, P * V)
    flat_cls = cls.reshape(R, P * V)
    req = jnp.zeros((R, P * V, N_PORTS), bool).at[
        jnp.arange(R)[:, None], jnp.arange(P * V)[None, :],
        out_port.reshape(R, P * V),
    ].set(flat)

    # round-robin key relative to per-output pointer
    idx = jnp.arange(P * V, dtype=jnp.int32)
    key = (idx[None, :, None] - state.rr_ptr[:, None, :]) % (P * V)  # (R,PV,O)
    # KF=1: prefer the pattern class first (paper Fig. 8, 2 GPU : 1 CPU)
    is_pref = (flat_cls[:, :, None] == sa_pref_class) | (sa_pref_class < 0)
    key = key + jnp.where(is_pref, 0, P * V)
    key = jnp.where(req, key, BIG)
    winner = jnp.argmin(key, axis=1).astype(jnp.int32)            # (R, O)
    any_req = jnp.any(req, axis=1)                                 # (R, O)

    # --- output-side credit checks
    out_ids = jnp.arange(N_PORTS)
    w_cls = flat_cls[jnp.arange(R)[:, None], winner]               # (R, O)
    down_r = topo_neighbor[jnp.arange(R)[:, None], out_ids[None, :]]  # (R,O)
    down_p = topo_opposite[out_ids][None, :].astype(jnp.int32)     # (1, O) -> bcast
    down_r_safe = jnp.maximum(down_r, 0)

    allowed = jnp.where(w_cls[..., None] == 1, gpu_vc_mask[None, None, :],
                        cpu_vc_mask[None, None, :])                # (R,O,V)
    down_count = state.count[down_r_safe, jnp.broadcast_to(down_p, down_r.shape)]
    has_space = (down_count < B) & allowed                         # (R,O,V)
    down_vc = jnp.argmax(has_space, axis=-1).astype(jnp.int32)
    credit_ok = jnp.any(has_space, axis=-1)                        # (R,O)

    is_local = out_ids[None, :] == PORT_L
    # local ejection needs the sink (node / MC queue) to accept
    eject_ok = is_local & mc_can_accept[:, None]
    link_ok = (~is_local) & (down_r >= 0) & credit_ok
    grant = any_req & (eject_ok | link_ok) & active                # (R,O)

    # --- one traversal per input port: keep the lowest-output grant per port
    w_port = winner // V                                           # (R,O)
    o_rank = jnp.arange(N_PORTS)[None, :].astype(jnp.int32)
    rank = jnp.where(grant, o_rank, BIG)
    # min output index per (router, input port)
    min_rank = jnp.full((R, N_PORTS), BIG, jnp.int32).at[
        jnp.arange(R)[:, None], w_port
    ].min(rank)
    grant = grant & (rank == min_rank[jnp.arange(R)[:, None], w_port])

    # --- apply: dequeue winners
    deq = jnp.zeros((R, P * V), bool).at[
        jnp.arange(R)[:, None], winner
    ].max(grant)
    state2 = _dequeue(state, deq.reshape(R, P, V))

    # advance RR pointer past the winner on granted outputs
    new_ptr = jnp.where(grant, (winner + 1) % (P * V), state.rr_ptr)
    state2 = state2._replace(rr_ptr=new_ptr)

    # --- gather winner packet fields (R, O)
    def g(x):
        return x.reshape(R, P * V)[jnp.arange(R)[:, None], winner]

    wd, ws, wc, wb = g(dest), g(src), g(cls), g(birth)
    wj = g(binj)

    # --- ejections (out_port == Local): <= 1 per router by construction
    ej = grant & is_local
    eject_valid = jnp.any(ej, axis=1)
    ej_slot = jnp.argmax(ej, axis=1)
    ar = jnp.arange(R)
    # dramfull stalls: a head packet wants to eject but the sink is full
    blocked_local = any_req & is_local & ~mc_can_accept[:, None]
    events = CycleEvents(
        eject_valid=eject_valid,
        eject_dest=wd[ar, ej_slot],
        eject_src=ws[ar, ej_slot],
        eject_cls=wc[ar, ej_slot],
        eject_birth=wb[ar, ej_slot],
        eject_binj=wj[ar, ej_slot],
        moved=jnp.sum(grant.astype(jnp.int32)),
        dram_block_gpu=jnp.sum((blocked_local & (w_cls == 1)).astype(jnp.int32)),
        dram_block_cpu=jnp.sum((blocked_local & (w_cls == 0)).astype(jnp.int32)),
    )

    # --- link traversals: enqueue at downstream (r', opposite port, chosen vc)
    lk = (grant & ~is_local).reshape(-1)
    state3 = _enqueue_at(
        state2,
        down_r_safe.reshape(-1),
        jnp.broadcast_to(down_p, down_r.shape).reshape(-1),
        down_vc.reshape(-1),
        wd.reshape(-1), ws.reshape(-1), wc.reshape(-1), wb.reshape(-1),
        wj.reshape(-1),
        lk,
    )
    return state3, events


def inject(
    state: SubnetState,
    r_ids: Array,        # (K,) routers attempting one injection each
    want: Array,         # (K,) bool
    dest: Array, src: Array, cls: Array, birth: Array, binj: Array,
    gpu_vc_mask: Array, cpu_vc_mask: Array,
) -> tuple[SubnetState, Array]:
    """Inject at the Local input port, honoring the class VC partition.

    Returns (state, accepted (K,) bool).  r_ids must be unique (one attempt
    per router per cycle — sources queue internally otherwise).
    """
    V = state.count.shape[2]
    B = state.buf_dest.shape[3]
    local_count = state.count[r_ids, PORT_L]                       # (K, V)
    allowed = jnp.where(cls[:, None] == 1, gpu_vc_mask[None, :],
                        cpu_vc_mask[None, :])
    has_space = (local_count < B) & allowed
    vc = jnp.argmax(has_space, axis=-1).astype(jnp.int32)
    ok = want & jnp.any(has_space, axis=-1)
    state = _enqueue_at(
        state, r_ids, jnp.full_like(r_ids, PORT_L), vc,
        dest, src, cls, birth, binj, ok,
    )
    return state, ok


def device_tables(topo: Topology):
    """Move topology tables onto device once per simulation."""
    return (
        jnp.asarray(topo.route),
        jnp.asarray(topo.neighbor),
        jnp.asarray(topo.opposite),
        jnp.asarray(topo.node_type),
        jnp.asarray(topo.mc_ids),
    )
