"""Cycle-stepped heterogeneous-chiplet NoC simulation with the KF in the loop.

Reproduces the paper's evaluation pipeline end to end:

  traffic sources -> routers (VC alloc + switch alloc) -> MCs -> replies
        ^                                                          |
        '------ per-epoch counters -> Kalman Filter -> policy <----'

Four network configurations (paper §4.2):
  * ``baseline``  — 2 subnets (req/reply), VCs fully shared, round-robin SA.
  * ``fair``      — 2 subnets, static 2:2 VC partition between GPU and CPU.
  * ``4subnet``   — physical segregation: {CPU,GPU} x {req,reply}; each
                    subnet gets half link width (modeled as alternating-cycle
                    link activation) and half the VCs.
  * ``kf``        — 2 subnets + Kalman-Filter-driven reconfiguration of the
                    VC partition (2:2 <-> 3:1) and switch arbitration
                    (RR <-> GPU,GPU,CPU pattern), with the paper's
                    warmup / hold / revert hysteresis.
  * ``static``    — fixed [gpu:cpu] VC partition, for the Fig. 2/3 sweep.

The whole run is one jitted ``lax.scan`` over epochs with an inner scan over
cycles; 36 routers x 4 VCs x depth 4 keeps per-cycle tensors tiny.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kalman
from repro.core.allocator import (
    PolicyConfig,
    PolicyState,
    apply_policy,
    init_policy_state,
    sa_priority_pattern,
    vc_partition,
)
from repro.core.noc import metrics
from repro.core.noc import router as rt
from repro.core.noc.topology import make_topology
from repro.core.noc.traffic import (
    PROFILES,
    WorkloadProfile,
    init_phase,
    injection_rates,
    step_phase,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NoCConfig:
    mode: str = "kf"              # baseline | fair | 4subnet | kf | static
    static_gpu_vcs: int = 2       # for mode=static: GPU gets [g : V-g]
    n_vcs: int = 4                # per input port per subnet (2-subnet modes)
    buf_depth: int = 4            # packets per VC (paper: 4)
    epoch_len: int = 500          # cycles per KF epoch
    n_epochs: int = 120
    # DRAM is the scarce shared resource (paper §2.1: "CPU packets pile up at
    # MCs which already have many GPU packets waiting").  Total DRAM service
    # is 8 MCs / 2 cycles = 4 pkt/cycle vs ~7.3 offered during bursts; the
    # NoC's VC partition + switch priority decide *admission* into MC queues,
    # which is exactly the lever the paper's KF reconfigures.
    mc_queue_cap: int = 16
    mc_service_period: int = 2    # cycles per serviced request per MC
    mshr_limit: int = 16          # max outstanding requests per node (MSHRs)
    policy: PolicyConfig = PolicyConfig()
    # normalization scales for KF observations (counters per epoch)
    z_scales: tuple[float, float, float] = (300.0, 160.0, 2500.0)
    kf_q: float = 1e-3
    kf_r: float = 2e-1
    seed: int = 0

    @property
    def n_subnets(self) -> int:
        return 4 if self.mode == "4subnet" else 2

    @property
    def vcs_per_subnet(self) -> int:
        return self.n_vcs // 2 if self.mode == "4subnet" else self.n_vcs


class MCState(NamedTuple):
    q_src: Array      # (R, Q) pending request sources
    q_cls: Array
    q_birth: Array    # generation timestamp of the original request
    head: Array       # (R,)
    count: Array      # (R,)
    timer: Array      # (R,) cycles until current service completes
    stage_valid: Array  # (R,) staged reply waiting to inject
    stage_dst: Array
    stage_cls: Array
    stage_birth: Array


class EpochCounters(NamedTuple):
    gpu_push: Array           # GPU request injections accepted
    gpu_stall_icnt: Array     # GPU node-cycles blocked at MSHR/injection
    gpu_stall_dram: Array     # GPU dramfull events
    cpu_push: Array
    gpu_done: Array           # completed GPU transactions
    cpu_done: Array
    gpu_gen: Array            # generated GPU demand
    cpu_gen: Array
    lat_sum: Array            # all ejected packets: sum of network latency
    lat_cnt: Array
    cpu_lat_sum: Array        # per-class NETWORK latency of ejected packets
    cpu_lat_cnt: Array        # (excludes DRAM queue wait: the NoC's own share)
    gpu_lat_sum: Array
    gpu_lat_cnt: Array
    moved: Array


def _zero_counters() -> EpochCounters:
    z = jnp.int32(0)
    return EpochCounters(z, z, z, z, z, z, z, z, z, z, z, z, z, z, z)


class SimResult(NamedTuple):
    gpu_ipc: Array        # (E,) per-epoch GPU IPC proxy
    cpu_ipc: Array        # (E,)
    avg_latency: Array    # (E,) mean packet network latency
    kf_signal: Array      # (E,) binarized KF output
    applied_config: Array  # (E,) configuration actually applied
    counters: EpochCounters  # (E,) leaves
    gpu_inj_rate: Array   # (E,) offered GPU load (Fig. 4 trace)


def _class_masks(cfg: NoCConfig, config_idx: Array, n_vcs: int):
    """(S, V) boolean masks for GPU / CPU occupancy per subnet."""
    if cfg.mode == "baseline":
        g = jnp.ones((n_vcs,), bool)
        c = jnp.ones((n_vcs,), bool)
    elif cfg.mode == "fair":
        g, c = vc_partition(jnp.int32(0), n_vcs)
    elif cfg.mode == "static":
        idx = jnp.arange(n_vcs)
        g = idx < cfg.static_gpu_vcs
        c = ~g
    elif cfg.mode == "kf":
        g, c = vc_partition(config_idx, n_vcs)
    elif cfg.mode == "4subnet":
        # physical segregation: within a subnet every VC belongs to its class
        g = jnp.ones((n_vcs,), bool)
        c = jnp.ones((n_vcs,), bool)
    else:
        raise ValueError(cfg.mode)
    S = cfg.n_subnets
    return jnp.broadcast_to(g, (S, n_vcs)), jnp.broadcast_to(c, (S, n_vcs))


def _make_kf(cfg: NoCConfig):
    return kalman.paper_params(q=cfg.kf_q, r=cfg.kf_r)


@functools.partial(jax.jit, static_argnames=("cfg", "profile"))
def simulate(cfg: NoCConfig, profile: WorkloadProfile) -> SimResult:
    topo = make_topology()
    route_t, nb_t, opp_t, ntype, mc_ids = rt.device_tables(topo)
    R = topo.n_routers
    S = cfg.n_subnets
    V = cfg.vcs_per_subnet
    B = cfg.buf_depth

    is_mc = ntype == 2
    is_gpu = ntype == 1
    is_cpu = ntype == 0
    node_cls = jnp.where(is_gpu, 1, 0)  # class a node's own traffic belongs to

    # subnet routing of a node's traffic: (request_subnet, reply_subnet)
    if cfg.mode == "4subnet":
        req_sub = 2 * node_cls
        rep_sub = 2 * node_cls + 1
    else:
        req_sub = jnp.zeros((R,), jnp.int32)
        rep_sub = jnp.ones((R,), jnp.int32)

    subnets0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[rt.init_subnet(R, V, B) for _ in range(S)],
    )
    mc0 = MCState(
        q_src=jnp.zeros((R, cfg.mc_queue_cap), jnp.int32),
        q_cls=jnp.zeros((R, cfg.mc_queue_cap), jnp.int32),
        q_birth=jnp.zeros((R, cfg.mc_queue_cap), jnp.int32),
        head=jnp.zeros((R,), jnp.int32),
        count=jnp.zeros((R,), jnp.int32),
        timer=jnp.zeros((R,), jnp.int32),
        stage_valid=jnp.zeros((R,), bool),
        stage_dst=jnp.zeros((R,), jnp.int32),
        stage_cls=jnp.zeros((R,), jnp.int32),
        stage_birth=jnp.zeros((R,), jnp.int32),
    )

    kf_params = _make_kf(cfg)
    z_scales = jnp.asarray(cfg.z_scales, jnp.float32)

    vmapped_cycle = jax.vmap(
        rt.router_cycle, in_axes=(0, None, None, None, 0, 0, None, 0, 0)
    )

    BCAP = 64  # per-node source-queue (shader/LSQ) capacity

    def cycle_body(carry, cycle_key):
        (subs, mc, phase, outstanding, backlog, cnt, policy, cycle) = carry
        bl_birth, bl_head, bl_count = backlog
        key = cycle_key
        k_phase, k_gen, k_dest = jax.random.split(key, 3)

        config_idx = policy.config
        gpu_masks, cpu_masks = _class_masks(cfg, config_idx, V)
        sa_pref = (
            sa_priority_pattern(config_idx, cycle)
            if cfg.mode == "kf"
            else jnp.int32(-1)
        )

        # subnet link activation: full width (2-subnet) or alternating (4-subnet)
        if cfg.mode == "4subnet":
            active = (cycle % 2) == (jnp.arange(S) % 2)
        else:
            active = jnp.ones((S,), bool)

        # MC acceptance applies to ejections on *request* subnets at MC nodes.
        # With multiple request subnets (4-subnet mode) up to S/2 packets can
        # arrive at one MC in a cycle, so reserve that many slots.
        if cfg.mode == "4subnet":
            sub_is_req = np.asarray([True, False, True, False])
            n_req_subs = 2
        else:
            sub_is_req = np.asarray([True, False])
            n_req_subs = 1
        mc_space = mc.count <= cfg.mc_queue_cap - n_req_subs
        can_accept = jnp.where(is_mc, mc_space, True)  # (R,)
        accept_s = jnp.where(sub_is_req[:, None], can_accept[None, :], True)

        # ---- 1. MC: inject staged replies into the reply subnet(s)
        new_subs = subs
        inj_ok_all = jnp.zeros((R,), bool)
        for s in range(S):
            sub_s = jax.tree.map(lambda x: x[s], new_subs)
            if cfg.mode == "4subnet":
                # reply subnet is determined by the requester's class
                want = mc.stage_valid & is_mc & (2 * mc.stage_cls + 1 == s)
            else:
                want = mc.stage_valid & is_mc & (s == 1)
            sub_s, ok = rt.inject(
                sub_s,
                jnp.arange(R),
                want,
                mc.stage_dst,
                jnp.arange(R),
                mc.stage_cls,
                mc.stage_birth,
                jnp.full((R,), cycle, jnp.int32),
                gpu_masks[s],
                cpu_masks[s],
            )
            new_subs = jax.tree.map(
                lambda full, part: full.at[s].set(part), new_subs, sub_s
            )
            inj_ok_all = inj_ok_all | ok
        mc = mc._replace(stage_valid=mc.stage_valid & ~inj_ok_all)

        # ---- 2. MC service: tick timers, move head request -> staging
        can_serve = is_mc & (mc.count > 0) & ~mc.stage_valid
        timer = jnp.where(can_serve, jnp.maximum(mc.timer - 1, 0), mc.timer)
        done = can_serve & (timer == 0)
        hq = mc.head
        src_out = mc.q_src[jnp.arange(R), hq]
        cls_out = mc.q_cls[jnp.arange(R), hq]
        birth_out = mc.q_birth[jnp.arange(R), hq]
        mc = mc._replace(
            head=jnp.where(done, (mc.head + 1) % cfg.mc_queue_cap, mc.head),
            count=mc.count - done.astype(jnp.int32),
            timer=jnp.where(done, cfg.mc_service_period, timer),
            stage_valid=mc.stage_valid | done,
            stage_dst=jnp.where(done, src_out, mc.stage_dst),
            stage_cls=jnp.where(done, cls_out, mc.stage_cls),
            stage_birth=jnp.where(done, birth_out, mc.stage_birth),
        )

        # ---- 3. route/arbitrate every subnet
        new_subs, events = vmapped_cycle(
            new_subs, route_t, nb_t, opp_t,
            gpu_masks, cpu_masks, sa_pref, accept_s, active,
        )

        # ---- 4. ejection handling
        # request-subnet ejections at MC nodes -> enqueue into MC queue,
        # sequentially per subnet (4-subnet mode can deliver two per cycle;
        # `mc_space` reserved slots for all of them above).
        req_ej = events.eject_valid & sub_is_req[:, None] & is_mc[None, :]  # (S,R)
        for s in range(S):
            if not bool(sub_is_req[s]):
                continue
            arrive = req_ej[s]
            tail = (mc.head + mc.count) % cfg.mc_queue_cap
            mc = mc._replace(
                q_src=mc.q_src.at[jnp.arange(R), tail].set(
                    jnp.where(arrive, events.eject_src[s],
                              mc.q_src[jnp.arange(R), tail])
                ),
                q_cls=mc.q_cls.at[jnp.arange(R), tail].set(
                    jnp.where(arrive, events.eject_cls[s],
                              mc.q_cls[jnp.arange(R), tail])
                ),
                q_birth=mc.q_birth.at[jnp.arange(R), tail].set(
                    jnp.where(arrive, events.eject_birth[s],
                              mc.q_birth[jnp.arange(R), tail])
                ),
                count=mc.count + arrive.astype(jnp.int32),
            )
        # reply-subnet ejections at source nodes -> complete transactions
        rep_ej = events.eject_valid & (~sub_is_req)[:, None] & (~is_mc)[None, :]
        rep_done = jnp.any(rep_ej, axis=0)
        outstanding = outstanding - rep_done.astype(jnp.int32)
        rep_cls = jnp.sum(jnp.where(rep_ej, events.eject_cls, 0), axis=0)

        # Fig. 11 packet latency: network time (injection -> ejection)
        ej_lat = jnp.where(events.eject_valid, cycle - events.eject_binj, 0)
        cpu_ej = events.eject_valid & (events.eject_cls == 0)
        gpu_ej = events.eject_valid & (events.eject_cls == 1)

        # ---- 5. source injection (generation -> birth-stamped source queue)
        phase = step_phase(profile, phase, k_phase)
        rates = injection_rates(profile, ntype, phase)
        gen = jax.random.bernoulli(k_gen, rates)  # (R,) new demand this cycle
        gen = gen & ~is_mc
        # push into the per-node source queue (drop + stall if full)
        can_push = gen & (bl_count < BCAP)
        tail = (bl_head + bl_count) % BCAP
        tail = jnp.where(can_push, tail, BCAP)  # OOB -> dropped write
        bl_birth = bl_birth.at[jnp.arange(R), tail].set(
            jnp.full((R,), cycle, jnp.int32), mode="drop"
        )
        bl_count = bl_count + can_push.astype(jnp.int32)

        can_inj = (bl_count > 0) & (outstanding < cfg.mshr_limit) & ~is_mc
        dests = jnp.take(
            mc_ids, jax.random.randint(k_dest, (R,), 0, mc_ids.shape[0])
        )
        births = bl_birth[jnp.arange(R), bl_head]  # packet birth = generation
        inj_ok = jnp.zeros((R,), bool)
        for s in range(S):
            sub_s = jax.tree.map(lambda x: x[s], new_subs)
            want = can_inj & (req_sub == s)
            sub_s, ok = rt.inject(
                sub_s, jnp.arange(R), want, dests, jnp.arange(R),
                node_cls, births, jnp.full((R,), cycle, jnp.int32),
                gpu_masks[s], cpu_masks[s],
            )
            new_subs = jax.tree.map(
                lambda full, part: full.at[s].set(part), new_subs, sub_s
            )
            inj_ok = inj_ok | ok
        bl_head = jnp.where(inj_ok, (bl_head + 1) % BCAP, bl_head)
        bl_count = bl_count - inj_ok.astype(jnp.int32)
        outstanding = outstanding + inj_ok.astype(jnp.int32)
        backlog = (bl_birth, bl_head, bl_count)

        # ---- 6. counters
        gpu_blocked = is_gpu & (bl_count > 0)  # shader waiting on the ICNT
        cnt = EpochCounters(
            gpu_push=cnt.gpu_push + jnp.sum((inj_ok & is_gpu).astype(jnp.int32)),
            gpu_stall_icnt=cnt.gpu_stall_icnt
            + jnp.sum(gpu_blocked.astype(jnp.int32)),
            gpu_stall_dram=cnt.gpu_stall_dram + jnp.sum(events.dram_block_gpu),
            cpu_push=cnt.cpu_push + jnp.sum((inj_ok & is_cpu).astype(jnp.int32)),
            gpu_done=cnt.gpu_done
            + jnp.sum((rep_done & (rep_cls == 1)).astype(jnp.int32)),
            cpu_done=cnt.cpu_done
            + jnp.sum((rep_done & (rep_cls == 0)).astype(jnp.int32)),
            gpu_gen=cnt.gpu_gen + jnp.sum((gen & is_gpu).astype(jnp.int32)),
            cpu_gen=cnt.cpu_gen + jnp.sum((gen & is_cpu).astype(jnp.int32)),
            lat_sum=cnt.lat_sum + jnp.sum(ej_lat),
            lat_cnt=cnt.lat_cnt + jnp.sum(events.eject_valid.astype(jnp.int32)),
            cpu_lat_sum=cnt.cpu_lat_sum
            + jnp.sum(jnp.where(cpu_ej, ej_lat, 0)),
            cpu_lat_cnt=cnt.cpu_lat_cnt + jnp.sum(cpu_ej.astype(jnp.int32)),
            gpu_lat_sum=cnt.gpu_lat_sum
            + jnp.sum(jnp.where(gpu_ej, ej_lat, 0)),
            gpu_lat_cnt=cnt.gpu_lat_cnt + jnp.sum(gpu_ej.astype(jnp.int32)),
            moved=cnt.moved + jnp.sum(events.moved),
        )
        return (
            (new_subs, mc, phase, outstanding, backlog, cnt, policy, cycle + 1),
            None,
        )

    def epoch_body(carry, epoch_key):
        subs, mc, phase, outst, backlog, policy, kf_state, cycle = carry
        keys = jax.random.split(epoch_key, cfg.epoch_len)
        inner0 = (subs, mc, phase, outst, backlog, _zero_counters(), policy, cycle)
        (subs, mc, phase, outst, backlog, cnt, policy, cycle), _ = jax.lax.scan(
            cycle_body, inner0, keys
        )

        # ---- KF epoch update (paper §3.2)
        raw = jnp.stack(
            [
                cnt.gpu_stall_dram.astype(jnp.float32),
                cnt.gpu_push.astype(jnp.float32),
                cnt.gpu_stall_icnt.astype(jnp.float32),
            ]
        )
        z = kalman.normalize_observations(raw, jnp.zeros(3), z_scales)
        kf_state, _, _ = kalman.step(kf_params, kf_state, z)
        signal = kalman.binarize(kf_state.x[0])
        if cfg.mode == "kf":
            policy = apply_policy(cfg.policy, policy, signal, cycle)

        # ---- IPC proxies (documented in metrics.py)
        gpu_ipc = metrics.gpu_ipc_proxy(
            cnt.gpu_done.astype(jnp.float32), cnt.gpu_gen.astype(jnp.float32)
        )
        cpu_lat = cnt.cpu_lat_sum / jnp.maximum(cnt.cpu_lat_cnt, 1)
        cpu_ipc = metrics.cpu_ipc_proxy(cpu_lat)
        avg_lat = cnt.lat_sum / jnp.maximum(cnt.lat_cnt, 1)
        inj_rate = (cnt.gpu_push.astype(jnp.float32)
                    / (cfg.epoch_len * jnp.sum(is_gpu)))

        out = (gpu_ipc, cpu_ipc, avg_lat, signal, policy.config, cnt, inj_rate)
        return (subs, mc, phase, outst, backlog, policy, kf_state, cycle), out

    key0 = jax.random.PRNGKey(cfg.seed)
    epoch_keys = jax.random.split(key0, cfg.n_epochs)
    backlog0 = (
        jnp.zeros((R, 64), jnp.int32),   # birth ring buffer (BCAP=64)
        jnp.zeros((R,), jnp.int32),      # head
        jnp.zeros((R,), jnp.int32),      # count
    )
    carry0 = (
        subnets0,
        mc0,
        init_phase(),
        jnp.zeros((R,), jnp.int32),
        backlog0,
        init_policy_state(),
        kalman.init_state(1),
        jnp.int32(0),
    )
    _, (gpu_ipc, cpu_ipc, avg_lat, sig, conf, cnt, inj) = jax.lax.scan(
        epoch_body, carry0, epoch_keys
    )
    return SimResult(
        gpu_ipc=gpu_ipc,
        cpu_ipc=cpu_ipc,
        avg_latency=avg_lat,
        kf_signal=sig,
        applied_config=conf,
        counters=cnt,
        gpu_inj_rate=inj,
    )


def run_workload(mode: str, workload: str, **overrides) -> SimResult:
    cfg = NoCConfig(mode=mode, **overrides)
    return simulate(cfg, PROFILES[workload])


def summarize(res: SimResult, warmup_epochs: int = 10) -> dict:
    sl = slice(warmup_epochs, None)
    return {
        "gpu_ipc": float(jnp.mean(res.gpu_ipc[sl])),
        "cpu_ipc": float(jnp.mean(res.cpu_ipc[sl])),
        "avg_latency": float(jnp.mean(res.avg_latency[sl])),
        "kf_on_frac": float(jnp.mean(res.applied_config[sl])),
    }
