"""Cycle-stepped heterogeneous-chiplet NoC simulation with the KF in the loop.

Reproduces the paper's evaluation pipeline end to end:

  traffic sources -> routers (VC alloc + switch alloc) -> MCs -> replies
        ^                                                          |
        '------ per-epoch counters -> Kalman Filter -> policy <----'

Four network configurations (paper §4.2):
  * ``baseline``  — 2 subnets (req/reply), VCs fully shared, round-robin SA.
  * ``fair``      — 2 subnets, static 2:2 VC partition between GPU and CPU.
  * ``4subnet``   — physical segregation: {CPU,GPU} x {req,reply}; each
                    subnet gets half link width (modeled as alternating-cycle
                    link activation) and half the VCs.
  * ``kf``        — 2 subnets + Kalman-Filter-driven reconfiguration of the
                    VC partition (2:2 <-> 3:1) and switch arbitration
                    (RR <-> GPU,GPU,CPU pattern), with the paper's
                    warmup / hold / revert hysteresis.
  * ``static``    — fixed [gpu:cpu] VC partition, for the Fig. 2/3 sweep.

The whole run is one jitted ``lax.scan`` over epochs with an inner scan over
cycles; 36 routers x 4 VCs x depth 4 keeps per-cycle tensors tiny.

Batched sweep engine (DESIGN.md §4, §10)
----------------------------------------
``mode``, the static VC ratio, the workload rates, the seed, AND the subnet
structure are all *traced* data (`allocator.ModePolicy` tensors +
`traffic.WorkloadProfile` pytrees): every configuration's subnet axis is
padded to ``S_MAX`` (padded subnets are zero-width — never injected into,
links never active) and the 4-subnet network's 2 VCs/subnet ride a V-padded
axis with the upper VCs masked off, so 2-subnet and 4-subnet configurations
share ONE compiled program.  ``simulate_batch`` vmaps that program over a
leading batch axis (configs x workloads x seeds evaluated in lockstep, with
donated carry buffers) and can shard that axis data-parallel across devices
(``devices=``/``mesh=``, via the `repro.dist.sharding.shard_map` shim);
``sweep`` / ``sweep_sharded`` are the drivers the paper-figure benchmarks
run on.

Predictor ablation + scenario schedules (DESIGN.md §12): the epoch-boundary
reconfiguration signal comes from a traced predictor *bank*
(`repro.core.predictor` — KF / EMA / last-value / always-on / always-off,
selected by `ModePolicy.predictor.kind`), and workloads — stationary or
`traffic.ScenarioSchedule` programs — are materialized to per-epoch
parameter rows consumed through the epoch scan's `xs`, so the whole
ablation x scenario grid still costs the ONE compiled program.

Traffic sources (DESIGN.md §15): every entry point accepts any
`traffic.TrafficSource` — a workload name, `WorkloadProfile`,
`ScenarioSchedule`, or a replayed `RecordedTrace` — and lowers it through
the single `traffic.resolve_source` path to the canonical per-epoch
`EpochDemand` rows, so recorded/adapted traces reuse the same compiled
program as synthetic generators.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kalman, predictor
from repro.core.allocator import (
    ModePolicy,
    PolicyConfig,
    apply_policy_gated,
    class_vc_masks,
    epoch_sa_prefs,
    init_policy_state,
    mode_policy,
    placement_class,
)
from repro.core.allocator import degrade_policy
from repro.core.noc import metrics
from repro.core.noc import router as rt
from repro.core.noc.faults import (
    TELEM_DROP,
    TELEM_NAN,
    TELEM_SPIKE,
    FaultSourceLike,
    FaultStream,
    resolve_faults,
)
from repro.core.noc.placement import (
    PlacementSourceLike,
    PlacementStream,
    resolve_placement,
)
from repro.core.noc.topology import make_topology
from repro.obs.probes import ProbeConfig, SimTrace
from repro.core.noc.traffic import (
    TrafficSource,
    TrafficSourceLike,
    WorkloadProfile,
    init_phase,
    injection_rates,
    resolve_source,
    stack_profiles,
    step_phase_u,
)

Array = jax.Array

BCAP = 64  # per-node source-queue (shader/LSQ) capacity

# Padded subnet-axis length shared by every mode's program (DESIGN.md §10):
# large enough for the 4-subnet network; 2-subnet modes leave rows 2..3
# zero-width (never injected into, links never active).
S_MAX = 4


@dataclasses.dataclass(frozen=True)
class SimStatic:
    """The structural (compile-time) part of a simulation config.

    Everything the XLA program *shape* depends on.  Deliberately excludes
    ``mode`` — including its subnet structure, which since the S-padding
    refactor (DESIGN.md §10) is traced `ModePolicy` data over a padded
    (``n_subnets``, ..., ``n_vcs``) state — plus the static VC ratio and the
    seed.  With the default padded spec every configuration shares one
    compiled executable.
    """

    n_subnets: int   # length of the (possibly padded) subnet axis
    n_vcs: int       # per-subnet VC axis length (possibly padded)
    buf_depth: int
    epoch_len: int
    n_epochs: int
    mc_queue_cap: int
    mc_service_period: int
    mshr_limit: int
    policy: PolicyConfig
    z_scales: tuple[float, float, float]
    kf_q: float
    kf_r: float
    # cycle-engine knobs (DESIGN.md §11, §13): scan unroll factor for the
    # inner cycle loop, and which engine to trace ("ref" = dense jnp,
    # "pallas" = the fused full-cycle repro.kernels.noc_cycle lane kernel,
    # "pallas_arb" = dense body with only arbitration on the lane kernel).
    cycle_unroll: int = 1
    backend: str = "ref"
    # injection-stamp dtype: "auto" picks uint16 whenever every age the run
    # can produce is wraparound-exact (see init_sim_state); "int32" forces
    # the wide stamps — a test/debug knob the uint16-boundary regression
    # test uses to pin auto == int32 bitwise at the 2^16-cycle boundary.
    stamp_dtype: str = "auto"
    # flight recorder (repro.obs, DESIGN.md §14): probes off (the default)
    # leaves the traced program — and so the goldens and trace count —
    # bit-for-bit unchanged; probes on is its own single trace returning
    # (SimResult, SimTrace).
    probe: ProbeConfig = ProbeConfig()
    # mesh geometry (DESIGN.md §17): the topology tables are shape-bearing,
    # so grid dimensions are structural.  The paper grid (6x6, 8 MCs) is
    # the default; any grid accepted by `topology.validate_topology_args`
    # builds and runs (capped at 64 routers by the lane-metadata packing).
    width: int = 6
    height: int = 6
    n_mc: int = 8


@dataclasses.dataclass(frozen=True)
class NoCConfig:
    mode: str = "kf"              # baseline | fair | 4subnet | kf | static
    static_gpu_vcs: int = 2       # for mode=static: GPU gets [g : V-g]
    n_vcs: int = 4                # per input port per subnet (2-subnet modes)
    buf_depth: int = 4            # packets per VC (paper: 4)
    epoch_len: int = 500          # cycles per KF epoch
    n_epochs: int = 120
    # DRAM is the scarce shared resource (paper §2.1: "CPU packets pile up at
    # MCs which already have many GPU packets waiting").  Total DRAM service
    # is 8 MCs / 2 cycles = 4 pkt/cycle vs ~7.3 offered during bursts; the
    # NoC's VC partition + switch priority decide *admission* into MC queues,
    # which is exactly the lever the paper's KF reconfigures.
    mc_queue_cap: int = 16
    mc_service_period: int = 2    # cycles per serviced request per MC
    mshr_limit: int = 16          # max outstanding requests per node (MSHRs)
    policy: PolicyConfig = PolicyConfig()
    # normalization scales for KF observations (counters per epoch)
    z_scales: tuple[float, float, float] = (300.0, 160.0, 2500.0)
    kf_q: float = 1e-3
    kf_r: float = 2e-1
    seed: int = 0
    cycle_unroll: int = 1         # inner cycle-scan unroll factor
    backend: str = "ref"          # cycle engine: ref | pallas | pallas_arb
    stamp_dtype: str = "auto"     # injection-stamp dtype: auto | int32
    # predictor-ablation knobs (DESIGN.md §12): which bank member drives the
    # hysteresis machine (only meaningful for mode="kf") and the EMA
    # predictor's smoothing factor.  Traced data — not part of SimStatic.
    predictor: str = "kf"
    ema_alpha: float = 0.5   # the textbook naive-EMA default
    # robustness knobs (DESIGN.md §16) — both traced data, NOT SimStatic:
    # `guard` arms the predictor's self-healing layer (innovation gate +
    # divergence watchdog + covariance reset + fair-split fallback);
    # `faults` is any `faults.FaultSourceLike` (scenario name,
    # FaultSchedule, FaultStream, or None = healthy) injected through the
    # epoch scan's xs — faulty and healthy runs share one compiled program.
    guard: bool = False
    faults: FaultSourceLike = None
    # compute-placement knobs (DESIGN.md §17) — both traced data, NOT
    # SimStatic: `placement` is any `placement.PlacementSourceLike`
    # (scenario name, PlacementSchedule, PlacementStream, or None = the
    # identity/static layout) riding the epoch scan's xs; `control` picks
    # which lever(s) the applied config drives — "bandwidth" (the paper's
    # VC/SA controller), "placement" (relocation only), or "joint".
    placement: PlacementSourceLike = None
    control: str = "bandwidth"
    # flight recorder (repro.obs, DESIGN.md §14) — static, default off
    probe: ProbeConfig = ProbeConfig()
    # mesh geometry (DESIGN.md §17) — structural; see SimStatic
    width: int = 6
    height: int = 6
    n_mc: int = 8

    @property
    def n_subnets(self) -> int:
        return 4 if self.mode == "4subnet" else 2

    @property
    def vcs_per_subnet(self) -> int:
        return self.n_vcs // 2 if self.mode == "4subnet" else self.n_vcs

    def static_spec(self, padded: bool = True) -> SimStatic:
        """Structural spec — padded (default) or the mode's dedicated shape.

        ``padded=True`` pads the subnet axis to ``S_MAX`` and keeps the full
        VC axis, so EVERY mode returns the same spec and shares one compiled
        program.  ``padded=False`` reproduces the pre-§10 dedicated traces
        (2-subnet xV, or 4-subnet x V/2) — kept for the equivalence tests.
        """
        return SimStatic(
            n_subnets=S_MAX if padded else self.n_subnets,
            n_vcs=self.n_vcs if padded else self.vcs_per_subnet,
            buf_depth=self.buf_depth,
            epoch_len=self.epoch_len,
            n_epochs=self.n_epochs,
            mc_queue_cap=self.mc_queue_cap,
            mc_service_period=self.mc_service_period,
            mshr_limit=self.mshr_limit,
            policy=self.policy,
            z_scales=tuple(self.z_scales),
            kf_q=self.kf_q,
            kf_r=self.kf_r,
            cycle_unroll=self.cycle_unroll,
            backend=self.backend,
            stamp_dtype=self.stamp_dtype,
            probe=self.probe,
            width=self.width,
            height=self.height,
            n_mc=self.n_mc,
        )

    def mode_policy(self, padded: bool = True) -> ModePolicy:
        stc = self.static_spec(padded)
        return mode_policy(
            self.mode, stc.n_vcs, self.static_gpu_vcs,
            n_subnets=stc.n_subnets, active_vcs=self.vcs_per_subnet,
            predictor=self.predictor, ema_alpha=self.ema_alpha,
            guard=self.guard, control=self.control,
        )


class MCState(NamedTuple):
    q_meta: Array     # (R, Q) int8 — pending request src | cls << 6
    head: Array       # (R,)
    count: Array      # (R,)
    timer: Array      # (R,) cycles until current service completes
    stage_valid: Array  # (R,) staged reply waiting to inject
    stage_dst: Array
    stage_cls: Array


class EpochCounters(NamedTuple):
    gpu_push: Array           # GPU request injections accepted
    gpu_stall_icnt: Array     # GPU node-cycles blocked at MSHR/injection
    gpu_stall_dram: Array     # GPU dramfull events
    cpu_push: Array
    gpu_done: Array           # completed GPU transactions
    cpu_done: Array
    gpu_gen: Array            # generated GPU demand
    cpu_gen: Array
    lat_sum: Array            # all ejected packets: sum of network latency
    lat_cnt: Array
    cpu_lat_sum: Array        # per-class NETWORK latency of ejected packets
    cpu_lat_cnt: Array        # (excludes DRAM queue wait: the NoC's own share)
    gpu_lat_sum: Array
    gpu_lat_cnt: Array
    moved: Array


def _zero_counters() -> EpochCounters:
    z = jnp.int32(0)
    return EpochCounters(z, z, z, z, z, z, z, z, z, z, z, z, z, z, z)


class _ProbeAcc(NamedTuple):
    """Dense-engine flight-recorder accumulators (repro.obs, DESIGN.md §14):
    the per-cycle carry the probes-on cycle scan threads next to the
    counters.  The fused engine's twin is `fused.ProbeLanes`; both sample
    END-of-cycle state, so the two agree bitwise."""

    occ: Array      # (S, R, P, V) int32 — summed VC occupancy
    grant: Array    # (S, R) int32 — switch grants, summed over outputs
    deny: Array     # (S, R) int32 — refused requests, summed over outputs
    mcq_sum: Array  # (R,) int32 — summed MC queue depth
    mcq_max: Array  # (R,) int32 — running max MC queue depth


def _zero_probe_acc(S: int, R: int, V: int) -> _ProbeAcc:
    return _ProbeAcc(
        occ=jnp.zeros((S, R, rt.N_PORTS, V), jnp.int32),
        grant=jnp.zeros((S, R), jnp.int32),
        deny=jnp.zeros((S, R), jnp.int32),
        mcq_sum=jnp.zeros((R,), jnp.int32),
        mcq_max=jnp.zeros((R,), jnp.int32),
    )


class SimResult(NamedTuple):
    gpu_ipc: Array        # (E,) per-epoch GPU IPC proxy
    cpu_ipc: Array        # (E,)
    avg_latency: Array    # (E,) mean packet network latency
    kf_signal: Array      # (E,) binarized KF output
    applied_config: Array  # (E,) configuration actually applied
    counters: EpochCounters  # (E,) leaves
    gpu_inj_rate: Array   # (E,) offered GPU load (Fig. 4 trace)
    # VCs the GPU class could occupy during the epoch — pins the hoisted
    # per-epoch masks to the policy state that entered the epoch (the mask
    # flip must trail `applied_config` by exactly one epoch; see
    # tests/test_cycle_engine.py's policy-boundary regression test).
    gpu_vc_quota: Array   # (E,)


def _make_kf(stc: SimStatic):
    return kalman.paper_params(q=stc.kf_q, r=stc.kf_r)


def init_sim_state(stc: SimStatic, batch: int | None = None):
    """Zero-initialized carry buffers (subnets, MC queues, source backlogs).

    Built outside the jitted entry points so the batched path can donate
    them: XLA then reuses the buffers in place instead of holding both the
    init and the first-iteration copy live.
    """
    topo = make_topology(stc.width, stc.height, stc.n_mc)
    R = topo.n_routers
    S, V, B = stc.n_subnets, stc.n_vcs, stc.buf_depth

    def z(shape, dtype=jnp.int32):
        if batch is not None:
            shape = (batch,) + shape
        return jnp.zeros(shape, dtype)

    # Injection stamps ride uint16 when every possible age fits: the latency
    # subtraction is wraparound-exact for ages <= 2^16 - 1.  The max age is
    # `total - 1` (a cycle-0 injection ejected on the last cycle): stamps
    # are injection cycles <= total - 1 — epoch-end replies carry the next
    # epoch's first cycle, but the run's final cycle defers its replies to
    # an epoch prologue that never executes, so no stamp exceeds total - 1
    # either — hence uint16 is exact whenever total <= 2^16.  (The old gate
    # `total + 1 <= 0xFFFF` was conservative by two: totals of exactly
    # 65535/65536 cycles paid int32 stamps for no reason — pinned at the
    # boundary by tests/test_predictor_ablation.py.)
    total_cycles = stc.epoch_len * stc.n_epochs
    if stc.stamp_dtype == "int32":
        binj_dtype = jnp.int32
    elif stc.stamp_dtype == "auto":
        binj_dtype = jnp.uint16 if total_cycles <= 2**16 else jnp.int32
    else:
        raise ValueError(
            f"unknown stamp_dtype {stc.stamp_dtype!r}; expected auto|int32"
        )
    subnets0 = rt.SubnetState(
        buf_meta=z((S, R, rt.N_PORTS, V, B), jnp.int16),
        buf_binj=z((S, R, rt.N_PORTS, V, B), binj_dtype),
        head=z((S, R, rt.N_PORTS, V), jnp.int8),
        count=z((S, R, rt.N_PORTS, V), jnp.int8),
        rr_ptr=z((S, R, rt.N_PORTS), jnp.int8),
    )
    mc0 = MCState(
        q_meta=z((R, stc.mc_queue_cap), jnp.int8),
        head=z((R,)),
        count=z((R,)),
        timer=z((R,)),
        stage_valid=z((R,), bool),
        stage_dst=z((R,)),
        stage_cls=z((R,)),
    )
    outstanding0 = z((R,))
    backlog0 = z((R,))  # per-node source-queue depth (see BCAP)
    return subnets0, mc0, outstanding0, backlog0


# Incremented each time XLA actually (re)traces the simulator — the
# equivalence tests assert the whole paper sweep costs at most two traces.
_trace_counter = [0]


def trace_count() -> int:
    return _trace_counter[0]


def reset_trace_count() -> None:
    _trace_counter[0] = 0


def _simulate_impl(
    stc: SimStatic,
    mp: ModePolicy,
    profile: WorkloadProfile,
    seed: Array,
    state0,
    faults: FaultStream,
    placement: PlacementStream,
) -> SimResult:
    """Core jitted simulation.  ``profile`` arrives MATERIALIZED: every leaf
    is an (n_epochs,) float32 row (``traffic.materialize``), consumed by the
    epoch scan as `xs` — one parameter row per epoch.  Stationary workloads
    broadcast their scalars across the epoch axis, so scenario schedules
    (piecewise switches, ramps, pinned burst phases — DESIGN.md §12) share
    this one trace with them by construction.

    ``faults`` arrives the same way (DESIGN.md §16): per-epoch mask rows
    (`faults.resolve_faults`) rode through the epoch scan's xs, always
    threaded — a healthy run carries the identity stream, so faulty and
    healthy configurations share this ONE trace and the healthy values are
    bit-for-bit the pre-fault program's (every fault gate is an AND or a
    mode-0 `where`).

    ``placement`` too (DESIGN.md §17): per-epoch (R,) node-class plans
    (`placement.resolve_placement`) riding the epoch scan's xs.  Node
    identity — `is_gpu`/`is_cpu`/`node_cls`/`req_sub` and the injection
    gates — is derived per epoch from the traced plan inside `epoch_body`
    instead of from static topology constants, so relocated and static
    runs share this ONE trace; the identity stream carries the topology's
    own layout, making a static run's derived values bit-for-bit the
    pre-placement program's.  MCs are physical and never relocate: `is_mc`
    stays a static table and the virtual node type re-asserts it."""
    _trace_counter[0] += 1  # Python side effect: runs only at trace time

    topo = make_topology(stc.width, stc.height, stc.n_mc)
    route_t, nb_t, opp_t, ntype, mc_ids = rt.device_tables(topo)
    R = topo.n_routers
    S = stc.n_subnets
    V = stc.n_vcs

    is_mc = ntype == 2  # static: MCs are physical, placement never moves them
    ar = jnp.arange(R)

    # Traced subnet structure (DESIGN.md §10): which rows of the padded
    # subnet axis are live, which carry requests, and whether routing is
    # class-segregated.  Padded rows are zero-width: excluded from every
    # inject want-matrix below and link-inactive in cycle_body, so no packet
    # can ever enter them.
    fs = mp.four_subnet                      # () bool
    sub_enabled = mp.sub_enabled             # (S,) bool
    sub_is_req = mp.sub_is_req               # (S,) bool
    sub_is_rep = sub_enabled & ~sub_is_req   # (S,) bool
    n_req_subs = jnp.sum(sub_is_req.astype(jnp.int32))
    sub_ids = jnp.arange(S, dtype=jnp.int32)
    # NB `is_gpu`/`is_cpu`/`node_cls`/`req_sub` are no longer derived here:
    # they are per-epoch quantities computed in `epoch_body` from the traced
    # placement plan (DESIGN.md §17).

    subnets0, mc0, outstanding0, backlog0 = state0

    # flight recorder (DESIGN.md §14): a STATIC switch — probes off traces
    # the exact pre-probe program (the accumulators below are Python-gated,
    # not lax.cond-gated), probes on is its own single trace.
    probe_on = stc.probe.enabled

    kf_params = _make_kf(stc)
    z_scales = jnp.asarray(stc.z_scales, jnp.float32)

    # cycle-engine backend (DESIGN.md §11, §13) — all three agree bitwise
    # (tests/test_cycle_engine.py), so the choice is pure perf:
    #   "ref"        — the dense jnp cycle body below.
    #   "pallas"     — the FUSED full-cycle Pallas kernel: one launch per
    #                  simulated cycle with the whole carry in lane refs
    #                  (repro.kernels.noc_cycle, interpret-mode off-TPU).
    #   "pallas_arb" — dense cycle body with only switch allocation swapped
    #                  for the arbitration lane kernel (the PR-4 path).
    fused_engine = stc.backend == "pallas"
    if stc.backend == "pallas_arb":
        from repro.kernels.noc_cycle.ops import arbitrate_lanes as arb_fn
    elif stc.backend in ("ref", "pallas"):
        arb_fn = rt.arbitrate
    else:
        raise ValueError(f"unknown cycle-engine backend {stc.backend!r}")
    if fused_engine:
        from repro.kernels.noc_cycle import fused as lanes
        from repro.kernels.noc_cycle import ops as lane_ops

        assert lanes.COUNTER_FIELDS == EpochCounters._fields, (
            "fused kernel counter lanes out of sync with EpochCounters"
        )
        # the lane engine carries stamps as int32 and masks the latency
        # subtraction instead, reproducing the uint16 wraparound bitwise
        stamp_mask = 0xFFFF if subnets0.buf_binj.dtype == jnp.uint16 else 0
        lane_dims = lanes.lane_dims(
            S=S, R=R, V=V, B=stc.buf_depth, Q=stc.mc_queue_cap,
            width=topo.width, mc_service_period=stc.mc_service_period,
            mshr_limit=stc.mshr_limit, bcap=BCAP, stamp_mask=stamp_mask,
        )
        # the node-type row and the req_match-bearing policy rows are now
        # per-epoch data (placement, DESIGN.md §17) — rebuilt in epoch_body
        route_rows, exists_rows, _ = lanes.run_consts(lane_dims, topo)

    def make_want_rep(mc):
        """Want-matrix for staged MC replies (reply subnet of requester
        class c is 2c+1 under class-segregated routing, subnet 1 otherwise)."""
        rep_target = jnp.where(fs, 2 * mc.stage_cls + 1, 1)
        return (
            (sub_ids[:, None] == rep_target[None, :])
            & (mc.stage_valid & is_mc)[None, :]
            & sub_enabled[:, None]
        )

    def epoch_body(carry, epoch_xs):
        # prof: this epoch's scalar-leaf profile; flt: this epoch's fault
        # masks — link_ok (R, P), router_ok (R,), mc_ok (R,), telem ()s;
        # plc: this epoch's placement plans — cls0/cls1 (R,)
        epoch_key, prof, flt, plc = epoch_xs
        subs, mc, phase, outst, backlog, policy, pred_state, cycle0 = carry

        # ---- epoch-invariant hoisting (DESIGN.md §11): `policy.config` is
        # frozen until the KF acts at the epoch boundary, so the VC masks,
        # the SA preference stream, the link-activation parity and ALL of
        # the cycle RNG are computed here once and fed to the cycle scan as
        # per-cycle `xs` instead of being recomputed every cycle.
        config_idx = policy.config
        g_vec, c_vec = class_vc_masks(mp, config_idx)          # (V,)
        gpu_masks = jnp.broadcast_to(g_vec, (S, V))
        cpu_masks = jnp.broadcast_to(c_vec, (S, V))

        # ---- traced node identity (DESIGN.md §17): the applied config
        # selects between this epoch's base/boosted placement plans (gated
        # on `place_enable`), and EVERY class-derived quantity follows.
        # MC rows re-assert NT_MC — memory controllers are physical.  With
        # the identity stream all of these select the static topology
        # values bit-for-bit.
        cls_e = placement_class(mp, config_idx, plc.cls0, plc.cls1)
        ntype_e = jnp.where(is_mc, 2, cls_e)               # (R,) virtual type
        is_gpu = ntype_e == 1
        is_cpu = ntype_e == 0
        node_cls = jnp.where(is_gpu, 1, 0)  # class a node's traffic belongs to
        # request subnet of a node's own traffic; the reply subnet
        # additionally depends on the requester's class under
        # class-segregated routing.
        req_sub = jnp.where(fs, 2 * node_cls, 0)

        # Epoch prologue: replies staged on the previous epoch's last cycle
        # inject under THIS epoch's masks.  The in-cycle merged inject is
        # gated off on the epoch's last cycle (`rep_gate`), which preserves
        # the original engine's ordering across a KF reconfiguration: a
        # reply staged at cycle E-1 always entered the network with the
        # *new* epoch's VC partition.
        subs, ok0 = rt.inject_all(
            subs, make_want_rep(mc), mc.stage_dst, ar,
            mc.stage_cls, cycle0, gpu_masks, cpu_masks,
        )
        mc = mc._replace(stage_valid=mc.stage_valid & ~jnp.any(ok0, axis=0))

        # Per-epoch RNG streams: the SAME keys and draws as the old
        # per-cycle `split(cycle_key, 3)` engine, batched with vmap (a
        # value-preserving transform), so every stream is bitwise-identical
        # to drawing inside the loop.
        ep_len = stc.epoch_len
        keys = jax.random.split(epoch_key, ep_len)
        k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
        u_phase = jax.vmap(lambda k: jax.random.uniform(k, ()))(k3[:, 0])
        u_gen = jax.vmap(
            lambda k: jax.random.uniform(k, (R,), jnp.float32)
        )(k3[:, 1])
        d_idx = jax.vmap(
            lambda k: jax.random.randint(k, (R,), 0, mc_ids.shape[0])
        )(k3[:, 2])
        dests_all = jnp.take(mc_ids, d_idx)                     # (L, R)
        cycles = cycle0 + jnp.arange(ep_len, dtype=jnp.int32)
        sa_all = epoch_sa_prefs(mp, config_idx, cycles)         # (L,)
        # subnet link activation: full width (2-subnet) or alternating-cycle
        # half width (4-subnet); padded subnet rows are never active.
        alternating = (cycles[:, None] % 2) == (jnp.arange(S)[None, :] % 2)
        active_all = sub_enabled[None, :] & jnp.where(fs, alternating, True)
        rep_gate = jnp.arange(ep_len) < ep_len - 1
        xs = (cycles, u_phase, u_gen, dests_all, sa_all, active_all, rep_gate)

        def cycle_body(carry, x):
            if probe_on:
                subs, mc, phase, outstanding, bl_count, cnt, prb = carry
            else:
                subs, mc, phase, outstanding, bl_count, cnt = carry
            cycle, u_ph, u_gen_c, dests, sa_pref, active, gate = x

            # MC acceptance applies to ejections on *request* subnets at MC
            # nodes, judged on the queue depth BEFORE this cycle's service
            # frees a slot.  With multiple request subnets (4-subnet mode)
            # up to S/2 packets can arrive at one MC per cycle, so reserve
            # that many slots.
            mc_space = mc.count <= stc.mc_queue_cap - n_req_subs
            can_accept = jnp.where(is_mc, mc_space, True)  # (R,)
            accept_s = jnp.where(
                sub_is_req[:, None], can_accept[None, :], True
            )

            # ---- 1. MC service: tick timers, move head request -> staging
            # (a stalled MC — flt.mc_ok False — freezes its timer and
            # staging; the queue keeps filling until it back-pressures)
            can_serve = is_mc & (mc.count > 0) & ~mc.stage_valid & flt.mc_ok
            timer = jnp.where(
                can_serve, jnp.maximum(mc.timer - 1, 0), mc.timer
            )
            done = can_serve & (timer == 0)
            hq = mc.head[:, None]
            q_head = jnp.take_along_axis(
                mc.q_meta, hq, axis=1
            )[:, 0].astype(jnp.int32)
            # MC-queue meta is src | cls << META_SRC_SHIFT (router ids fit
            # the shift width — asserted once in rt.device_tables)
            src_out = q_head & ((1 << rt.META_SRC_SHIFT) - 1)
            cls_out = q_head >> rt.META_SRC_SHIFT
            mc = mc._replace(
                head=jnp.where(
                    done, (mc.head + 1) % stc.mc_queue_cap, mc.head
                ),
                count=mc.count - done.astype(jnp.int32),
                timer=jnp.where(done, stc.mc_service_period, timer),
                stage_valid=mc.stage_valid | done,
                stage_dst=jnp.where(done, src_out, mc.stage_dst),
                stage_cls=jnp.where(done, cls_out, mc.stage_cls),
            )

            # ---- 2. route/arbitrate every subnet (per-epoch fault masks:
            # a dead link back-pressures, a browned-out router grants
            # nothing — DESIGN.md §16)
            subs, events = rt.router_cycle(
                subs, route_t, nb_t, opp_t,
                gpu_masks, cpu_masks, sa_pref, accept_s, active,
                arbitrate_fn=arb_fn,
                link_ok=flt.link_ok, router_ok=flt.router_ok,
            )

            # ---- 3. ejection handling
            # request-subnet ejections at MC nodes -> enqueue into MC
            # queues.  A per-subnet exclusive prefix count serializes
            # same-MC arrivals into consecutive ring slots; the write is a
            # dense masked where over (R, Q) (no scatter).
            req_ej = (
                events.eject_valid & sub_is_req[:, None] & is_mc[None, :]
            )  # (S, R)
            arr_i = req_ej.astype(jnp.int32)
            slot_off = jnp.cumsum(arr_i, axis=0) - arr_i
            slot = (
                mc.head[None, :] + mc.count[None, :] + slot_off
            ) % stc.mc_queue_cap
            qmask = req_ej[..., None] & (
                slot[..., None] == jnp.arange(stc.mc_queue_cap)
            )  # (S, R, Q) — at most one subnet hits each slot
            qhit = jnp.any(qmask, axis=0)
            q_val = events.eject_src + (events.eject_cls << rt.META_SRC_SHIFT)
            qm = jnp.sum(jnp.where(qmask, q_val[..., None], 0), axis=0)
            mc = mc._replace(
                q_meta=jnp.where(qhit, qm.astype(jnp.int8), mc.q_meta),
                count=mc.count + jnp.sum(arr_i, axis=0),
            )
            # reply-subnet ejections at source nodes -> complete
            # transactions (masked to live reply rows under S-padding)
            rep_ej = (
                events.eject_valid & sub_is_rep[:, None] & (~is_mc)[None, :]
            )
            rep_done = jnp.any(rep_ej, axis=0)
            outstanding = outstanding - rep_done.astype(jnp.int32)
            rep_cls = jnp.sum(jnp.where(rep_ej, events.eject_cls, 0), axis=0)

            # Fig. 11 packet latency: network time (injection -> ejection).
            # The subtraction runs in the stamp dtype — wraparound-exact
            # for uint16 stamps because ages are <= total - 1 <= 2^16 - 1
            # by construction (the init_sim_state stamp-dtype gate).
            dt = events.eject_binj.dtype
            age = (cycle.astype(dt) - events.eject_binj).astype(jnp.int32)
            ej_lat = jnp.where(events.eject_valid, age, 0)
            cpu_ej = events.eject_valid & (events.eject_cls == 0)
            gpu_ej = events.eject_valid & (events.eject_cls == 1)

            # ---- 4. source generation -> per-node source-queue depth
            phase = step_phase_u(prof, phase, u_ph)
            rates = injection_rates(prof, ntype_e, phase)
            gen = (u_gen_c < rates) & ~is_mc  # == bernoulli(k_gen, rates)
            # push into the per-node source queue (drop + stall if full)
            can_push = gen & (bl_count < BCAP)
            bl_count = bl_count + can_push.astype(jnp.int32)

            can_inj = (
                (bl_count > 0) & (outstanding < stc.mshr_limit) & ~is_mc
            )

            # ---- 5. ONE merged inject: this cycle's sources (request
            # rows) + the replies staged this cycle (reply rows — the old
            # engine injected those at the TOP of the next cycle; nothing
            # between the two points touches reply-row state, so fusing
            # them here is value-identical; `gate` defers the epoch's last
            # cycle to the next epoch's prologue).
            want_src = (
                (sub_ids[:, None] == req_sub[None, :])
                & can_inj[None, :]
                & sub_enabled[:, None]
            )
            want_rep = make_want_rep(mc) & gate
            is_req_row = sub_is_req[:, None]
            subs, ok = rt.inject_all(
                subs, want_src | want_rep,
                jnp.where(is_req_row, dests[None, :], mc.stage_dst[None, :]),
                jnp.broadcast_to(ar, (S, R)),
                jnp.where(
                    is_req_row, node_cls[None, :], mc.stage_cls[None, :]
                ),
                jnp.where(is_req_row, cycle, cycle + 1),
                gpu_masks, cpu_masks,
            )
            inj_ok = jnp.any(ok & is_req_row, axis=0)
            mc = mc._replace(
                stage_valid=mc.stage_valid
                & ~jnp.any(ok & ~is_req_row, axis=0)
            )
            bl_count = bl_count - inj_ok.astype(jnp.int32)
            outstanding = outstanding + inj_ok.astype(jnp.int32)

            # ---- 6. counters
            gpu_blocked = is_gpu & (bl_count > 0)  # shader stuck at ICNT
            cnt = EpochCounters(
                gpu_push=cnt.gpu_push
                + jnp.sum((inj_ok & is_gpu).astype(jnp.int32)),
                gpu_stall_icnt=cnt.gpu_stall_icnt
                + jnp.sum(gpu_blocked.astype(jnp.int32)),
                gpu_stall_dram=cnt.gpu_stall_dram + events.dram_block_gpu,
                cpu_push=cnt.cpu_push
                + jnp.sum((inj_ok & is_cpu).astype(jnp.int32)),
                gpu_done=cnt.gpu_done
                + jnp.sum((rep_done & (rep_cls == 1)).astype(jnp.int32)),
                cpu_done=cnt.cpu_done
                + jnp.sum((rep_done & (rep_cls == 0)).astype(jnp.int32)),
                gpu_gen=cnt.gpu_gen + jnp.sum((gen & is_gpu).astype(jnp.int32)),
                cpu_gen=cnt.cpu_gen + jnp.sum((gen & is_cpu).astype(jnp.int32)),
                lat_sum=cnt.lat_sum + jnp.sum(ej_lat),
                lat_cnt=cnt.lat_cnt
                + jnp.sum(events.eject_valid.astype(jnp.int32)),
                cpu_lat_sum=cnt.cpu_lat_sum
                + jnp.sum(jnp.where(cpu_ej, ej_lat, 0)),
                cpu_lat_cnt=cnt.cpu_lat_cnt
                + jnp.sum(cpu_ej.astype(jnp.int32)),
                gpu_lat_sum=cnt.gpu_lat_sum
                + jnp.sum(jnp.where(gpu_ej, ej_lat, 0)),
                gpu_lat_cnt=cnt.gpu_lat_cnt
                + jnp.sum(gpu_ej.astype(jnp.int32)),
                moved=cnt.moved + events.moved,
            )
            if probe_on:
                # ---- 7. flight-recorder accumulation from END-of-cycle
                # state (the fused engine samples at the same point)
                prb = _ProbeAcc(
                    occ=prb.occ + subs.count.astype(jnp.int32),
                    grant=prb.grant + events.grant_cnt,
                    deny=prb.deny + events.deny_cnt,
                    mcq_sum=prb.mcq_sum + mc.count,
                    mcq_max=jnp.maximum(prb.mcq_max, mc.count),
                )
                return (
                    subs, mc, phase, outstanding, bl_count, cnt, prb
                ), None
            return (subs, mc, phase, outstanding, bl_count, cnt), None

        if fused_engine:
            # ---- fused path (DESIGN.md §13): pack the carry into lane
            # layout once per epoch, run ONE pallas_call per cycle with the
            # whole state in kernel refs, unpack at the epoch boundary.
            # Everything outside the cycle scan (prologue inject, RNG, KF,
            # policy) is byte-for-byte the dense engine's code above/below.
            gm_rows, cm_rows = lanes.mask_rows(lane_dims, g_vec, c_vec)
            pr_rows = lanes.prof_rows(prof)
            # placement lane rows (DESIGN.md §17): the node-type row and
            # the req_match-bearing policy rows follow this epoch's plan
            ntype_row = lanes.placement_rows(lane_dims, ntype_e)
            req_match = (
                (sub_ids[:, None] == req_sub[None, :]) & sub_enabled[:, None]
            )
            pol_sr, pol_r = lanes.policy_rows(
                lane_dims, sub_enabled, sub_is_req, sub_is_rep, req_match,
                fs, n_req_subs,
            )
            xi, xf = lanes.cycle_xs(
                lane_dims, cycles, u_phase, u_gen, dests_all, sa_all,
                active_all, rep_gate,
                router_ok=flt.router_ok, mc_ok=flt.mc_ok,
            )
            # epoch link-fault mask folded into the link-exists rows: the
            # lane kernel sees a dead link exactly as a non-existent one
            link_rows = jnp.tile(
                jnp.pad(
                    flt.link_ok.astype(jnp.int32).T,
                    ((0, 0), (0, lanes.R_PAD - R)),
                ),
                (1, S),
            )
            exists_ep = exists_rows * link_rows
            ls0 = lanes.pack_state(lane_dims, subs, mc, outst, backlog, phase)

            def fused_cycle(ls, x):
                ls = lane_ops.fused_cycle_step(
                    lane_dims, ls, x[0], x[1], gm_rows, cm_rows, pr_rows,
                    pol_sr, pol_r, ntype_row, route_rows, exists_ep,
                )
                return ls, None

            def fused_cycle_probed(carry, x):
                ls, pb = carry
                ls, pb = lane_ops.fused_cycle_step(
                    lane_dims, ls, x[0], x[1], gm_rows, cm_rows, pr_rows,
                    pol_sr, pol_r, ntype_row, route_rows, exists_ep,
                    probe=pb,
                )
                return (ls, pb), None

            if probe_on:
                (ls, pb), _ = jax.lax.scan(
                    fused_cycle_probed, (ls0, lanes.zero_probe(lane_dims)),
                    (xi, xf), unroll=stc.cycle_unroll,
                )
                prb = _ProbeAcc(*lanes.unpack_probe(lane_dims, pb))
            else:
                ls, _ = jax.lax.scan(
                    fused_cycle, ls0, (xi, xf), unroll=stc.cycle_unroll
                )
            subs, mc, outst, backlog, phase = lanes.unpack_state(
                lane_dims, ls, MCState, subnets0.buf_binj.dtype
            )
            cnt = EpochCounters(
                *(ls.cnt[0, i] for i in range(lanes.N_COUNTERS))
            )
        else:
            inner0 = (subs, mc, phase, outst, backlog, _zero_counters())
            if probe_on:
                inner0 = inner0 + (_zero_probe_acc(S, R, V),)
                (subs, mc, phase, outst, backlog, cnt, prb), _ = jax.lax.scan(
                    cycle_body, inner0, xs, unroll=stc.cycle_unroll
                )
            else:
                (subs, mc, phase, outst, backlog, cnt), _ = jax.lax.scan(
                    cycle_body, inner0, xs, unroll=stc.cycle_unroll
                )
        cycle = cycle0 + jnp.int32(stc.epoch_len)

        # ---- KF epoch update (paper §3.2)
        raw = jnp.stack(
            [
                cnt.gpu_stall_dram.astype(jnp.float32),
                cnt.gpu_push.astype(jnp.float32),
                cnt.gpu_stall_icnt.astype(jnp.float32),
            ]
        )
        z = kalman.normalize_observations(raw, jnp.zeros(3), z_scales)
        # telemetry corruption (DESIGN.md §16): applied AFTER normalization
        # so a spike escapes the [-1, 1] clip the way a corrupted counter
        # bus escapes the sensor's calibrated range.  Mode 0 selects the
        # clean vector through every `where`, so a healthy epoch's z is
        # bit-for-bit the pre-fault program's.
        tm = flt.telem_mode
        z = jnp.where(tm == TELEM_DROP, jnp.full_like(z, -1.0), z)
        z = jnp.where(tm == TELEM_SPIKE, z + flt.telem_mag, z)
        z = jnp.where(tm == TELEM_NAN, jnp.full_like(z, jnp.nan), z)
        # predictor bank (DESIGN.md §12): every member advances, the traced
        # `mp.predictor.kind` selects which signal drives the hysteresis
        # machine — the KF lane reproduces the legacy
        # `binarize(kalman.step(...).x[0])` bitwise.
        if probe_on:
            pred_state, signal, kfi = predictor.step_probed(
                mp.predictor, kf_params, pred_state, z
            )
        else:
            pred_state, signal = predictor.step(
                mp.predictor, kf_params, pred_state, z
            )
        policy = apply_policy_gated(stc.policy, mp, policy, signal, cycle)
        # degraded-mode fallback (DESIGN.md §16): while the predictor
        # watchdog reports unhealthy, the applied configuration reverts to
        # the fair static split; `healthy` is constant True whenever the
        # guard is disarmed, so this is an identity on pre-guard programs.
        policy = degrade_policy(policy, pred_state.healthy)

        # ---- IPC proxies (documented in metrics.py)
        gpu_ipc = metrics.gpu_ipc_proxy(
            cnt.gpu_done.astype(jnp.float32), cnt.gpu_gen.astype(jnp.float32)
        )
        cpu_lat = cnt.cpu_lat_sum / jnp.maximum(cnt.cpu_lat_cnt, 1)
        cpu_ipc = metrics.cpu_ipc_proxy(cpu_lat)
        avg_lat = cnt.lat_sum / jnp.maximum(cnt.lat_cnt, 1)
        inj_rate = (cnt.gpu_push.astype(jnp.float32)
                    / (stc.epoch_len * jnp.sum(is_gpu)))

        out = (gpu_ipc, cpu_ipc, avg_lat, signal, policy.config, cnt, inj_rate,
               jnp.sum(g_vec.astype(jnp.int32)))
        if probe_on:
            # fault-event channel: how many fabric elements this epoch's
            # masks suppressed, plus whether telemetry was corrupted
            faults_active = (
                jnp.sum((~flt.link_ok).astype(jnp.int32))
                + jnp.sum((~flt.router_ok).astype(jnp.int32))
                + jnp.sum((~flt.mc_ok).astype(jnp.int32))
                + (tm != 0).astype(jnp.int32)
            )
            # placement channel (DESIGN.md §17): the virtual node class
            # applied this epoch — shared by every backend, so the
            # relocation timeline is cross-engine congruent by construction
            out = (out, (prb, kfi, z, faults_active, cls_e))
        return (subs, mc, phase, outst, backlog, policy, pred_state, cycle), out

    key0 = jax.random.PRNGKey(seed)
    epoch_keys = jax.random.split(key0, stc.n_epochs)
    carry0 = (
        subnets0,
        mc0,
        init_phase(),
        outstanding0,
        backlog0,
        init_policy_state(),
        predictor.init_state(),
        jnp.int32(0),
    )
    _, outs = jax.lax.scan(
        epoch_body, carry0, (epoch_keys, profile, faults, placement)
    )
    if probe_on:
        outs, (prb, kfi, z_obs, faults_active, place_cls) = outs
    gpu_ipc, cpu_ipc, avg_lat, sig, conf, cnt, inj, quota = outs
    result = SimResult(
        gpu_ipc=gpu_ipc,
        cpu_ipc=cpu_ipc,
        avg_latency=avg_lat,
        kf_signal=sig,
        applied_config=conf,
        counters=cnt,
        gpu_inj_rate=inj,
        gpu_vc_quota=quota,
    )
    if not probe_on:
        return result
    trace = SimTrace(
        occ_sum=prb.occ,
        arb_grant=prb.grant,
        arb_deny=prb.deny,
        mcq_sum=prb.mcq_sum,
        mcq_max=prb.mcq_max,
        kf_innovation=kfi.innovation,
        kf_gain=kfi.gain,
        kf_cov_trace=kfi.cov_trace,
        kf_x_pred=kfi.x_pred,
        z_obs=z_obs,
        kf_nis=kfi.nis,
        kf_rejected=kfi.rejected,
        kf_reset=kfi.reset,
        kf_healthy=kfi.healthy,
        faults_active=faults_active,
        place_cls=place_cls,
    )
    return result, trace


_SIM_JIT = jax.jit(_simulate_impl, static_argnums=0)

_BATCH_JIT = None


def _batch_jit():
    """Batched entry: vmap over (policy tensors, profile, seed, carry).

    Carry buffers are donated so XLA reuses the (B, S, R, P, V, B)-sized
    state in place; CPU's runtime has no donation support, so skip it there
    to avoid a warning per call.  Built lazily on first use — deciding at
    import time would initialize the JAX backend before callers can
    configure the platform (e.g. `jax.config.update("jax_platform_name")`).
    """
    global _BATCH_JIT
    if _BATCH_JIT is None:
        donate = () if jax.default_backend() == "cpu" else (4,)
        _BATCH_JIT = jax.jit(
            jax.vmap(_simulate_impl, in_axes=(None, 0, 0, 0, 0, 0, 0)),
            static_argnums=0,
            donate_argnums=donate,
        )
    return _BATCH_JIT


def _run_faults(source: FaultSourceLike, stc: SimStatic) -> FaultStream:
    """Lower a config's fault source against the run topology.

    The neighbor table makes link faults symmetric (a dead link is dead
    both ways — `faults.FaultSchedule.materialize`)."""
    topo = make_topology(stc.width, stc.height, stc.n_mc)
    return resolve_faults(
        source, stc.n_epochs, n_routers=topo.n_routers,
        neighbor=topo.neighbor, opposite=topo.opposite,
    )


def _run_placement(
    source: PlacementSourceLike, stc: SimStatic
) -> PlacementStream:
    """Lower a config's placement source against the run topology."""
    topo = make_topology(stc.width, stc.height, stc.n_mc)
    return resolve_placement(source, stc.n_epochs, topo)


def simulate(
    cfg: NoCConfig,
    source: TrafficSourceLike,
    padded: bool = True,
    backend: str | None = None,
) -> SimResult:
    """Run one configuration (compiles at most once per `SimStatic`).

    ``source`` may be any `traffic.TrafficSource` — a stationary
    `WorkloadProfile`, a `traffic.ScenarioSchedule` (piecewise workload
    program — DESIGN.md §12), a replayed `traffic.RecordedTrace`
    (DESIGN.md §15), or a name resolving to any of them; it is lowered to
    per-epoch rows by `traffic.resolve_source` before dispatch, so every
    source kind reuses the same compiled program as stationary workloads.

    With ``padded=True`` (default) every mode runs the shared S/V-padded
    program; ``padded=False`` compiles the mode's dedicated trace, kept so
    the equivalence tests can pin padded == dedicated bit-for-bit.
    ``backend`` overrides the config's cycle-engine backend ("ref" |
    "pallas" | "pallas_arb", see DESIGN.md §11/§13 — "pallas" is the fused
    full-cycle lane kernel); each backend is its own `SimStatic`, so opting
    into a Pallas path never perturbs the default program's trace count.
    """
    stc = cfg.static_spec(padded)
    if backend is not None:
        stc = dataclasses.replace(stc, backend=backend)
    return _SIM_JIT(
        stc,
        cfg.mode_policy(padded),
        resolve_source(source, stc.n_epochs),
        jnp.int32(cfg.seed),
        init_sim_state(stc),
        _run_faults(cfg.faults, stc),
        _run_placement(cfg.placement, stc),
    )


def simulate_with_trace(
    cfg: NoCConfig,
    source: TrafficSourceLike,
    padded: bool = True,
    backend: str | None = None,
) -> tuple[SimResult, SimTrace]:
    """`simulate` with the flight recorder on: returns (SimResult, SimTrace).

    Forces ``probe.enabled`` — a distinct `SimStatic`, so the probed
    program is its own single trace and the probes-off program (goldens,
    sweeps) is never perturbed.  `SimResult` is bitwise the probes-off
    result; `SimTrace` is bitwise-equal across cycle-engine backends
    (tests/test_obs.py)."""
    if not cfg.probe.enabled:
        cfg = dataclasses.replace(cfg, probe=ProbeConfig(enabled=True))
    return simulate(cfg, source, padded=padded, backend=backend)


def _tree_rows(tree, sl):
    return jax.tree.map(lambda x: x[sl], tree)


def _pad_rows(tree, n_pad: int):
    """Append n_pad copies of row 0 along axis 0 of every leaf (discarded
    after the dispatch)."""
    if n_pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.repeat(x[:1], n_pad, axis=0)], axis=0
        ),
        tree,
    )


# Sharded dispatch cache: one jitted shard_map program per (SimStatic, Mesh).
# jit itself handles per-batch-shape retraces under each entry.
_SHARD_JIT: dict = {}


def _sharded_jit(stc: SimStatic, mesh):
    """Data-parallel batched entry: the vmapped program under shard_map.

    The batch axis is split across the mesh's `sweep` axis; each device runs
    the SAME per-shard vmapped program with no cross-device communication
    (psum-free), which keeps it clear of the jax-0.4.37 partial-manual
    collective SIGABRT (DESIGN.md §10) — all mesh axes are manual here and
    no collective is ever emitted.
    """
    key = (stc, mesh)
    if key not in _SHARD_JIT:
        from jax.sharding import PartitionSpec as P

        from repro.dist import sharding as dist_sharding

        batched = jax.vmap(_simulate_impl, in_axes=(None, 0, 0, 0, 0, 0, 0))

        def shard_body(mp, prof, seeds, state0, flt, plc):
            return batched(stc, mp, prof, seeds, state0, flt, plc)

        spec = P(SWEEP_AXIS)
        # check_vma off: jax 0.4.37's replication checker mis-types the
        # epoch-scan carry under shard_map and aborts the trace; with every
        # mesh axis manual and zero collectives the check has nothing to
        # verify here anyway.  Carry donation mirrors _batch_jit (state0 is
        # shard_body arg 3; CPU has no donation support).
        donate = () if jax.default_backend() == "cpu" else (3,)
        _SHARD_JIT[key] = jax.jit(
            dist_sharding.shard_map(
                shard_body, mesh=mesh,
                in_specs=(spec,) * 6, out_specs=spec,
                axis_names=(SWEEP_AXIS,), check_vma=False,
            ),
            donate_argnums=donate,
        )
    return _SHARD_JIT[key]


def simulate_batch(
    cfgs: Sequence[NoCConfig],
    sources: TrafficSourceLike | Sequence,
    seeds: Sequence[int] | None = None,
    batch_tile: int | None = None,
    devices: int | None = None,
    mesh=None,
) -> SimResult:
    """Evaluate many configurations in lockstep: one compiled program,
    one device dispatch per tile.

    cfgs      — length-B configs; all must share the same `static_spec()`
                (mode/ratio/seed/subnet-structure/predictor are traced).
    sources   — length-B demand sources, or one for all rows; each entry
                may be any `traffic.TrafficSource` (`WorkloadProfile`,
                `ScenarioSchedule`, `RecordedTrace`) or a name resolving
                to one — all rows lower through `traffic.resolve_source`
                to per-epoch rows and share the one compiled program.
    seeds     — optional per-row seeds; defaults to each cfg's own seed.
    batch_tile— if set, the batch is processed in fixed-size tiles (short
                batches and the ragged tail padded up), so EVERY sweep in
                the process reuses the same (tile-shaped) executable
                regardless of its batch size.
    devices / mesh —
                shard the batch axis data-parallel across devices: the flat
                point list is padded to a multiple of the device count and
                dispatched once through the shard_map path (`batch_tile` is
                ignored; per-device row count is the effective tile).
                `devices=N` builds a mesh over the first N local devices;
                pass `mesh` to reuse one (must have a `sweep` axis).

    Returns a `SimResult` whose leaves carry a leading (B,) axis.
    """
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("simulate_batch needs at least one config")
    stc = cfgs[0].static_spec()
    for c in cfgs[1:]:
        if c.static_spec() != stc:
            raise ValueError(
                "all configs in a batch must share the same structural "
                f"config; got {c.static_spec()} != {stc} — group with sweep()"
            )
    B = len(cfgs)
    # NB WorkloadProfile is itself a tuple, so a single source must be
    # detected by type (name or TrafficSource), not by Sequence-ness.
    if isinstance(sources, (str, TrafficSource)):
        sources = [sources] * B
    profiles = [resolve_source(s, stc.n_epochs) for s in sources]
    if len(profiles) != B:
        raise ValueError(f"{len(profiles)} sources for {B} configs")
    if seeds is None:
        seeds = [c.seed for c in cfgs]
    seeds = jnp.asarray(list(seeds), jnp.int32)
    if seeds.shape[0] != B:
        raise ValueError(f"{seeds.shape[0]} seeds for {B} configs")

    mp = jax.tree.map(lambda *xs: jnp.stack(xs), *[c.mode_policy() for c in cfgs])
    prof = stack_profiles(profiles)
    flt = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[_run_faults(c.faults, stc) for c in cfgs]
    )
    plc = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_run_placement(c.placement, stc) for c in cfgs],
    )

    if devices is not None or mesh is not None:
        if mesh is None:
            from repro.dist import sharding as dist_sharding

            mesh = dist_sharding.sweep_mesh(devices)
        ndev = int(mesh.devices.size)
        padded_b = -(-B // ndev) * ndev
        mp, prof, seeds, flt, plc = (
            _pad_rows(t, padded_b - B) for t in (mp, prof, seeds, flt, plc)
        )
        out = _sharded_jit(stc, mesh)(
            mp, prof, seeds, init_sim_state(stc, padded_b), flt, plc
        )
        return _tree_rows(out, slice(0, B))

    tile = B if batch_tile is None else batch_tile
    parts = []
    for lo in range(0, B, tile):
        sl = slice(lo, min(lo + tile, B))
        n = sl.stop - sl.start
        mp_t, prof_t, seeds_t, flt_t, plc_t = (
            _tree_rows(t, sl) for t in (mp, prof, seeds, flt, plc)
        )
        if n < tile:  # pad the ragged tail by repeating row 0 (discarded)
            mp_t, prof_t, seeds_t, flt_t, plc_t = (
                _pad_rows(t, tile - n)
                for t in (mp_t, prof_t, seeds_t, flt_t, plc_t)
            )
        out = _batch_jit()(
            stc, mp_t, prof_t, seeds_t, init_sim_state(stc, tile), flt_t,
            plc_t,
        )
        parts.append(_tree_rows(out, slice(0, n)))
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)


class SweepSpec(NamedTuple):
    """One row of a sweep: a network config x workload x seed point.

    ``workload`` names any demand source resolvable by
    `traffic.lookup_workload`: a stationary profile (`traffic.PROFILES`),
    a scenario schedule (`traffic.SCENARIOS`), or a trace/custom source
    added via `traffic.register_workload` / `traffic.register_trace`
    (DESIGN.md §15); ``predictor`` picks the bank member driving the
    hysteresis machine (meaningful for mode="kf" — the predictor-ablation
    axis, DESIGN.md §12).

    ``faults`` names a registered fault scenario (`faults.FAULTS`, None =
    healthy) and ``guard`` arms the predictor's self-healing layer
    (DESIGN.md §16) — both traced data, so the whole fault x guard grid
    rides the same compiled program and batches into one dispatch.  A
    ``faults``/``guard`` key in `sweep`'s overrides (e.g. the shared
    `--faults` CLI flag) takes precedence over the per-spec value.

    ``placement`` names a registered placement scenario
    (`placement.PLACEMENTS`, None = the identity/static layout) and
    ``control`` picks which lever(s) the applied config drives
    ("bandwidth" | "placement" | "joint" — DESIGN.md §17); both traced
    data with the same override-precedence rule as ``faults``/``guard``
    (the shared `--placement` CLI flag)."""

    mode: str
    workload: str
    static_gpu_vcs: int = 2
    seed: int = 0
    predictor: str = "kf"
    faults: str | None = None
    guard: bool = False
    placement: str | None = None
    control: str = "bandwidth"


# Tile size for sweep batches.  The paper sweeps (4 workloads x 3 ratios,
# 6 workloads x 4 modes) are all multiples of 6 once multiplied by any seed
# count, so 6 gives zero padding waste while keeping every sweep on the one
# shared S/V-padded executable.
SWEEP_TILE = 6

# Mesh axis name the sharded sweep path splits the batch axis over.
SWEEP_AXIS = "sweep"


def sweep(
    specs: Sequence[SweepSpec],
    batch_tile: int | None = SWEEP_TILE,
    devices: int | None = None,
    mesh=None,
    **overrides,
) -> list[SimResult]:
    """Run a heterogeneous sweep, batching rows that share an executable.

    Rows are grouped by `static_spec()` — since the S-padding refactor
    (DESIGN.md §10) every mode shares one spec, so the whole sweep is a
    single group and dispatches once — each group runs through
    `simulate_batch`, and results come back as one `SimResult` per spec, in
    input order.  `overrides` are forwarded to every row's `NoCConfig`
    (e.g. n_epochs=30); `devices`/`mesh` select the device-sharded dispatch
    path (see `simulate_batch`).
    """
    specs = list(specs)
    rows: list[SimResult | None] = [None] * len(specs)
    groups: dict[SimStatic, list[int]] = defaultdict(list)
    cfgs = []
    for i, sp in enumerate(specs):
        kw = dict(overrides)
        kw.setdefault("faults", sp.faults)
        kw.setdefault("guard", sp.guard)
        kw.setdefault("placement", sp.placement)
        kw.setdefault("control", sp.control)
        cfg = NoCConfig(
            mode=sp.mode, static_gpu_vcs=sp.static_gpu_vcs, seed=sp.seed,
            predictor=sp.predictor, **kw,
        )
        cfgs.append(cfg)
        groups[cfg.static_spec()].append(i)
    for idxs in groups.values():
        res = simulate_batch(
            [cfgs[i] for i in idxs],
            [specs[i].workload for i in idxs],
            batch_tile=batch_tile,
            devices=devices,
            mesh=mesh,
        )
        for j, i in enumerate(idxs):
            rows[i] = _tree_rows(res, j)
    return rows


def sweep_sharded(
    specs: Sequence[SweepSpec],
    devices: int | None = None,
    mesh=None,
    **overrides,
) -> list[SimResult]:
    """`sweep` with the flat point list data-parallel across devices.

    The point list is padded to a multiple of the device count (pad rows
    repeat row 0 and are discarded), then the whole sweep runs as ONE
    shard_map dispatch of the shared padded program.  Defaults to all local
    devices; results are identical to `sweep` row-for-row.
    """
    if mesh is None and devices is None:
        devices = len(jax.devices())
    return sweep(specs, batch_tile=None, devices=devices, mesh=mesh,
                 **overrides)


def run_workload(mode: str, workload: str, **overrides) -> SimResult:
    cfg = NoCConfig(mode=mode, **overrides)
    return simulate(cfg, workload)


def summarize(res: SimResult, warmup_epochs: int = 10) -> dict:
    # Clamp the warmup slice so short runs (n_epochs <= warmup_epochs, e.g.
    # the fig4/fig12 smoke invocations) summarize their tail epoch instead
    # of taking the mean of an empty slice (NaN).
    n_epochs = int(res.gpu_ipc.shape[-1])
    sl = slice(min(warmup_epochs, max(n_epochs - 1, 0)), None)
    return {
        "gpu_ipc": float(jnp.mean(res.gpu_ipc[sl])),
        "cpu_ipc": float(jnp.mean(res.cpu_ipc[sl])),
        "avg_latency": float(jnp.mean(res.avg_latency[sl])),
        "kf_on_frac": float(jnp.mean(res.applied_config[sl])),
    }


def summarize_seeds(rows: Sequence[SimResult], warmup_epochs: int = 10) -> dict:
    """Aggregate one sweep point over its seed replicas: mean + `<k>_std`."""
    per = [summarize(r, warmup_epochs) for r in rows]
    out = {}
    for k in per[0]:
        vals = np.asarray([p[k] for p in per])
        out[k] = float(vals.mean())
        out[k + "_std"] = float(vals.std())
    return out
