"""Reconfiguration policy (paper §3.2 deployment rules + §3.3 allocation).

The KF emits a binary signal each epoch.  The policy turns that signal into
an *applied configuration* under three hysteresis rules:

  1. warmup  — KF decisions are ignored for the first `warmup` cycles
               (paper: 10,000 cycles after GPU apps start);
  2. hold    — after any reallocation the configuration is frozen for
               `hold` cycles (paper: 5,000 cycles);
  3. revert  — if the boosted state (config=1) persists beyond `revert`
               cycles, fall back to the equal split (paper: 10,000 cycles).

The same state machine drives (a) the NoC simulator's VC partition + switch
arbitration and (b) the TPU comm scheduler's compiled-variant selection —
only the *meaning* of the configuration index differs.

Implemented as a pure jittable function over `PolicyState` so it can live
inside `lax.scan`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.predictor import PredictorPolicy, predictor_policy

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    warmup: int = 10_000     # cycles before the KF may act
    hold: int = 5_000        # min cycles between reallocations
    revert: int = 10_000     # max cycles to stay boosted before fallback
    n_configs: int = 2       # paper uses {0: equal, 1: GPU-boosted}


class PolicyState(NamedTuple):
    config: Array          # () int32 — currently applied configuration
    last_change: Array     # () int32 — cycle of the last reallocation
    boosted_since: Array   # () int32 — cycle when config became nonzero (-1 if not)


def init_policy_state() -> PolicyState:
    return PolicyState(
        config=jnp.int32(0),
        last_change=jnp.int32(-(10**9)),
        boosted_since=jnp.int32(-1),
    )


def apply_policy(
    cfg: PolicyConfig, state: PolicyState, kf_signal: Array, cycle: Array
) -> PolicyState:
    """Advance the hysteresis machine by one epoch.

    kf_signal: () int32 in [0, n_configs) — the KF's desired configuration.
    cycle:     () int32 — current cycle count.
    """
    desired = jnp.clip(kf_signal, 0, cfg.n_configs - 1)

    in_warmup = cycle < cfg.warmup
    in_hold = (cycle - state.last_change) < cfg.hold
    # revert rule: boosted for too long -> force equal split
    boosted = state.config > 0
    over_revert = boosted & (state.boosted_since >= 0) & (
        (cycle - state.boosted_since) > cfg.revert
    )

    want = jnp.where(over_revert, jnp.int32(0), desired)
    blocked = in_warmup | (in_hold & ~over_revert)
    new_config = jnp.where(blocked, state.config, want)

    changed = new_config != state.config
    new_last_change = jnp.where(changed, cycle, state.last_change)
    new_boosted_since = jnp.where(
        (new_config > 0) & ~boosted,
        cycle,
        jnp.where(new_config > 0, state.boosted_since, jnp.int32(-1)),
    )
    return PolicyState(
        config=new_config,
        last_change=new_last_change,
        boosted_since=new_boosted_since,
    )


# ---------------------------------------------------------------------------
# Configuration tables (paper §3.3, Figure 7/8)
# ---------------------------------------------------------------------------

class ModePolicy(NamedTuple):
    """Traced policy tensors: everything a network *mode* means to the sim.

    The simulator used to branch at trace time on ``cfg.mode`` — every mode
    (and every static VC ratio) compiled its own XLA program.  A
    ``ModePolicy`` lifts all of that into data so ``baseline``/``fair``/
    ``static``/``kf`` share one compiled 2-subnet trace and can be stacked
    along a batch axis for ``sim.simulate_batch`` (DESIGN.md §4).

    Since the S-padding refactor (DESIGN.md §10) the *subnet structure* is
    traced too: ``sub_enabled``/``sub_is_req`` describe which rows of the
    padded subnet axis are live and which direction they carry, and
    ``four_subnet`` selects the class-segregated routing of Fig. 9.  With
    those in data, 2-subnet and 4-subnet configurations share ONE compiled
    program (padded subnets are zero-width: never injected into, links never
    active).

    Since the predictor-ablation subsystem (DESIGN.md §12) the *predictor*
    driving the hysteresis machine is traced data too: ``predictor`` is a
    `repro.core.predictor.PredictorPolicy` sub-pytree selecting which bank
    member (KF / EMA / last-value / always-on / always-off) emits the
    epoch-boundary signal.

    Since the placement subsystem (DESIGN.md §17) the hysteresis machine
    drives TWO levers, each behind its own traced enable: ``bw_enable``
    lets the applied config reconfigure the VC partition + SA pattern (the
    paper's bandwidth lever) and ``place_enable`` lets it relocate compute
    between the placement stream's base/boosted plans (the SHIFT-style
    lever).  bandwidth-only / placement-only / joint control is therefore
    one compiled program — `mode_policy(..., control=...)` just flips these
    two scalars.

    Leaves may carry a leading batch dimension when stacked.
    """

    gpu_mask0: Array   # (V,) bool — VCs GPU packets may occupy, config = 0
    cpu_mask0: Array   # (V,) bool
    gpu_mask1: Array   # (V,) bool — masks when boosted (config = 1)
    cpu_mask1: Array   # (V,) bool
    sa_enable: Array   # ()  bool — enable the Fig. 8 SA preference pattern
    kf_enable: Array   # ()  bool — let the KF hysteresis machine drive config
    four_subnet: Array  # () bool — class-segregated subnet routing (Fig. 9)
    sub_enabled: Array  # (S,) bool — live rows of the padded subnet axis
    sub_is_req: Array   # (S,) bool — request-direction subnets (rest: reply)
    predictor: PredictorPolicy  # traced predictor-bank selection (§12)
    bw_enable: Array    # () bool — config drives the VC/SA bandwidth lever (§17)
    place_enable: Array  # () bool — config drives the compute-placement lever


# control levers the applied configuration may drive (DESIGN.md §17)
CONTROLS = ("bandwidth", "placement", "joint")


def mode_policy(
    mode: str,
    n_vcs: int = 4,
    static_gpu_vcs: int = 2,
    *,
    n_subnets: int | None = None,
    active_vcs: int | None = None,
    predictor: str = "kf",
    ema_alpha: float = 0.5,
    guard: bool = False,
    control: str = "bandwidth",
) -> ModePolicy:
    """Build the traced policy tensors for one of the paper's modes.

    baseline — VCs fully shared between classes, round-robin SA, no KF.
    fair     — static equal VC partition, no KF.
    static   — fixed [static_gpu_vcs : V - static_gpu_vcs] partition (Fig. 2/3).
    kf       — equal partition when config=0, boosted partition + SA pattern
               when config=1, KF drives config.
    4subnet  — physical segregation: within a subnet every VC its class may
               use is allowed (the subnet index segregates classes).

    ``n_subnets`` is the (possibly padded) length of the subnet axis and
    ``active_vcs`` the number of usable VCs out of ``n_vcs`` — VC indices
    ``>= active_vcs`` are masked off for both classes, which is how the
    4-subnet network (2 VCs/subnet) rides a V-padded shared program.  Both
    default to the mode's dedicated (unpadded) structure.

    ``predictor``/``ema_alpha`` pick the bank member that emits the
    reconfiguration signal (repro.core.predictor; meaningful only when the
    hysteresis machine is enabled, i.e. mode="kf").  ``guard`` arms that
    member's self-healing layer (innovation gate, divergence watchdog,
    covariance reset — DESIGN.md §16); disarmed it is bitwise inert.

    ``control`` selects which lever(s) the applied config drives
    (DESIGN.md §17): "bandwidth" (VC partition + SA pattern — the paper's
    controller, and the bitwise-identity default), "placement" (compute
    relocation between the placement stream's plans only), or "joint"
    (both).  Pure traced data — all three compile to one program.
    """
    if control not in CONTROLS:
        raise ValueError(
            f"unknown control {control!r}; expected one of {CONTROLS}"
        )
    if n_subnets is None:
        n_subnets = 4 if mode == "4subnet" else 2
    if active_vcs is None:
        active_vcs = n_vcs
    if not 0 < active_vcs <= n_vcs:
        raise ValueError(f"active_vcs={active_vcs} outside (0, {n_vcs}]")
    avail = jnp.arange(n_vcs) < active_vcs
    if mode in ("baseline", "4subnet"):
        g0, c0 = avail, avail
    elif mode == "fair":
        g0, c0 = vc_partition(jnp.int32(0), active_vcs)
    elif mode == "static":
        g0 = (jnp.arange(n_vcs) < static_gpu_vcs) & avail
        c0 = avail & ~g0
    elif mode == "kf":
        g0, c0 = vc_partition(jnp.int32(0), active_vcs)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "kf":
        g1, c1 = vc_partition(jnp.int32(1), active_vcs)
    else:
        g1, c1 = g0, c0  # config never leaves 0 when the KF is disabled

    def pad_v(m: Array) -> Array:  # partition masks are built over active_vcs
        if m.shape[0] == n_vcs:
            return m
        return jnp.concatenate([m, jnp.zeros((n_vcs - m.shape[0],), bool)])

    sub = jnp.arange(n_subnets)
    if mode == "4subnet":
        if n_subnets != 4:
            raise ValueError("4subnet mode needs a 4-row subnet axis, got "
                             f"{n_subnets}")
        sub_enabled = jnp.ones((n_subnets,), bool)
        sub_is_req = sub % 2 == 0          # {CPU,GPU} x {req, reply}
    else:
        if n_subnets < 2:
            raise ValueError(f"2-subnet modes need n_subnets >= 2, got "
                             f"{n_subnets}")
        sub_enabled = sub < 2              # rows 2.. are zero-width padding
        sub_is_req = sub == 0              # subnet 0 req, subnet 1 reply
    is_kf = mode == "kf"
    return ModePolicy(
        gpu_mask0=pad_v(g0), cpu_mask0=pad_v(c0),
        gpu_mask1=pad_v(g1), cpu_mask1=pad_v(c1),
        sa_enable=jnp.asarray(is_kf), kf_enable=jnp.asarray(is_kf),
        four_subnet=jnp.asarray(mode == "4subnet"),
        sub_enabled=sub_enabled,
        sub_is_req=sub_is_req,
        predictor=predictor_policy(predictor, ema_alpha=ema_alpha,
                                   guard=guard),
        bw_enable=jnp.asarray(control != "placement"),
        place_enable=jnp.asarray(control != "bandwidth"),
    )


def class_vc_masks(policy: ModePolicy, config: Array) -> tuple[Array, Array]:
    """Select the (V,) GPU/CPU VC masks for the applied configuration.

    Gated on ``bw_enable`` (DESIGN.md §17): under placement-only control
    the VC partition stays at the config-0 split no matter what the
    hysteresis machine applied.  ``bw_enable`` defaults True, so
    pre-placement programs select identical values."""
    boosted = (config > 0) & policy.bw_enable
    gpu = jnp.where(boosted, policy.gpu_mask1, policy.gpu_mask0)
    cpu = jnp.where(boosted, policy.cpu_mask1, policy.cpu_mask0)
    return gpu, cpu


def placement_class(
    policy: ModePolicy, config: Array, cls0: Array, cls1: Array
) -> Array:
    """Select the (R,) node-class plan for the applied configuration.

    The placement twin of `class_vc_masks` (DESIGN.md §17): while the
    hysteresis machine holds a boosted config AND ``place_enable`` is set,
    compute relocates to the placement stream's boosted plan ``cls1``;
    otherwise it sits on the base plan ``cls0``.  The identity stream
    carries ``cls0 == cls1``, so placement-free runs select bit-for-bit
    the static layout either way."""
    boosted = (config > 0) & policy.place_enable
    return jnp.where(boosted, cls1, cls0)


def apply_policy_gated(
    cfg: PolicyConfig,
    policy: ModePolicy,
    state: PolicyState,
    kf_signal: Array,
    cycle: Array,
) -> PolicyState:
    """`apply_policy` under a traced enable flag (no-op unless kf_enable)."""
    new = apply_policy(cfg, state, kf_signal, cycle)
    return jax.tree.map(
        lambda n, o: jnp.where(policy.kf_enable, n, o), new, state
    )


def degrade_policy(state: PolicyState, healthy: Array) -> PolicyState:
    """Traced degraded-mode fallback (DESIGN.md §16).

    While the predictor watchdog reports unhealthy, the applied
    configuration reverts to the fair static split (config 0) and the
    boost timer is cleared, so a poisoned filter can never starve a
    chiplet class worse than the no-predictor baseline.  `last_change`
    is kept, not reset: on recovery the hysteresis hold window is
    whatever it already was, so a healthy signal can re-boost
    immediately instead of serving a fresh hold penalty.

    `healthy` is a () bool (from `PredictorState.healthy`); it is
    constant True whenever the guard is disarmed, making this an
    elementwise identity on every pre-guard program.
    """
    fallback = PolicyState(
        config=jnp.int32(0),
        last_change=state.last_change,
        boosted_since=jnp.int32(-1),
    )
    return jax.tree.map(
        lambda f, o: jnp.where(healthy, o, f), fallback, state
    )


def epoch_sa_prefs(policy: ModePolicy, config: Array, cycles: Array) -> Array:
    """Per-cycle SA preference stream for one epoch (cycle-engine `xs`).

    `config` is frozen between epoch boundaries (`apply_policy_gated` runs
    only after the inner cycle scan), so the whole epoch's switch-arbitration
    preference classes can be precomputed from the cycle numbers instead of
    branching per cycle: returns (len(cycles),) int32, -1 for round-robin.
    The SA pattern is a bandwidth lever, so it rides ``bw_enable`` (§17).
    """
    pattern = sa_priority_pattern(config, cycles)
    return jnp.where(policy.sa_enable & policy.bw_enable, pattern,
                     jnp.int32(-1))


def vc_partition(config: Array, n_vcs: int = 4) -> tuple[Array, Array]:
    """Return boolean masks (gpu_vcs, cpu_vcs) over VC indices.

    config=0: GPU {0,1}, CPU {2,3}     (equal split)
    config=1: GPU {0,1,2}, CPU {3}     (75/25 boost)
    Generalized to n_vcs: equal split at n/2, boost at n-1.
    """
    idx = jnp.arange(n_vcs)
    gpu_hi = jnp.where(config > 0, n_vcs - 1, n_vcs // 2)  # exclusive bound
    gpu_mask = idx < gpu_hi
    return gpu_mask, ~gpu_mask


def sa_priority_pattern(config: Array, phase: Array) -> Array:
    """Switch-arbitration class preference for this cycle.

    Returns the preferred class (0=CPU, 1=GPU) given the 3-phase pattern.
    config=0: round-robin (no class preference — encoded as -1).
    config=1: GPU, GPU, CPU repeating (paper Fig. 8).
    """
    pattern = jnp.asarray([1, 1, 0], dtype=jnp.int32)[phase % 3]
    return jnp.where(config > 0, pattern, jnp.int32(-1))
