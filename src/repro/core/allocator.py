"""Reconfiguration policy (paper §3.2 deployment rules + §3.3 allocation).

The KF emits a binary signal each epoch.  The policy turns that signal into
an *applied configuration* under three hysteresis rules:

  1. warmup  — KF decisions are ignored for the first `warmup` cycles
               (paper: 10,000 cycles after GPU apps start);
  2. hold    — after any reallocation the configuration is frozen for
               `hold` cycles (paper: 5,000 cycles);
  3. revert  — if the boosted state (config=1) persists beyond `revert`
               cycles, fall back to the equal split (paper: 10,000 cycles).

The same state machine drives (a) the NoC simulator's VC partition + switch
arbitration and (b) the TPU comm scheduler's compiled-variant selection —
only the *meaning* of the configuration index differs.

Implemented as a pure jittable function over `PolicyState` so it can live
inside `lax.scan`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    warmup: int = 10_000     # cycles before the KF may act
    hold: int = 5_000        # min cycles between reallocations
    revert: int = 10_000     # max cycles to stay boosted before fallback
    n_configs: int = 2       # paper uses {0: equal, 1: GPU-boosted}


class PolicyState(NamedTuple):
    config: Array          # () int32 — currently applied configuration
    last_change: Array     # () int32 — cycle of the last reallocation
    boosted_since: Array   # () int32 — cycle when config became nonzero (-1 if not)


def init_policy_state() -> PolicyState:
    return PolicyState(
        config=jnp.int32(0),
        last_change=jnp.int32(-(10**9)),
        boosted_since=jnp.int32(-1),
    )


def apply_policy(
    cfg: PolicyConfig, state: PolicyState, kf_signal: Array, cycle: Array
) -> PolicyState:
    """Advance the hysteresis machine by one epoch.

    kf_signal: () int32 in [0, n_configs) — the KF's desired configuration.
    cycle:     () int32 — current cycle count.
    """
    desired = jnp.clip(kf_signal, 0, cfg.n_configs - 1)

    in_warmup = cycle < cfg.warmup
    in_hold = (cycle - state.last_change) < cfg.hold
    # revert rule: boosted for too long -> force equal split
    boosted = state.config > 0
    over_revert = boosted & (state.boosted_since >= 0) & (
        (cycle - state.boosted_since) > cfg.revert
    )

    want = jnp.where(over_revert, jnp.int32(0), desired)
    blocked = in_warmup | (in_hold & ~over_revert)
    new_config = jnp.where(blocked, state.config, want)

    changed = new_config != state.config
    new_last_change = jnp.where(changed, cycle, state.last_change)
    new_boosted_since = jnp.where(
        (new_config > 0) & ~boosted,
        cycle,
        jnp.where(new_config > 0, state.boosted_since, jnp.int32(-1)),
    )
    return PolicyState(
        config=new_config,
        last_change=new_last_change,
        boosted_since=new_boosted_since,
    )


# ---------------------------------------------------------------------------
# Configuration tables (paper §3.3, Figure 7/8)
# ---------------------------------------------------------------------------

def vc_partition(config: Array, n_vcs: int = 4) -> tuple[Array, Array]:
    """Return boolean masks (gpu_vcs, cpu_vcs) over VC indices.

    config=0: GPU {0,1}, CPU {2,3}     (equal split)
    config=1: GPU {0,1,2}, CPU {3}     (75/25 boost)
    Generalized to n_vcs: equal split at n/2, boost at n-1.
    """
    idx = jnp.arange(n_vcs)
    gpu_hi = jnp.where(config > 0, n_vcs - 1, n_vcs // 2)  # exclusive bound
    gpu_mask = idx < gpu_hi
    return gpu_mask, ~gpu_mask


def sa_priority_pattern(config: Array, phase: Array) -> Array:
    """Switch-arbitration class preference for this cycle.

    Returns the preferred class (0=CPU, 1=GPU) given the 3-phase pattern.
    config=0: round-robin (no class preference — encoded as -1).
    config=1: GPU, GPU, CPU repeating (paper Fig. 8).
    """
    pattern = jnp.asarray([1, 1, 0], dtype=jnp.int32)[phase % 3]
    return jnp.where(config > 0, pattern, jnp.int32(-1))
