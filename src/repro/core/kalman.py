"""Kalman Filter (paper Eqs. 1-5), JAX-native.

The paper uses a scalar state (next-epoch GPU IPC trend) observed through a
3-vector of normalized NoC counters.  We implement the general linear KF

    time update:         x^_k = A x_{k-1} + B u_{k-1}           (Eq. 1)
                         P^_k = A P_{k-1} A^T + Q               (Eq. 2)
    measurement update:  K_k  = P^_k H^T (H P^_k H^T + R)^-1    (Eq. 3)
                         x_k  = x^_k + K_k (z_k - H x^_k)       (Eq. 4)
                         P_k  = (I - K_k H) P^_k                (Eq. 5)

as a pure function over a `KalmanState` pytree, plus a batched variant
(`vmap`) used to run one filter per router/link/traffic-class, and a
`lax.scan` driver for offline trace filtering.  Everything is jittable and
dtype-polymorphic (fp32 default).

Notes
-----
* Eq. 5 in the paper text is written `(I - K_k) P^_k`; for a non-square H
  the dimensionally correct Joseph-free form is `(I - K_k H) P^_k`, which is
  what the paper's scalar-state/3-obs setup requires (K_k is n x m).  We use
  `(I - K_k H)`.
* The measurement-space solve uses `jnp.linalg.solve` rather than an explicit
  inverse for numerical robustness; for m = 1 this reduces to a scalar
  divide that XLA folds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class KalmanState(NamedTuple):
    """Posterior state estimate and error covariance (paper: X_k, P_k)."""

    x: Array  # (n,)   posterior state estimate
    p: Array  # (n, n) posterior error covariance


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KalmanParams:
    """Model matrices. Shapes: A (n,n), B (n,u), H (m,n), Q (n,n), R (m,m)."""

    a: Array
    b: Array
    h: Array
    q: Array
    r: Array

    @property
    def state_dim(self) -> int:
        return self.a.shape[0]

    @property
    def obs_dim(self) -> int:
        return self.h.shape[0]


def init_state(n: int, p0: float = 1.0, dtype=jnp.float32) -> KalmanState:
    return KalmanState(x=jnp.zeros((n,), dtype), p=jnp.eye(n, dtype=dtype) * p0)


def make_params(
    a, b, h, q, r, dtype=jnp.float32
) -> KalmanParams:  # convenience, accepts scalars / lists
    a = jnp.atleast_2d(jnp.asarray(a, dtype))
    b = jnp.atleast_2d(jnp.asarray(b, dtype))
    h = jnp.atleast_2d(jnp.asarray(h, dtype))
    q = jnp.atleast_2d(jnp.asarray(q, dtype))
    r = jnp.atleast_2d(jnp.asarray(r, dtype))
    return KalmanParams(a=a, b=b, h=h, q=q, r=r)


def time_update(params: KalmanParams, state: KalmanState, u: Array | None = None):
    """Eqs. (1)-(2): a-priori estimate (x^_k, P^_k)."""
    x, p = state
    x_prior = params.a @ x
    if u is not None:
        x_prior = x_prior + params.b @ u
    p_prior = params.a @ p @ params.a.T + params.q
    return KalmanState(x=x_prior, p=p_prior)


def measurement_update(params: KalmanParams, prior: KalmanState, z: Array):
    """Eqs. (3)-(5): posterior (x_k, P_k) given observation z (m,)."""
    x_prior, p_prior = prior
    h = params.h
    # S = H P^ H^T + R  (innovation covariance, m x m)
    s = h @ p_prior @ h.T + params.r
    # K = P^ H^T S^-1  solved as S^T K^T = H P^T  (S symmetric)
    k = jnp.linalg.solve(s, h @ p_prior.T).T  # (n, m)
    innovation = z - h @ x_prior
    x_post = x_prior + k @ innovation
    n = params.state_dim
    p_post = (jnp.eye(n, dtype=p_prior.dtype) - k @ h) @ p_prior
    # symmetrize to fight drift in long scans
    p_post = 0.5 * (p_post + p_post.T)
    # numerical-breakdown coast (DESIGN.md §16): at pathological
    # conditioning (e.g. R ~ 1e-12 against P ~ 1 makes cond(S) ~ 1e12,
    # past fp32's solve) the update can emit a non-finite or
    # negative-variance posterior that poisons every later step.  Coast
    # on the prior instead of propagating the breakdown.  Only a FINITE
    # observation triggers the coast: a corrupted (NaN) z must still
    # poison an unguarded filter — rejecting bad telemetry is the
    # innovation gate's job (predictor.step_probed), not this layer's.
    # Any well-conditioned update leaves `broke` False and the `where`
    # selects the computed posterior bit-for-bit, so healthy programs
    # are unchanged.
    broke = ~(jnp.all(jnp.isfinite(x_post))
              & jnp.all(jnp.isfinite(p_post))
              & jnp.all(jnp.diagonal(p_post) > 0.0))
    coast = broke & jnp.all(jnp.isfinite(z))
    x_post = jnp.where(coast, x_prior, x_post)
    p_post = jnp.where(coast, p_prior, p_post)
    return KalmanState(x=x_post, p=p_post), innovation


def kalman_gain(params: KalmanParams, prior: KalmanState) -> Array:
    """The gain K = P^ H^T S^-1 the measurement update applied, (n, m).

    `measurement_update` computes but does not return K; the flight
    recorder (repro.obs, DESIGN.md §14) wants it in the trace.  This
    recomputes it with the SAME expressions in the same order so XLA
    CSEs the work inside a traced program and the recorded gain is
    bitwise the one that weighted the innovation.
    """
    h = params.h
    p_prior = prior.p
    s = h @ p_prior @ h.T + params.r
    return jnp.linalg.solve(s, h @ p_prior.T).T


def innovation_nis(params: KalmanParams, prior: KalmanState, z: Array) -> Array:
    """Normalized innovation squared: nu^T S^-1 nu, a () scalar.

    The chi-square-distributed consistency statistic the self-healing
    gate thresholds (DESIGN.md §16): under a healthy filter NIS ~
    chi2(m), so a corrupted observation (spike, floor-drop) shows up as
    a value tens of sigma above the m=3 expectation.  Like
    `kalman_gain`, this recomputes S and the innovation with the SAME
    expressions in the same order as `measurement_update` so XLA CSEs
    the work inside a traced program.  NaN observations yield NaN NIS;
    `NaN > threshold` is False, which is why the gate in
    `predictor.step_probed` carries an explicit finiteness term.
    """
    h = params.h
    p_prior = prior.p
    s = h @ p_prior @ h.T + params.r
    nu = z - h @ prior.x
    return nu @ jnp.linalg.solve(s, nu)


def step(
    params: KalmanParams,
    state: KalmanState,
    z: Array,
    u: Array | None = None,
):
    """One full predict+correct cycle. Returns (posterior, prior, innovation)."""
    prior = time_update(params, state, u)
    posterior, innovation = measurement_update(params, prior, z)
    return posterior, prior, innovation


@partial(jax.jit, static_argnames=())
def filter_trace(params: KalmanParams, state0: KalmanState, zs: Array):
    """Run the KF along a trace `zs` of shape (T, m) via lax.scan.

    Returns (final_state, (xs_post, xs_prior)) where xs_* have shape (T, n).
    """

    def body(state, z):
        post, prior, _ = step(params, state, z)
        return post, (post.x, prior.x)

    return jax.lax.scan(body, state0, zs)


# ---------------------------------------------------------------------------
# Batched bank of independent filters (one per router / link / traffic class).
# Used by the NoC simulator (36 routers) and by the fleet-scale comm scheduler
# (one per pod x traffic-class).  The Pallas kernel in repro.kernels.kf_bank
# implements the same contract for TPU; this is the jnp oracle it is tested
# against.
# ---------------------------------------------------------------------------

batched_step = jax.vmap(step, in_axes=(None, 0, 0, None))


def batched_filter_trace(params: KalmanParams, states0: KalmanState, zs: Array):
    """zs: (T, B, m); states0 leaves have leading batch dim B."""

    def body(states, z):
        post, prior, _ = batched_step(params, states, z, None)
        return post, (post.x, prior.x)

    return jax.lax.scan(body, states0, zs)


# ---------------------------------------------------------------------------
# Paper-specific instantiation: scalar IPC-trend state, 3 NoC observations.
# ---------------------------------------------------------------------------

def paper_params(
    q: float = 1e-3,
    r: float = 1e-1,
    h: tuple[float, float, float] = (1.0, 1.0, 1.0),
    dtype=jnp.float32,
) -> KalmanParams:
    """KF for the paper's setup.

    State x = normalized GPU IPC *pressure* in [-1, 1] (positive => IPC will
    decline => allocate more resources to GPUs).  Observations z =
    [GPU_Stall_Dramfull, GPU_Icnt_Push, GPU_Stall_Icnt-Shader], each
    normalized to [-1, 1].  Random-walk state model (A = 1, no control).
    """
    return KalmanParams(
        a=jnp.eye(1, dtype=dtype),
        b=jnp.zeros((1, 1), dtype),
        h=jnp.asarray(h, dtype).reshape(3, 1),
        q=jnp.eye(1, dtype=dtype) * q,
        r=jnp.eye(3, dtype=dtype) * r,
    )


def one_step_prediction(params: KalmanParams, state: KalmanState) -> Array:
    """The filter's forecast for the NEXT epoch's state: `A x_k` (Eq. 1
    without the control term).

    This is the quantity the paper's controller actually thresholds — "the
    KF *predicts* next-epoch demand" — made explicit for the predictor bank
    (repro.core.predictor).  For the paper's random-walk model (A = I) it
    equals the posterior elementwise, so binarizing it is bitwise-identical
    to the legacy `binarize(x_post)` path.
    """
    return params.a @ state.x


def normalize_observations(raw: Array, lo: Array, hi: Array) -> Array:
    """Scale raw counters into [-1, 1] (paper §3.2 preprocessing)."""
    mid = 0.5 * (hi + lo)
    half = jnp.maximum(0.5 * (hi - lo), 1e-9)
    return jnp.clip((raw - mid) / half, -1.0, 1.0)


def binarize(x_post: Array, threshold: float = 0.0) -> Array:
    """Paper §3.2: KF output > 0 => IPC will decline => reconfigure (1)."""
    return (x_post > threshold).astype(jnp.int32)
