"""Train-step builders: the pre-compiled step VARIANTS the KF scheduler
switches between (the paper's pre-defined router configurations).

  variant 0 'balanced'      — plain pjit step; XLA's static schedule shares
                              the fabric (paper: equal VC split, RR arbiter).
  variant 1 'comm-priority' — the bandwidth class is boosted:
      * multi-pod mesh: shard_map manual over (pod, data); grad sync =
        bf16 psum over `data` (ICI) + int8+EF all_gather over `pod` (DCI)
        — 4x fewer cross-pod wire bytes (dist/compress.py);
      * single-pod mesh: 2-way microbatched gradient accumulation — halves
        activation HBM pressure (the z1 'dramfull' signal) at unchanged
        math; the grad collective fires once per step either way.

Both variants produce the SAME optimizer update given the same gradients;
only the fabric traffic pattern differs — mirroring the paper, where the
VC/arbiter reconfiguration changes packet scheduling, not packet payloads.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist import compress, sharding
from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib

Array = jax.Array

BALANCED, COMM_PRIORITY = 0, 1


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.OptState
    residuals: Any   # EF residuals; zeros-pytree when unused


def make_loss_fn(cfg: ModelConfig, *, use_kernel: bool = False) -> Callable:
    if cfg.is_encoder_decoder:
        return functools.partial(encdec.encdec_loss, cfg=cfg,
                                 use_kernel=use_kernel)
    return functools.partial(lm.lm_loss, cfg=cfg, use_kernel=use_kernel)


def init_train_state(
    key, cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig,
    *, with_residuals: bool = False, data_size: int = 1,
) -> tuple[TrainState, Any]:
    """Returns (state, spec-tree matching state).

    with_residuals allocates the flat error-feedback bucket for the
    comm-priority multipod variant: a (D, N/D) f32 array sharded over the
    `data` axis (each chip keeps the residual of ITS gradient shard).
    """
    if cfg.is_encoder_decoder:
        params, pspecs = encdec.make_encdec(key, cfg)
    else:
        params, pspecs = lm.make_lm(key, cfg)
    opt_state = opt_lib.init(opt_cfg, params)
    if with_residuals:
        def res_leaf(p):
            dim = scatter_dim_for(p.shape, data_size)
            return (jnp.zeros(p.shape, jnp.float32) if dim is not None
                    else jnp.zeros((), jnp.float32))

        def res_spec(p):
            dim = scatter_dim_for(p.shape, data_size)
            if dim is None:
                return P()
            ent = [None] * len(p.shape)
            ent[dim] = "grad_shard"
            return P(*ent)

        residuals = jax.tree.map(res_leaf, params)
        res_specs = jax.tree.map(res_spec, params)
    else:
        residuals = jax.tree.map(lambda _: jnp.zeros((), jnp.float32),
                                 params)
        res_specs = jax.tree.map(lambda _: P(), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
    state = TrainState(params=params, opt=opt_state, residuals=residuals)
    specs = TrainState(
        params=pspecs,
        opt=opt_lib.opt_state_specs(pspecs),
        residuals=res_specs,
    )
    return state, specs


def batch_specs(batch: dict) -> dict:
    """Logical specs for a data batch: leading dim is the global batch."""
    return {
        k: P("batch", *([None] * (v.ndim - 1))) for k, v in batch.items()
    }


# --------------------------------------------------------------------------
# Variant 0: balanced (plain pjit)
# --------------------------------------------------------------------------

def _balanced_step(loss_fn, opt_cfg):
    def step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        params, opt_state, opt_m = opt_lib.update(
            opt_cfg, state.opt, grads, state.params)
        metrics = {**metrics, **opt_m}
        return TrainState(params, opt_state, state.residuals), metrics

    return step


# --------------------------------------------------------------------------
# Variant 1a: comm-priority on a multi-pod mesh (hierarchical int8-EF sync)
# --------------------------------------------------------------------------
#
# First attempt (recorded in EXPERIMENTS.md §Perf, REFUTED by measurement):
# psum(data) then int8 all_gather(pod) of the FULL gradient — every chip
# carried the same 9.4 GB int8 payload across the DCI, 16x redundant, and
# measured WORSE than XLA's baseline hierarchical reduction (which crosses
# pods with only its 1/16 shard).  The fix below reduce-scatters a flat
# gradient bucket over `data` first, compresses ONLY the per-chip shard for
# the pod hop, then all-gathers intra-pod:
#
#   flat bucket --psum_scatter(data, f32)--> shard (N/D per chip)
#     --int8+EF all_gather(pod), wire = N/D bytes--> pod-summed shard
#     --all_gather(data, bf16, ICI)--> full reduced gradient
#
# Cross-pod wire: N/D int8 bytes/chip vs N/D bf16 bytes/chip baseline => 2x
# DCI cut, now with NO redundancy.  EF residuals live on the shard, stored
# as a (D, N/D) array sharded over `data` ("grad_shard" logical axis).

def scatter_dim_for(shape, d_size: int) -> Optional[int]:
    """Per-tensor RS dim in NATIVE layout (iteration 2's flat bucket
    forced model-axis regathers — see the module header).  Subdividing
    an existing dim never moves model shards."""
    if len(shape) and shape[-1] % d_size == 0:
        return len(shape) - 1
    if len(shape) and shape[0] % d_size == 0:
        return 0
    return None


def _comm_priority_multipod_step(loss_fn, opt_cfg, mesh: Mesh):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    d_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)

    def _scatter_dim(shape) -> Optional[int]:
        return scatter_dim_for(shape, d_size)

    def step(state: TrainState, batch: dict):
        def local(state: TrainState, batch: dict):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
            n_pods = (jax.lax.axis_size("pod")
                      if "pod" in data_axes else 1)

            def sync(g, r):
                dim = _scatter_dim(g.shape)
                if dim is None or "pod" not in data_axes:
                    # small tensors (norms/biases): plain mean — negligible
                    out = (jax.lax.psum(g.astype(jnp.float32), data_axes)
                           / (d_size * n_pods)).astype(g.dtype)
                    return out, r
                # stage 1: reduce-scatter over data in native layout
                gs = jax.lax.psum_scatter(
                    g.astype(jnp.float32), "data",
                    scatter_dimension=dim, tiled=True)
                # stage 2: int8+EF over the pod axis — the DCI hop carries
                # 1 byte/el of a 1/D shard
                q, scale, r = compress.quantize_ef(gs, r)
                qs = jax.lax.all_gather(q, "pod")
                ss = jax.lax.all_gather(scale, "pod")
                gs = jnp.sum(
                    qs.astype(jnp.float32)
                    * ss.reshape((n_pods,) + (1,) * gs.ndim), axis=0)
                gs = gs / (d_size * n_pods)
                # stage 3: rebuild intra-pod (bf16 ICI)
                full = jax.lax.all_gather(
                    gs.astype(jnp.bfloat16), "data", axis=dim, tiled=True)
                return full.astype(g.dtype), r

            flat_g, tdef = jax.tree.flatten(grads)
            flat_r = jax.tree.leaves(state.residuals)
            synced = [sync(g, r) for g, r in zip(flat_g, flat_r)]
            grads = jax.tree.unflatten(tdef, [s[0] for s in synced])
            residuals = jax.tree.unflatten(tdef, [s[1] for s in synced])

            params, opt_state, opt_m = opt_lib.update(
                opt_cfg, state.opt, grads, state.params)
            metrics_all = {**metrics, **opt_m}
            metrics_all = jax.tree.map(
                lambda m: jax.lax.pmean(m, data_axes), metrics_all)
            return TrainState(params, opt_state, residuals), metrics_all

        bspecs = jax.tree.map(
            lambda v: P(data_axes, *([None] * (v.ndim - 1))), batch)
        # P() prefixes: params/opt/metrics replicated over the manual data
        # axes (identical post-reduction); EF residuals are per-shard state
        # sharded over `data`.
        # check_vma=False: the int8 path reduces via all_gather + local sum,
        # whose result is value-invariant over `pod` by construction — the
        # varying-manual-axes checker cannot infer that (it would demand a
        # psum, which would wire f32 and defeat the compression).
        def res_spec(r):
            dim = _scatter_dim(r.shape) if r.ndim else None
            if r.ndim == 0 or dim is None:
                return P()
            ent = [None] * r.ndim
            ent[dim] = "data"
            return P(*ent)

        res_specs = jax.tree.map(res_spec, state.residuals)
        state_spec = TrainState(params=P(), opt=P(), residuals=res_specs)
        return jax.shard_map(
            local, mesh=mesh,
            in_specs=(state_spec, bspecs),
            out_specs=(state_spec, P()),
            axis_names=set(data_axes),
            check_vma=False,
        )(state, batch)

    return step


# --------------------------------------------------------------------------
# Variant 1b: comm-priority on a single-pod mesh (microbatch accumulation)
# --------------------------------------------------------------------------

def _comm_priority_singlepod_step(loss_fn, opt_cfg, n_micro: int = 2):
    def step(state: TrainState, batch: dict):
        def micro(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, mb)
            gacc = jax.tree.map(jnp.add, gacc, grads)
            return (gacc, lacc + loss), None

        mbs = jax.tree.map(
            lambda v: v.reshape((n_micro, v.shape[0] // n_micro)
                                + v.shape[1:]),
            batch,
        )
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                             state.params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt_state, opt_m = opt_lib.update(
            opt_cfg, state.opt, grads, state.params)
        metrics = {"loss": lsum / n_micro, "ce": lsum / n_micro, **opt_m}
        return TrainState(params, opt_state, state.residuals), metrics

    return step


# --------------------------------------------------------------------------
# Public builder
# --------------------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_lib.OptimizerConfig,
    *,
    mesh: Optional[Mesh] = None,
    variant: int = BALANCED,
    use_kernel: bool = False,
    donate: bool = True,
):
    """Returns an UNJITTED step fn (state, batch) -> (state, metrics).

    The launcher jits it with in/out shardings resolved from the logical
    spec trees — one compiled executable per variant, dispatched by the
    KF scheduler.
    """
    loss_fn = make_loss_fn(cfg, use_kernel=use_kernel)
    if variant == BALANCED:
        return _balanced_step(loss_fn, opt_cfg)
    if mesh is not None and len(mesh.axis_names) >= 2 and any(
        a in mesh.axis_names for a in ("pod",)
    ):
        return _comm_priority_multipod_step(loss_fn, opt_cfg, mesh)
    return _comm_priority_singlepod_step(loss_fn, opt_cfg)


def jit_step(step_fn, mesh: Mesh, state: TrainState, state_specs: TrainState,
             batch: dict):
    """Resolve logical specs -> NamedShardings and jit with donation."""
    state_sh = sharding.shard_specs(state_specs, state, mesh)
    batch_sh = jax.tree.map(
        lambda v: NamedSharding(
            mesh,
            sharding.logical_to_mesh(
                P("batch", *([None] * (v.ndim - 1))), v.shape, mesh
            ),
        ),
        batch,
    )
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
