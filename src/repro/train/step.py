"""Train-step builders: the pre-compiled step VARIANTS the KF scheduler
switches between (the paper's pre-defined router configurations).

  variant 0 'balanced'      — plain pjit step; XLA's static schedule shares
                              the fabric (paper: equal VC split, RR arbiter).
  variant 1 'comm-priority' — the bandwidth class is boosted:
      * multi-pod mesh: GSPMD steered by sharding constraints (see the
        variant-1a comment block); grad sync = f32 reduce-scatter over
        `data` (ICI) + int8+EF all_gather over `pod` (DCI) + bf16 rebuild
        — 2x fewer cross-pod wire bytes (dist/compress.py);
      * single-pod mesh: 2-way microbatched gradient accumulation — halves
        activation HBM pressure (the z1 'dramfull' signal) at unchanged
        math; the grad collective fires once per step either way.

Both variants produce the SAME optimizer update given the same gradients;
only the fabric traffic pattern differs — mirroring the paper, where the
VC/arbiter reconfiguration changes packet scheduling, not packet payloads.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist import compress, sharding
from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib

Array = jax.Array

BALANCED, COMM_PRIORITY = 0, 1


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.OptState
    residuals: Any   # EF residuals; zeros-pytree when unused


def make_loss_fn(cfg: ModelConfig, *, use_kernel: bool = False) -> Callable:
    if cfg.is_encoder_decoder:
        return functools.partial(encdec.encdec_loss, cfg=cfg,
                                 use_kernel=use_kernel)
    return functools.partial(lm.lm_loss, cfg=cfg, use_kernel=use_kernel)


def init_train_state(
    key, cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig,
    *, with_residuals: bool = False, data_size: int = 1, pod_size: int = 1,
) -> tuple[TrainState, Any]:
    """Returns (state, spec-tree matching state).

    with_residuals allocates the error-feedback buckets for the
    comm-priority multipod variant: a (pod, ...shape) f32 array sharded
    over ("pod", "grad_shard"->data) so each chip keeps the residual of
    exactly the gradient shard IT quantizes.  pod_size=1 still works on a
    multi-pod mesh (pod 0's residual is broadcast — degenerate EF).
    """
    if cfg.is_encoder_decoder:
        params, pspecs = encdec.make_encdec(key, cfg)
    else:
        params, pspecs = lm.make_lm(key, cfg)
    opt_state = opt_lib.init(opt_cfg, params)
    if with_residuals:
        def res_leaf(p):
            dim = scatter_dim_for(p.shape, data_size)
            return (jnp.zeros((pod_size,) + p.shape, jnp.float32)
                    if dim is not None else jnp.zeros((), jnp.float32))

        def res_spec(p):
            dim = scatter_dim_for(p.shape, data_size)
            if dim is None:
                return P()
            ent = [None] * (len(p.shape) + 1)
            ent[0] = "pod"
            ent[dim + 1] = "grad_shard"
            return P(*ent)

        residuals = jax.tree.map(res_leaf, params)
        res_specs = jax.tree.map(res_spec, params)
    else:
        residuals = jax.tree.map(lambda _: jnp.zeros((), jnp.float32),
                                 params)
        res_specs = jax.tree.map(lambda _: P(), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
    state = TrainState(params=params, opt=opt_state, residuals=residuals)
    specs = TrainState(
        params=pspecs,
        opt=opt_lib.opt_state_specs(pspecs),
        residuals=res_specs,
    )
    return state, specs


def batch_specs(batch: dict) -> dict:
    """Logical specs for a data batch: leading dim is the global batch."""
    return {
        k: P("batch", *([None] * (v.ndim - 1))) for k, v in batch.items()
    }


# --------------------------------------------------------------------------
# Variant 0: balanced (plain pjit)
# --------------------------------------------------------------------------

def _balanced_step(loss_fn, opt_cfg):
    def step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        params, opt_state, opt_m = opt_lib.update(
            opt_cfg, state.opt, grads, state.params)
        metrics = {**metrics, **opt_m}
        return TrainState(params, opt_state, state.residuals), metrics

    return step


# --------------------------------------------------------------------------
# Variant 1a: comm-priority on a multi-pod mesh (hierarchical int8-EF sync)
# --------------------------------------------------------------------------
#
# First attempt (recorded in EXPERIMENTS.md §Perf, REFUTED by measurement):
# psum(data) then int8 all_gather(pod) of the FULL gradient — every chip
# carried the same 9.4 GB int8 payload across the DCI, 16x redundant, and
# measured WORSE than XLA's baseline hierarchical reduction (which crosses
# pods with only its 1/16 shard).  The fix reduce-scatters over `data`
# first, compresses ONLY the per-chip shard for the pod hop, then
# all-gathers intra-pod:
#
#   per-slice grads --reduce-scatter(data, f32)--> shard (N/D per chip)
#     --int8+EF all_gather(pod), wire = N/D bytes--> pod-summed shard
#     --all_gather(data, bf16, ICI)--> full reduced gradient
#
# Cross-pod wire: N/D int8 bytes/chip vs N/D bf16 bytes/chip baseline => 2x
# DCI cut, with NO redundancy.  EF residuals are per-chip: a
# (pod, ...shape) array sharded over ("pod", "grad_shard") so every chip
# keeps the rounding error of exactly the shard IT quantized.
#
# Mechanically this is pure GSPMD steered by sharding constraints — NOT a
# shard_map: on this toolchain the SPMD partitioner only supports psum-form
# collectives inside partial-manual (auto-axes) regions, and the model's
# tensor parallelism must stay under compiler control.  Instead the batch
# is split into K = pod*data slices on a leading array axis (vmap'd grads,
# zero cross-slice comm), and the hierarchical reduction is written as
# array ops whose forced output shardings make XLA emit exactly the
# reduce-scatter / s8 all-gather / bf16 all-gather sequence above
# (asserted on the compiled HLO in tests/test_multidevice.py).

def scatter_dim_for(shape, d_size: int) -> Optional[int]:
    """Per-tensor RS dim in NATIVE layout (iteration 2's flat bucket
    forced model-axis regathers — see the module header).  Subdividing
    an existing dim never moves model shards."""
    if len(shape) and shape[-1] % d_size == 0:
        return len(shape) - 1
    if len(shape) and shape[0] % d_size == 0:
        return 0
    return None


def _comm_priority_multipod_step(loss_fn, opt_cfg, mesh: Mesh):
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d_size = mesh_sizes.get("data", 1)
    pod_size = mesh_sizes.get("pod", 1)
    n_slices = pod_size * d_size

    def _wsc(x, *entries):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*entries)))

    def step(state: TrainState, batch: dict):
        # per-slice gradients: one batch slice per (pod, data) coordinate on
        # a leading array axis — the backward pass has zero cross-slice comm
        mbs = jax.tree.map(
            lambda v: _wsc(
                v.reshape((n_slices, v.shape[0] // n_slices) + v.shape[1:]),
                ("pod", "data")),
            batch)
        (_, metrics_k), grads_k = jax.vmap(
            jax.value_and_grad(loss_fn, has_aux=True),
            in_axes=(None, 0))(state.params, mbs)

        def sync(g, r):
            # g: (K, *shape) per-slice grads; r: (R, *shape) EF residuals
            # with R == pod_size (exact per-chip EF) or R == 1 (degenerate)
            shape = g.shape[1:]
            dim = scatter_dim_for(shape, d_size)
            if dim is None:
                # small tensors (norms/biases): plain f32 mean — negligible
                out = jnp.mean(g.astype(jnp.float32), axis=0)
                return out.astype(g.dtype), r
            # stage 1: within-pod sum, scattered over `data` in native
            # layout (forced output sharding => reduce-scatter on the ICI)
            ent = [None] * (1 + len(shape))
            ent[0], ent[1 + dim] = "pod", "data"
            gp = jnp.sum(
                g.astype(jnp.float32).reshape(
                    (pod_size, d_size) + shape), axis=1)
            gp = _wsc(gp, *ent)
            # stage 2: int8+EF per pod shard; replicating q over `pod`
            # forces the s8 all-gather — the DCI hop carries 1 byte/el of a
            # 1/D shard
            # per-pod residuals feed back whole; a shared (R==1) residual is
            # split so the total error added across pods stays r
            rfeed = (r if r.ndim and r.shape[0] == pod_size
                     else r / pod_size)
            q, scale, err = jax.vmap(compress.quantize_ef)(
                gp, jnp.broadcast_to(rfeed, gp.shape))
            # double-pin: produce q pod-sharded, then demand it replicated —
            # the reshard between the two constraints IS the s8 all-gather
            # (one pin only, and the partitioner hoists the reshard to the
            # f32 input instead)
            q = _wsc(_wsc(q, *ent), None, *ent[1:])
            scale = _wsc(_wsc(scale, "pod"), None)
            deq = (q.astype(jnp.float32)
                   * scale.reshape((pod_size,) + (1,) * len(shape)))
            gs = jnp.sum(deq, axis=0) / n_slices
            # per-chip residuals when R == pod_size; pod 0's otherwise
            # (scalar placeholders — with_residuals=False — stay zeros)
            if r.ndim:
                r_ent = list(ent)
                if r.shape[0] != pod_size:
                    r_ent[0] = None     # degenerate: replicate over pod
                r = _wsc(err[: r.shape[0]], *r_ent)
            # stage 3: rebuild intra-pod — the `data` all-gather XLA
            # inserts for the optimizer runs in bf16 on the ICI
            return gs.astype(jnp.bfloat16).astype(g.dtype), r

        flat_g, tdef = jax.tree.flatten(grads_k)
        flat_r = jax.tree.leaves(state.residuals)
        synced = [sync(g, r) for g, r in zip(flat_g, flat_r)]
        grads = jax.tree.unflatten(tdef, [s[0] for s in synced])
        residuals = jax.tree.unflatten(tdef, [s[1] for s in synced])

        params, opt_state, opt_m = opt_lib.update(
            opt_cfg, state.opt, grads, state.params)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_k)
        return TrainState(params, opt_state, residuals), {**metrics, **opt_m}

    return step


# --------------------------------------------------------------------------
# Variant 1b: comm-priority on a single-pod mesh (microbatch accumulation)
# --------------------------------------------------------------------------

def _comm_priority_singlepod_step(loss_fn, opt_cfg, n_micro: int = 2):
    def step(state: TrainState, batch: dict):
        def micro(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, mb)
            gacc = jax.tree.map(jnp.add, gacc, grads)
            return (gacc, lacc + loss), None

        mbs = jax.tree.map(
            lambda v: v.reshape((n_micro, v.shape[0] // n_micro)
                                + v.shape[1:]),
            batch,
        )
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                             state.params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt_state, opt_m = opt_lib.update(
            opt_cfg, state.opt, grads, state.params)
        metrics = {"loss": lsum / n_micro, "ce": lsum / n_micro, **opt_m}
        return TrainState(params, opt_state, state.residuals), metrics

    return step


# --------------------------------------------------------------------------
# Public builder
# --------------------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_lib.OptimizerConfig,
    *,
    mesh: Optional[Mesh] = None,
    variant: int = BALANCED,
    use_kernel: bool = False,
    donate: bool = True,
):
    """Returns an UNJITTED step fn (state, batch) -> (state, metrics).

    The launcher jits it with in/out shardings resolved from the logical
    spec trees — one compiled executable per variant, dispatched by the
    KF scheduler.
    """
    loss_fn = make_loss_fn(cfg, use_kernel=use_kernel)
    if variant == BALANCED:
        return _balanced_step(loss_fn, opt_cfg)
    if mesh is not None and len(mesh.axis_names) >= 2 and any(
        a in mesh.axis_names for a in ("pod",)
    ):
        return _comm_priority_multipod_step(loss_fn, opt_cfg, mesh)
    return _comm_priority_singlepod_step(loss_fn, opt_cfg)


def jit_step(step_fn, mesh: Mesh, state: TrainState, state_specs: TrainState,
             batch: dict):
    """Resolve logical specs -> NamedShardings and jit with donation."""
    state_sh = sharding.shard_specs(state_specs, state, mesh)
    batch_sh = jax.tree.map(
        lambda v: NamedSharding(
            mesh,
            sharding.logical_to_mesh(
                P("batch", *([None] * (v.ndim - 1))), v.shape, mesh
            ),
        ),
        batch,
    )
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
