"""Training substrate: optimizer, step builder, loop, remat policies."""
