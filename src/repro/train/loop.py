"""Fault-tolerant training loop with the KF scheduler in the driver's seat.

Composition per step:
  prefetcher (latency class) -> telemetry.mark_input_ready
  -> dispatch the KF-selected compiled variant (bandwidth class)
  -> scheduler.on_step() (KF epoch update at epoch boundaries)
  -> async checkpoint every `ckpt_every` (atomic, crash-safe)

Fault tolerance:
  * restart-safe: data is a pure function of (seed, step); restore_latest +
    the step counter reproduce the exact stream (tested bit-identical);
  * crash injection: `fail_at` raises mid-run for the restart tests;
  * straggler detection: EMA step-time watchdog counts outliers
    (> straggler_factor x EMA); at fleet scale the same signal feeds the
    per-pod FleetKF bank — here it is logged and exported in the result.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


from repro.ckpt import io as ckpt_io
from repro.data.prefetch import Prefetcher
from repro.dist.kf_scheduler import KFScheduler
from repro.train.step import TrainState


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    prefetch_depth: int = 2


@dataclasses.dataclass
class LoopResult:
    state: TrainState
    losses: list
    variants: list
    straggler_events: int
    restored_from: Optional[int]


def run(
    cfg: LoopConfig,
    state: TrainState,
    step_fns: dict[int, Callable],      # variant -> jitted step
    make_batch: Callable[[int], dict],
    scheduler: Optional[KFScheduler] = None,
    *,
    fail_at: Optional[int] = None,
    log: Callable[[str], None] = print,
) -> LoopResult:
    start_step = 0
    restored_from = None
    if cfg.ckpt_dir:
        restored = ckpt_io.restore_latest(cfg.ckpt_dir, state)
        if restored is not None:
            start_step, state = restored
            restored_from = start_step
            log(f"[loop] restored checkpoint at step {start_step}")

    saver = ckpt_io.AsyncSaver()
    prefetch = Prefetcher(make_batch, depth=cfg.prefetch_depth,
                          start_step=start_step)
    losses, variants = [], []
    straggler_events = 0
    ema_dt = None
    variant = scheduler.variant if scheduler else 0

    try:
        for step in range(start_step, cfg.total_steps):
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected failure at step {step}")

            timer = scheduler.telemetry.timer if scheduler else None
            if timer:
                timer.step_begin()
            fetched_step, batch = prefetch.get()
            assert fetched_step == step, (fetched_step, step)
            if timer:
                timer.mark_input_ready()

            t0 = time.perf_counter()
            state, metrics = step_fns[variant](state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if timer:
                timer.step_end()

            # straggler watchdog (step 0 pays JIT compilation — it must not
            # seed the baseline or real stragglers hide under its shadow)
            if step == start_step:
                pass
            elif ema_dt is None:
                ema_dt = dt
            else:
                if dt > cfg.straggler_factor * ema_dt:
                    straggler_events += 1
                    log(f"[loop] straggler: step {step} took {dt:.3f}s "
                        f"(EMA {ema_dt:.3f}s)")
                ema_dt = 0.9 * ema_dt + 0.1 * dt

            losses.append(loss)
            variants.append(variant)
            if scheduler:
                variant = scheduler.on_step()
                if variant not in step_fns:
                    variant = 0

            if cfg.log_every and step % cfg.log_every == 0:
                log(f"[loop] step {step} loss {loss:.4f} "
                    f"variant {variant} dt {dt * 1e3:.1f}ms")

            if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
                saver.save(cfg.ckpt_dir, step + 1, state,
                           keep_last=cfg.keep_last)
    finally:
        prefetch.close()
        saver.wait()

    return LoopResult(
        state=state,
        losses=losses,
        variants=variants,
        straggler_events=straggler_events,
        restored_from=restored_from,
    )
