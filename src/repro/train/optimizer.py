"""AdamW + global-norm clip + warmup-cosine schedule, pure-pytree.

Moment dtype is configurable per arch (`cfg.optimizer_dtype`): the 314B/400B
MoE configs use bf16 moments to fit the 16 GB/chip x 512 envelope; error
introduced by bf16 moments is bounded by stochastic-rounding-free Adam's own
epsilon floor and is the standard trade at that scale (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"   # float32 | bfloat16


class OptState(NamedTuple):
    step: Array   # () int32
    mu: Any       # first moments (pytree like params)
    nu: Any       # second moments


def _mdtype(cfg: OptimizerConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init(cfg: OptimizerConfig, params: Any) -> OptState:
    dt = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.int32(0),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def schedule(cfg: OptimizerConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def _decay_mask(path: tuple, p: Array) -> bool:
    """No weight decay on 1-D tensors (norms, biases) — standard LLM recipe."""
    return p.ndim >= 2


def update(
    cfg: OptimizerConfig, state: OptState, grads: Any, params: Any
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    mdt = _mdtype(cfg)
    # bias correction in fp32
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask((), p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_state = OptState(step=step, mu=new_mu, nu=new_nu)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_specs(param_specs: Any) -> OptState:
    """Moments shard exactly like their parameters."""
    from jax.sharding import PartitionSpec as P

    return OptState(step=P(), mu=param_specs, nu=param_specs)
