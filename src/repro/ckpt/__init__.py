"""Checkpointing: atomic sharded save/restore + elastic remesh/reshard."""
