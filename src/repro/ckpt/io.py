"""Atomic, restart-safe checkpointing with async writes.

Layout:  <dir>/step_<k>/ {manifest.json, arrays.npz}  +  <dir>/LATEST

Fault-tolerance contract (exercised in tests/test_ckpt.py):
  * writes go to `step_<k>.tmp/` and are renamed into place only after the
    manifest (with per-array checksums) is fully written — a crash mid-save
    can never corrupt the restore path;
  * `restore_latest` walks checkpoints newest-first and skips any whose
    manifest or checksums fail — surviving partial/corrupt snapshots;
  * saves can run on a background thread (`async_save`), overlapping the
    next training steps (device arrays are snapshotted to host first);
  * keep_last bounds disk usage.

At real multi-pod scale each host writes only its addressable shards (the
manifest records the global shape + sharding spec); in this single-process
container the gather is the identity, and `elastic.py` proves the
reshard-on-restore logic the multi-host path relies on.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_token(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3) -> str:
    """Blocking atomic save. Returns the final checkpoint path."""
    flat = _flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "arrays": {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            }
            for k, v in flat.items()
        },
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class AsyncSaver:
    """One in-flight background save; `wait()` before the next snapshot."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            self.last_path = save(ckpt_dir, step, host_tree,
                                  keep_last=keep_last)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _validate(path: str) -> Optional[dict]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for k, meta in manifest["arrays"].items():
                v = z[k]
                if list(v.shape) != meta["shape"]:
                    return None
                if zlib.crc32(np.ascontiguousarray(v).tobytes()) != meta["crc32"]:
                    return None
        return manifest
    except Exception:
        return None


def restore_latest(
    ckpt_dir: str, template: Any, *, shardings: Any = None
) -> Optional[tuple[int, Any]]:
    """Restore the newest valid checkpoint into `template`'s structure.

    Corrupt/partial checkpoints are skipped (newest-first scan).  If
    `shardings` (matching pytree of NamedSharding) is given, arrays are
    device_put with those shardings — this is the elastic-restart hook.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    candidates = sorted(
        (d for d in os.listdir(ckpt_dir)
         if d.startswith("step_") and not d.endswith(".tmp")),
        reverse=True,
    )
    for cand in candidates:
        path = os.path.join(ckpt_dir, cand)
        manifest = _validate(path)
        if manifest is None:
            continue
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}

        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        out_leaves = []
        ok = True
        for pth, leaf in leaves_t:
            key = SEP.join(_path_token(p) for p in pth)
            if key not in arrays:
                ok = False
                break
            arr = arrays[key]
            if arr.dtype.kind == "V":
                # npz stores extension dtypes (bfloat16) as raw void —
                # reinterpret via the manifest's recorded dtype
                arr = arr.view(np.dtype(manifest["arrays"][key]["dtype"]))
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            out_leaves.append(arr)
        if not ok:
            continue
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out_leaves
        )
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return int(manifest["step"]), tree
    return None
