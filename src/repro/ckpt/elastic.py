"""Elastic scaling: restore a checkpoint onto a DIFFERENT mesh.

Node failures at pod scale shrink the healthy device set; elastic restart
rebuilds a smaller (or larger) mesh and reshards the checkpoint onto it.
Because our sharding rules are *logical* (dist/sharding.py), resharding is
just re-resolving the same logical specs against the new mesh — divisibility
fallbacks (e.g. a model axis that no longer divides n_kv_heads) degrade to
replication automatically rather than failing the restart.

`plan_remesh` also implements the straggler policy: given a healthy-device
count it picks the largest supported mesh shape <= healthy, preferring to
shrink the data axis first (keeps the model sharding — and therefore the
compiled executable's per-device shapes — stable across restarts when
possible).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.ckpt import io as ckpt_io
from repro.dist import sharding


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    dropped_devices: int


def plan_remesh(
    healthy_devices: int,
    *,
    model_parallel: int = 16,
    multi_pod_threshold: int = 512,
) -> RemeshPlan:
    """Largest (pod, data, model) grid that fits the healthy device count."""
    if healthy_devices < model_parallel:
        # degenerate: shrink model axis to the largest power of two that fits
        mp = 1
        while mp * 2 <= healthy_devices:
            mp *= 2
        return RemeshPlan((1, mp), ("data", "model"), healthy_devices - mp)
    data = healthy_devices // model_parallel
    if data * model_parallel >= multi_pod_threshold and data % 2 == 0:
        shape = (2, data // 2, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (data, model_parallel)
        axes = ("data", "model")
    used = int(np.prod(shape))
    return RemeshPlan(tuple(shape), axes, healthy_devices - used)


def make_mesh_from_plan(plan: RemeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    used = int(np.prod(plan.shape))
    grid = np.asarray(devices[:used]).reshape(plan.shape)
    return Mesh(grid, plan.axes)


def elastic_restore(
    ckpt_dir: str,
    template: Any,
    spec_tree: Any,
    new_mesh: Mesh,
) -> Optional[tuple[int, Any]]:
    """Restore newest checkpoint resharded onto `new_mesh` via logical specs."""
    shardings = sharding.shard_specs(spec_tree, template, new_mesh)
    return ckpt_io.restore_latest(ckpt_dir, template, shardings=shardings)
