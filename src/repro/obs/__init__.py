"""Flight-recorder observability layer (DESIGN.md §14).

Three layers, all opt-in and zero-cost when off:

  * probes.py    — `ProbeConfig` / `SimTrace`: per-epoch introspection
                   emitted by the traced simulator (occupancy, arbitration
                   grant/deny, MC queue depth, KF internals), bitwise-equal
                   across the `ref` and fused `pallas` cycle engines.
  * ledger.py    — structured run records: the single append path for
                   BENCH_noc.json plus a JSONL mirror, with the schema
                   validator that benchmarks/check_bench.py enforces.
  * profiling.py — jax.profiler trace contexts behind the fig drivers'
                   `--profile DIR` flag.
"""

from repro.obs.probes import ProbeConfig, SimTrace
from repro.obs import ledger, profiling
