"""Flight-recorder observability layer (DESIGN.md §14).

Three layers, all opt-in and zero-cost when off:

  * probes.py    — `ProbeConfig` / `SimTrace`: per-epoch introspection
                   emitted by the traced simulator (occupancy, arbitration
                   grant/deny, MC queue depth, KF internals), bitwise-equal
                   across the `ref` and fused `pallas` cycle engines.
  * ledger.py    — structured run records: the single append path for
                   BENCH_noc.json plus a JSONL mirror, with the schema
                   validator that benchmarks/check_bench.py enforces.
  * profiling.py — jax.profiler trace contexts behind the fig drivers'
                   `--profile DIR` flag.
  * recorder.py  — `TraceRecorder`: captures the per-epoch demand rows of
                   any run as a replayable `traffic.RecordedTrace`
                   (DESIGN.md §15), optionally stamped with the observed
                   §14 telemetry digest.
"""

from repro.obs.probes import ProbeConfig, SimTrace
from repro.obs import ledger, profiling, recorder
from repro.obs.recorder import TraceRecorder
