"""jax.profiler hooks behind the fig drivers' `--profile DIR` flag.

`profiled_run(outdir, fn)` runs `fn` twice under two separate profiler
traces: DIR/compile (first call — includes tracing + XLA compilation)
and DIR/steady (second call — jit caches warm, pure device execution).
With outdir falsy it degrades to a single plain call, so drivers can
wrap their `run(...)` unconditionally.

View the captures with `tensorboard --logdir DIR` or Perfetto
(`xprof`); the trace directories are plain TensorBoard event layouts.
"""

from __future__ import annotations

import contextlib
import os
from typing import Callable, Iterator, TypeVar

T = TypeVar("T")


@contextlib.contextmanager
def trace(outdir: str | None, label: str) -> Iterator[None]:
    """Profile the enclosed block into outdir/label (no-op when falsy)."""
    if not outdir:
        yield
        return
    import jax

    path = os.path.join(outdir, label)
    os.makedirs(path, exist_ok=True)
    with jax.profiler.trace(path):
        yield


def profiled_run(outdir: str | None, fn: Callable[[], T], label: str = "") -> T:
    """Call fn under compile- and steady-phase profiler traces.

    The doubled call is deliberate: one capture that mixes tracing,
    compilation, and execution is unattributable, which is the problem
    this flag exists to solve. Without `--profile` there is exactly one
    call and zero overhead.
    """
    if not outdir:
        return fn()
    prefix = f"{label}-" if label else ""
    with trace(outdir, f"{prefix}compile"):
        fn()
    with trace(outdir, f"{prefix}steady"):
        return fn()
