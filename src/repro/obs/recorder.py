"""Demand-trace recorder: capture per-epoch demand rows from any run
(DESIGN.md §15).

`TraceRecorder` turns a (config, source) pair into a `RecordedTrace` — the
replayable demand artifact of the TrafficSource redesign.  The capture is
the *input* side of a run: the exact per-epoch parameter rows
`traffic.resolve_source` lowered for the simulator's epoch scan.  Because
the simulator consumes nothing about demand but those rows (plus the seed,
which lives in the config), replaying the capture through the SAME config
is bitwise-identical to the originating run — the property
tests/test_traffic_source.py and the CI trace-replay smoke both pin.

With ``run=True`` the recorder also rides the §14 flight-recorder path
(`sim.simulate_with_trace`) and attaches the run's *observed* telemetry
digest (occupancy / arbitration / MC-queue / KF-innovation summaries) to
the trace's meta — provenance that says what the fabric actually did under
this demand, without changing the replayable rows.

Import note: this module must import `sim` lazily (inside functions) —
`sim.py` imports `repro.obs.probes` at module load, which loads this
package's ``__init__``; a top-level sim import here would cycle.
"""

from __future__ import annotations

import dataclasses

from repro.core.noc.traffic import RecordedTrace, WorkloadProfile

import numpy as np


def _source_descriptor(source) -> str:
    """A short human-readable provenance tag for a demand source."""
    if isinstance(source, str):
        return source
    name = getattr(source, "name", None)
    if isinstance(name, str) and name:
        return f"{type(source).__name__}:{name}"
    return type(source).__name__


@dataclasses.dataclass
class TraceRecorder:
    """Captures replayable demand traces from simulation runs.

    name     — base name stamped on captured traces.
    observe  — when True (default), `record` runs the simulation with the
               §14 flight recorder on and stores the observed telemetry
               digest + result summary in the trace meta; when False the
               capture is input-only (no simulation dispatched), which is
               what the cheap CI smoke uses.
    """

    name: str = "capture"
    observe: bool = True

    def record(self, cfg, source, backend: str | None = None) -> RecordedTrace:
        """Capture the per-epoch demand rows a (cfg, source) run consumes.

        Returns a `RecordedTrace` whose rows replay bitwise-identical to
        running ``source`` directly under the same ``cfg`` (fit="exact",
        length pinned to ``cfg.n_epochs``).
        """
        from repro.core.noc import sim
        from repro.core.noc.traffic import resolve_source

        demand = resolve_source(source, cfg.n_epochs)
        rows = WorkloadProfile(**{
            f: np.asarray(getattr(demand, f), np.float32)
            for f in WorkloadProfile._fields
        })
        meta = {
            "source": _source_descriptor(source),
            "mode": cfg.mode,
            "n_epochs": int(cfg.n_epochs),
            "epoch_len": int(cfg.epoch_len),
            "seed": int(cfg.seed),
            "backend": backend or cfg.backend,
            "recorder": "TraceRecorder",
        }
        if self.observe:
            from repro.obs.probes import summarize_trace

            res, trace = sim.simulate_with_trace(cfg, demand, backend=backend)
            meta["observed"] = summarize_trace(trace)
            meta["result"] = sim.summarize(res)
        return RecordedTrace(demand=rows, fit="exact", name=self.name,
                             meta=meta)

    def record_to(self, path, cfg, source,
                  backend: str | None = None) -> RecordedTrace:
        """`record` and save the capture as a versioned npz trace file."""
        trace = self.record(cfg, source, backend=backend)
        trace.save(path)
        return trace


def capture_demand(cfg, source, path=None, name: str = "capture",
                   observe: bool = False) -> RecordedTrace:
    """One-shot convenience: capture (and optionally save) a demand trace."""
    rec = TraceRecorder(name=name, observe=observe)
    if path is not None:
        return rec.record_to(path, cfg, source)
    return rec.record(cfg, source)
