"""Structured run ledger: the single append path for BENCH_noc.json.

Every benchmark record in this repo funnels through `append` (via
`benchmarks.bench_sweep.append_record`, which all drivers import), which

  * stamps the row with provenance — `ledger_version`, `git_sha`,
    `device_kind` — on top of the fields the bench already recorded
    (`bench`, `timestamp`, `backend`, trace counts, wall-clock);
  * validates the row against the schema below and refuses to write a
    malformed one;
  * appends to the committed JSON array AND mirrors the row as one JSONL
    line to LEDGER_noc.jsonl next to it (machine-tailable, gitignored).

`validate_row` is also the gate `benchmarks/check_bench.py` runs over
every committed row: rows stamped with `ledger_version` are hard-gated,
pre-ledger rows get the tolerated core check (see check_bench).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
from typing import Any

LEDGER_VERSION = 1

# Fields every bench row must carry, ledger-stamped or not.
CORE_FIELDS = {"bench": str, "timestamp": str, "backend": str}
# Fields `append` stamps; present on every row written since the ledger.
STAMP_FIELDS = {"ledger_version": int, "git_sha": str, "device_kind": str}


def git_sha(cwd: str | None = None) -> str:
    """Current commit sha, or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def device_kind() -> str:
    """Kind of jax.devices()[0] (e.g. "cpu", "TPU v4"), never raises."""
    try:
        import jax

        return str(jax.devices()[0].device_kind)
    except Exception:
        return "unknown"


def config_hash(obj: Any) -> str:
    """Stable short hash of a config (dataclass, namedtuple, or dict)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        obj = dataclasses.asdict(obj)
    elif hasattr(obj, "_asdict"):
        obj = obj._asdict()
    blob = json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def run_stamp() -> dict:
    return {
        "ledger_version": LEDGER_VERSION,
        "git_sha": git_sha(),
        "device_kind": device_kind(),
    }


def validate_row(row: Any, stamped: bool | None = None) -> list:
    """Return the list of schema problems (empty = valid).

    stamped=None infers from the row: a `ledger_version` key means the
    row was written through this module and must carry the full stamp.
    """
    problems = []
    if not isinstance(row, dict):
        return [f"row is {type(row).__name__}, expected object"]
    for field, typ in CORE_FIELDS.items():
        if field not in row:
            problems.append(f"missing required field {field!r}")
        elif not isinstance(row[field], typ):
            problems.append(
                f"field {field!r} is {type(row[field]).__name__}, "
                f"expected {typ.__name__}"
            )
    if stamped is None:
        stamped = "ledger_version" in row
    if stamped:
        for field, typ in STAMP_FIELDS.items():
            if field not in row:
                problems.append(f"missing stamp field {field!r}")
            elif not isinstance(row[field], typ):
                problems.append(
                    f"stamp field {field!r} is {type(row[field]).__name__}, "
                    f"expected {typ.__name__}"
                )
        ver = row.get("ledger_version")
        if isinstance(ver, int) and ver > LEDGER_VERSION:
            problems.append(
                f"ledger_version {ver} is newer than this validator "
                f"({LEDGER_VERSION})"
            )
    return problems


def jsonl_path(bench_path: str) -> str:
    return os.path.join(os.path.dirname(os.path.abspath(bench_path)),
                        "LEDGER_noc.jsonl")


def _append_jsonl_atomic(rec: dict, path: str) -> None:
    """Crash-safe JSONL mirror append: rewrite via temp file + atomic rename.

    A plain `open(..., "a")` interrupted mid-write leaves a truncated
    final line that poisons every later `check_bench` parse of the
    mirror.  Instead the existing content plus the new line are written
    to a temp file in the same directory and `os.replace`d over the
    mirror — readers see either the old file or the new one, never a
    torn line.  One retry absorbs a transient OSError (e.g. a racing
    scanner holding the file on some platforms)."""
    existing = ""
    if os.path.exists(path):
        with open(path) as f:
            existing = f.read()
        if existing and not existing.endswith("\n"):
            # a previously torn tail line: drop it rather than corrupt
            # the new row by gluing onto it
            existing = existing[:existing.rfind("\n") + 1]
    line = json.dumps(rec, sort_keys=True) + "\n"
    tmp = path + ".tmp"
    for attempt in (0, 1):
        try:
            with open(tmp, "w") as f:
                f.write(existing + line)
            os.replace(tmp, path)
            return
        except OSError:
            if attempt:
                raise


def append(rec: dict, path: str) -> dict:
    """Stamp, validate, and append `rec` to the bench array at `path`.

    Returns the stamped record. Raises ValueError instead of writing a
    row that fails the schema — a malformed committed row would turn the
    check_bench gate red for every later PR.  The JSONL mirror write is
    atomic (temp file + rename) so an interrupted run cannot leave a
    truncated line.
    """
    rec = dict(rec)
    for field, value in run_stamp().items():
        rec.setdefault(field, value)
    problems = validate_row(rec, stamped=True)
    if problems:
        raise ValueError(f"ledger row rejected: {problems} in {rec!r}")

    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    else:
        records = []
    records.append(rec)
    with open(path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")
    _append_jsonl_atomic(rec, jsonl_path(path))
    return rec
