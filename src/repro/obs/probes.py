"""Probe configuration and trace pytree for the NoC simulator.

`ProbeConfig` is a STATIC, hashable knob: it rides on `NoCConfig` /
`sim.SimStatic`, so flipping it produces a different compiled program.
Probes off (the default) leaves the simulator's traced computation — and
therefore the trace count and every golden capture — bit-for-bit
unchanged; probes on is its own single trace that additionally returns a
`SimTrace` alongside `SimResult`.

This module must stay import-light (no sim/router imports): sim.py
imports it at module load.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

from jax import Array


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """Static flight-recorder switch.

    enabled=False must be the all-default value: `SimStatic` embeds this
    dataclass, and any non-default field would change the jit cache key of
    every existing caller.
    """

    enabled: bool = False


class SimTrace(NamedTuple):
    """Per-epoch introspection stream (leading axis = n_epochs, E).

    Fabric probes are accumulated per cycle inside the epoch and summed
    (or maxed) over the epoch's `epoch_len` cycles; KF internals are the
    epoch-boundary filter step that produced `SimResult.kf_signal`.

    Shapes use S = padded subnets, R = routers, P = ports, V = VCs per
    subnet, and all fabric probes sample END-of-cycle state so the `ref`
    and fused `pallas` engines agree bitwise.
    """

    # fabric occupancy: sum over cycles of per-buffer flit count
    occ_sum: Array        # (E, S, R, P, V) int32
    # switch allocation: grants and refusals per router, summed over
    # output ports and cycles
    arb_grant: Array      # (E, S, R) int32
    arb_deny: Array       # (E, S, R) int32
    # memory-controller queue depth, summed / maxed over cycles
    mcq_sum: Array        # (E, R) int32
    mcq_max: Array        # (E, R) int32
    # KF internals at the epoch boundary (scalar-state, 3-obs filter)
    kf_innovation: Array  # (E, 3) float32
    kf_gain: Array        # (E, 3) float32
    kf_cov_trace: Array   # (E,)   float32
    kf_x_pred: Array      # (E,)   float32  one-step demand prediction
    # realized (normalized) observation vector the filter consumed —
    # kf_x_pred[e] vs z_obs[e+1] is the prediction-vs-realized pairing
    # (z_obs records the POST-corruption vector under telemetry faults:
    # what the filter actually saw)
    z_obs: Array          # (E, 3) float32
    # fault + self-healing channels (DESIGN.md §16): the fault->reject->
    # reset->recover chain, one sample per epoch
    kf_nis: Array         # (E,)   float32 normalized innovation squared
    kf_rejected: Array    # (E,)   int32 {0,1} innovation gate coasted
    kf_reset: Array       # (E,)   int32 {0,1} covariance reset fired
    kf_healthy: Array     # (E,)   int32 {0,1} watchdog verdict (0 => the
    #                       allocator ran the fair-split fallback)
    faults_active: Array  # (E,)   int32 suppressed fabric elements +
    #                       telemetry-corruption flag this epoch
    # placement channel (DESIGN.md §17): the virtual node class applied
    # each epoch (the placement plan the traced policy selected) — the
    # relocation timeline `noc_trace` renders.  Appended LAST so older
    # positional consumers of the fault channels keep their indices.
    place_cls: Array      # (E, R) int32 node class per router (NT_* values)


def summarize_trace(trace: SimTrace) -> dict:
    """Small JSON-friendly digest of a SimTrace (for ledger rows)."""
    import numpy as np

    occ = np.asarray(trace.occ_sum)
    healthy = np.asarray(trace.kf_healthy)
    return {
        "epochs": int(occ.shape[0]),
        "occ_sum_total": int(occ.sum()),
        "arb_grant_total": int(np.asarray(trace.arb_grant).sum()),
        "arb_deny_total": int(np.asarray(trace.arb_deny).sum()),
        "mcq_max": int(np.asarray(trace.mcq_max).max()),
        "kf_innovation_rms": float(
            np.sqrt(np.mean(np.square(np.asarray(trace.kf_innovation))))
        ),
        "kf_cov_trace_last": float(np.asarray(trace.kf_cov_trace)[-1]),
        "kf_rejected_total": int(np.asarray(trace.kf_rejected).sum()),
        "kf_reset_total": int(np.asarray(trace.kf_reset).sum()),
        "fallback_epochs": int((healthy == 0).sum()),
        "fault_epochs": int((np.asarray(trace.faults_active) > 0).sum()),
        # total router-epochs whose node class differs from the previous
        # epoch's plan: 0 on every identity-placement run
        "place_moves_total": int(
            (np.diff(np.asarray(trace.place_cls), axis=0) != 0).sum()
        ),
    }
