"""Scan-aware HLO cost model: trip-count-correct FLOPs / bytes / wire bytes.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE regardless of
trip count (verified in tests/test_hlo_cost.py) — fatally undercounting any
model built on `lax.scan` (all of ours: layers, mamba chunks, microbatches).

This module parses the PARTITIONED HLO text into its computation graph and
computes, bottom-up:

    total(comp) = sum(op costs) + sum(called_comp_total x multiplier)

with multiplier = trip count for while bodies (extracted from the loop
condition's comparison constant), 1 elsewhere.  Costs modeled:

  flops:  dot        2 x prod(result_dims) x k   (k from contracting dims)
          elementwise prod(result_dims)           (add/mul/exp/tanh/...)
          reduce      prod(operand_dims)
  bytes:  HBM traffic at op boundaries (result + operands) for the big
          movers: dot, fusion boundaries, dynamic-(update-)slice, copy,
          gather/scatter, concatenate, collectives.  Fusion-INTERNAL ops
          contribute flops only — matching how fused elementwise chains
          never round-trip HBM.
  wire:   collective ops weighted by ring-algorithm factors (all-reduce
          2(g-1)/g, all-gather (g-1)/g of gathered bytes, reduce-scatter
          (g-1)x shard bytes, all-to-all (g-1)/g, permute 1 hop), times
          enclosing trip counts — a collective inside the layer scan fires
          once per layer.

Operands are resolved through a module-wide SSA table (HLO prints operand
NAMES only at use sites).  Validated against XLA's cost_analysis on
scan-free programs in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "tanh", "rsqrt", "sqrt", "log",
    "log-plus-one", "negate", "abs", "cosine", "sine", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "cbrt",
    "erf", "select", "clamp", "and", "or", "xor", "not", "atan2",
    "remainder", "sign", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "compare", "is-finite",
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_BYTES_OPS = {"dot", "convolution", "fusion", "call", "dynamic-slice",
              "dynamic-update-slice", "copy", "gather", "scatter",
              "concatenate", "sort", "cholesky", "triangular-solve"}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\(.*?\))|(?:[\w\[\]{},]+))\s+"
    r"([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COND_ATTR = re.compile(r"condition=%?([\w.\-]+)")
_BODY_ATTR = re.compile(r"body=%?([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^}]*\}[^=]*?\}|\[[\d,]+\]<=\[[\d,]+\])")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_PERM_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def xla_cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on newer jax, a
    one-element list of dicts on older versions — normalize to the dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """Total (elements, bytes) over a possibly-tuple type string."""
    elems, bts = 0, 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_count: float = 0.0
    wire_cross_pod: float = 0.0   # bytes on pod-spanning groups (DCI class)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.wire_bytes += other.wire_bytes
        self.coll_count += other.coll_count
        self.wire_cross_pod += other.wire_cross_pod
        for k, v in other.wire_by_kind.items():
            self.wire_by_kind[k] = self.wire_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.wire_bytes * m,
                    {k: v * m for k, v in self.wire_by_kind.items()},
                    self.coll_count * m, self.wire_cross_pod * m)


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str    # everything after the opening paren of the operand list


@dataclasses.dataclass
class Module:
    comps: dict            # name -> list[Op]
    types: dict            # ssa name -> result type string
    entry: Optional[str]


def parse_module(hlo: str) -> Module:
    comps: dict[str, list[Op]] = {}
    types: dict[str, str] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = hdr.group(2)
            comps[cur] = []
            if hdr.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            comps[cur].append(op)
            types[op.name] = op.result_type
    return Module(comps=comps, types=types, entry=entry)


def _operand_names(rest: str) -> list[str]:
    """Names inside the operand parens (attrs after `), ` are cut off)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND_NAME.findall(rest[:i])
    return _OPERAND_NAME.findall(rest)


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if not m:
        return 1
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, len([x for x in first.split(",") if x.strip()]))
    dims = g[1:g.index("]")].split(",")
    return int(dims[-1]) if len(dims) >= 2 else max(1, int(dims[0]))


def _crosses_pod(rest: str, pod_size: Optional[int]) -> bool:
    """True when a collective's replica groups span pods.

    Explicit groups: any group with ids on both sides of a pod boundary.
    Iota form [G,S]<=[N]: consecutive groups — spans iff a group straddles
    a multiple of pod_size; transposed iota (`<=[..]T(..)`) produces
    strided groups, which on our (pod, data, model) mesh are exactly the
    pod-spanning ones."""
    if not pod_size:
        return False
    m = _GROUPS_RE.search(rest)
    if not m:
        return False
    g = m.group(1)
    if g.startswith("{{"):
        for grp in g[1:-1].split("},"):
            ids = [int(x) for x in grp.strip("{}").split(",") if x.strip()]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                return True
        return False
    # transposed iota (`[G,S]<=[..]T(..)`) => strided groups => pod-spanning
    # on our (pod, data, model) device order
    pos = rest.find(g)
    if pos >= 0 and "T(" in rest[pos:pos + len(g) + 24]:
        return True
    # plain iota [G,S]<=[N]: group i covers [i*S, (i+1)*S)
    dims = g[1:g.index("]")].split(",")
    if len(dims) >= 2:
        s = int(dims[-1])
        return any((i * s) // pod_size != ((i + 1) * s - 1) // pod_size
                   for i in range(int(dims[0])))
    return False


def _trip_count(cond_ops: list[Op]) -> int:
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = _CONST_INT.search(f"constant({op.rest}")
            if m:
                best = max(best, int(m.group(1)))
    return best


def _score_shaped(type_str: str, seq: Optional[int]) -> bool:
    """True for attention score/prob-class intermediates the flash kernel
    keeps in VMEM: ndim >= 4, last dim == seq, and a second-to-last dim
    that tiles seq (== seq unsharded; == seq/TP under sequence parallelism;
    == head_dim for k^T layout copies, which flash also never materializes).

    ndim >= 4 excludes (B, S, D) activations even when d_model == seq
    (glm4: 4096 x 4096) and all weight matrices; decode logits (…, 1, S)
    fail the >= 64 floor, so KV-cache reads are correctly retained."""
    if seq is None:
        return False
    for _, dims_s in _SHAPE.findall(type_str):
        dims = [int(d) for d in dims_s.split(",") if d]
        if (len(dims) >= 4 and dims[-1] == seq and dims[-2] >= 64
                and seq % dims[-2] == 0):
            return True
    return False


# our attention einsums label score-class ops in HLO metadata
# ("bqgrd,bkgd->bgrqk" scores; "bgrqk,bkgd->bqgrd" probs x V) — XLA keeps
# the label through layout-change fusions/transposes, catching transposed
# score tensors whose shapes evade the rule above.
_SCORE_LABEL = "bgrqk"


def _score_labeled(op: "Op") -> bool:
    return _SCORE_LABEL in op.rest


def _score_operand_factory(mod, seq):
    def f(op):
        for name in _operand_names(op.rest):
            t = mod.types.get(name, "")
            if _score_shaped(t, seq):
                return True
        return False
    return f


def _state_shaped(type_str: str, ssm_state: Optional[int]) -> bool:
    """True for (…, C, D, S) selective-scan intermediates (ndim >= 4 with a
    trailing ssm_state dim) — what the fused Pallas scan kernel
    (kernels/mamba_scan/fused.py) keeps in VMEM."""
    if ssm_state is None:
        return False
    for _, dims_s in _SHAPE.findall(type_str):
        dims = [int(d) for d in dims_s.split(",") if d]
        if len(dims) >= 4 and dims[-1] == ssm_state:
            return True
    return False


def analyze_hlo(hlo: str, *, seq: Optional[int] = None,
                assume_flash: bool = False,
                ssm_state: Optional[int] = None,
                assume_fused_scan: bool = False,
                pod_size: Optional[int] = None) -> Cost:
    """Trip-count-correct cost of the partitioned module.

    HBM-byte policy (projected TPU fusion — documented in EXPERIMENTS.md):
      dot/convolution      operands + result (stream in, stream out)
      fusion/call          result only (elementwise chains write once; their
                           reads are their producers' writes, counted there)
      slice/copy/gather/…  2 x result (read + write)
      collectives          2 x payload
      ENTRY parameters     once (weights/optimizer state read per step)

    assume_flash=True additionally drops the HBM bytes (never the FLOPs) of
    score-shaped ops — what the validated Pallas flash kernel keeps in VMEM.
    """
    mod = parse_module(hlo)
    if not mod.comps:
        return Cost()
    entry = mod.entry or next(iter(mod.comps))
    memo: dict[tuple[str, bool], Cost] = {}
    _score_operand = _score_operand_factory(mod, seq)

    def operand_bytes(op: Op) -> int:
        total = 0
        for name in _operand_names(op.rest):
            t = mod.types.get(name)
            if t:
                total += _shape_elems_bytes(t)[1]
        return total

    def comp_cost(name: str, in_fusion: bool, stack: tuple) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        if name not in mod.comps or name in stack:
            return Cost()
        total = Cost()
        for op in mod.comps[name]:
            total += op_cost(op, in_fusion, stack + (name,))
        memo[key] = total
        return total

    def op_cost(op: Op, in_fusion: bool, stack: tuple) -> Cost:
        c = Cost()
        oc = op.opcode
        relems, rbytes = _shape_elems_bytes(op.result_type)

        if oc == "while":
            body = _BODY_ATTR.search(op.rest)
            cond = _COND_ATTR.search(op.rest)
            trips = 1
            if cond and cond.group(1) in mod.comps:
                trips = _trip_count(mod.comps[cond.group(1)])
            if body:
                c += comp_cost(body.group(1), in_fusion,
                               stack).scaled(max(trips, 1))
            return c

        if oc in ("fusion", "call", "async-start"):
            m = _CALL_ATTR.search(op.rest)
            if m:
                inner = comp_cost(m.group(1), True, stack)
                c.flops += inner.flops
                c.wire_bytes += inner.wire_bytes
                c.coll_count += inner.coll_count
                for k, v in inner.wire_by_kind.items():
                    c.wire_by_kind[k] = c.wire_by_kind.get(k, 0.0) + v
            drop = (assume_flash and (_score_shaped(op.result_type, seq)
                                      or _score_labeled(op))) \
                or (assume_fused_scan
                    and _state_shaped(op.result_type, ssm_state))
            if not in_fusion and not drop:
                c.bytes += rbytes  # write; reads = producers' writes
            return c

        if oc == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                op.rest)
            for b in branches:
                c += comp_cost(b, in_fusion, stack)
            return c

        base = oc.replace("-start", "")
        if base in _COLL_KINDS and not oc.endswith("-done"):
            g = _group_size(op.rest)
            # -start result types include aliased input tuples; take the
            # LAST array in the tuple as the payload (output buffer)
            shapes = _SHAPE.findall(op.result_type)
            payload = 0
            if shapes:
                dt, dims = shapes[-1]
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                payload = n * _DTYPE_BYTES.get(dt, 0)
            if base == "all-reduce":
                wire = 2.0 * (g - 1) / g * payload
            elif base == "all-gather":
                wire = (g - 1) / g * payload
            elif base == "reduce-scatter":
                wire = (g - 1) * payload
            elif base == "all-to-all":
                wire = (g - 1) / g * payload
            else:
                wire = float(payload)
                pm = _PERM_RE.search(op.rest)
                if pm and not pm.group(1).strip():
                    wire = 0.0
            c.wire_bytes += wire
            c.coll_count += 1
            c.wire_by_kind[base] = c.wire_by_kind.get(base, 0.0) + wire
            if _crosses_pod(op.rest, pod_size):
                c.wire_cross_pod += wire
            if not in_fusion:
                c.bytes += 2 * payload  # read + write
            return c

        if oc == "dot":
            lhs_names = _operand_names(op.rest)
            k = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
            if m and lhs_names:
                t = mod.types.get(lhs_names[0], "")
                sm = _SHAPE.search(t)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for i in (int(x) for x in m.group(1).split(",") if x):
                        if i < len(dims):
                            k *= dims[i]
            c.flops += 2.0 * relems * k
            if not in_fusion:
                drop = (assume_flash and (
                    _score_shaped(op.result_type, seq)
                    or _score_labeled(op)
                    or _score_operand(op))) \
                    or (assume_fused_scan
                        and _state_shaped(op.result_type, ssm_state))
                if not drop:
                    c.bytes += rbytes + operand_bytes(op)
            return c

        if oc == "convolution":
            names = _operand_names(op.rest)
            k = 1
            if len(names) >= 2:
                t = mod.types.get(names[1], "")
                sm = _SHAPE.search(t)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for d in dims[:-1]:
                        k *= max(d, 1)
            c.flops += 2.0 * relems * max(k, 1)
            if not in_fusion:
                c.bytes += rbytes + operand_bytes(op)
            return c

        if oc in _ELEMENTWISE:
            c.flops += relems
            return c

        if oc in ("reduce", "reduce-window"):
            names = _operand_names(op.rest)
            oelems = 0
            for n in names[:1]:
                t = mod.types.get(n, "")
                oelems += _shape_elems_bytes(t)[0]
            c.flops += max(oelems, relems)
            return c

        if oc in _BYTES_OPS and not in_fusion:
            drop = (assume_fused_scan
                    and _state_shaped(op.result_type, ssm_state)) \
                or (assume_flash and _score_labeled(op))
            if not drop:
                c.bytes += 2 * rbytes
            return c

        return c

    total = comp_cost(entry, False, ())
    for op in mod.comps.get(entry, []):
        if op.opcode == "parameter":
            total.bytes += _shape_elems_bytes(op.result_type)[1]
    return total
