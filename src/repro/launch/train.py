"""End-to-end training driver with the KF scheduler in the loop.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --size smoke --steps 200 --kf --ckpt-dir /tmp/ckpt

`--size smoke` trains the reduced config on the host mesh (CPU-runnable,
used by examples/); `--size full` targets the production mesh.  Both
compile the two step variants (balanced / comm-priority) up front and let
the KF scheduler dispatch between them — the paper's pre-defined
configuration model.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.configs as configs
from repro.data import synthetic
from repro.dist import sharding
from repro.dist.kf_scheduler import KFScheduler, SchedulerConfig
from repro.dist.telemetry import StaticCosts, Telemetry
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib


def build(arch: str, size: str, seq_len: int, global_batch: int,
          mesh=None, lr: float = 3e-4, total_steps: int = 1000,
          seed: int = 0, use_kf: bool = True):
    """Returns (state, step_fns, make_batch, scheduler, mesh)."""
    cfg = configs.smoke(arch) if size == "smoke" else configs.get(arch)
    mesh = mesh if mesh is not None else make_host_mesh()
    opt_cfg = opt_lib.OptimizerConfig(
        lr=lr, total_steps=total_steps,
        moment_dtype=cfg.optimizer_dtype)

    with sharding.activate(mesh):
        state, specs_tree = step_lib.init_train_state(
            jax.random.PRNGKey(seed), cfg, opt_cfg)
        ds = synthetic.make_dataset(cfg, seq_len, global_batch, seed=seed)
        batch0 = ds.batch(0)
        step_fns = {}
        for variant in (step_lib.BALANCED, step_lib.COMM_PRIORITY):
            fn = step_lib.make_train_step(
                cfg, opt_cfg, mesh=mesh, variant=variant)
            step_fns[variant] = step_lib.jit_step(
                fn, mesh, state, specs_tree, batch0)

    scheduler = None
    if use_kf:
        telemetry = Telemetry(costs_by_variant={
            0: StaticCosts(flops=0, hbm_bytes=0, collective_bytes=1e9),
            1: StaticCosts(flops=0, hbm_bytes=0, collective_bytes=2.5e8),
        })
        scheduler = KFScheduler(SchedulerConfig(
            epoch_steps=10, warmup_steps=30, hold_steps=20,
            revert_steps=60), telemetry)

    return state, step_fns, ds.batch, scheduler, mesh, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--size", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kf", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = (make_production_mesh() if args.size == "full"
            else make_host_mesh())
    state, step_fns, make_batch, scheduler, mesh, cfg = build(
        args.arch, args.size, args.seq_len, args.global_batch,
        mesh=mesh, lr=args.lr, total_steps=args.steps, seed=args.seed,
        use_kf=args.kf)

    loop_cfg = loop_lib.LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    with sharding.activate(mesh):
        result = loop_lib.run(loop_cfg, state, step_fns, make_batch,
                              scheduler, fail_at=args.fail_at)
    losses = result.losses
    print(f"[train] {args.arch} ({args.size}) {len(losses)} steps: "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(min {np.min(losses):.4f}); "
          f"stragglers={result.straggler_events}; "
          f"variants used={sorted(set(result.variants))}")
    return result


if __name__ == "__main__":
    main()
