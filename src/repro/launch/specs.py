"""Input ShapeDtypeStruct stand-ins + step builders for every
(architecture x input-shape) cell — the dry-run's contract.

Shapes (assignment):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step (fwd logits)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV/SSM cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid/SWA
                                                 archs only (sub-quadratic)

Applicability:
  * long_500k skipped for pure full-attention archs (DESIGN.md §5);
  * seamless-m4t (enc-dec): train/prefill run the teacher-forced decoder
    over `seq` tokens with `frontend_len` encoder frames; decode shapes
    lower its DECODER step (self-KV cache of seq_len + precomputed cross
    K/V) — it is not encoder-only, so decode cells run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# archs with sub-quadratic long-context decode (DESIGN.md §5)
LONG_CTX_ARCHS = ("h2o-danube-1.8b", "zamba2-2.7b", "falcon-mamba-7b")


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CTX_ARCHS
    return True


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in configs.ARCH_IDS for s in SHAPES]


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Abstract train/prefill batch for one cell."""
    b, s = cell.batch, cell.seq
    out = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
        "mask": _sds((b, s), jnp.float32),
    }
    if cfg.frontend:
        out["embeds"] = _sds(
            (b, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    return out


# --------------------------------------------------------------------------
# Abstract state builders (eval_shape — nothing is allocated)
# --------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig,
                         *, with_residuals: bool = False,
                         data_size: int = 1, pod_size: int = 1):
    """(abstract TrainState, spec tree) — nothing allocated (eval_shape).

    The spec tree holds PartitionSpecs (plain data); it is captured from
    inside the traced init via a holder so no real params ever exist.
    """
    holder = {}

    def init():
        state, specs = step_lib.init_train_state(
            jax.random.PRNGKey(0), cfg, opt_cfg,
            with_residuals=with_residuals, data_size=data_size,
            pod_size=pod_size)
        holder["specs"] = specs
        return state

    state = jax.eval_shape(init)
    return state, holder["specs"]


def abstract_params(cfg: ModelConfig):
    def init():
        if cfg.is_encoder_decoder:
            return encdec.make_encdec(jax.random.PRNGKey(0), cfg)[0]
        return lm.make_lm(jax.random.PRNGKey(0), cfg)[0]

    return jax.eval_shape(init)


def param_specs(cfg: ModelConfig):
    """Spec trees contain no arrays; safe to build eagerly via eval_shape
    closure trick: run make_* under eval_shape but return only specs."""
    if cfg.is_encoder_decoder:
        maker = encdec.make_encdec
    else:
        maker = lm.make_lm

    holder = {}

    def init():
        params, specs = maker(jax.random.PRNGKey(0), cfg)
        holder["specs"] = specs
        return params

    jax.eval_shape(init)
    return holder["specs"]


def abstract_decode_inputs(cfg: ModelConfig, cell: ShapeCell):
    """(token, state) ShapeDtypeStructs for serve_step at this cell."""
    b, s = cell.batch, cell.seq
    token = _sds((b, 1), jnp.int32)
    if cfg.is_encoder_decoder:
        def init():
            # cross K/V from a frontend_len encoder pass; self cache len s
            kv = jax.ShapeDtypeStruct
            state = encdec.EncDecState(
                self_kv=lm.KVCache(
                    k=jnp.zeros((cfg.n_layers, b, s, cfg.n_kv_heads,
                                 cfg.head_dim), lm.ACT_DTYPE),
                    v=jnp.zeros((cfg.n_layers, b, s, cfg.n_kv_heads,
                                 cfg.head_dim), lm.ACT_DTYPE),
                    length=jnp.zeros((cfg.n_layers, b), jnp.int32),
                ),
                cross_k=jnp.zeros((cfg.n_layers, b, cfg.frontend_len,
                                   cfg.n_kv_heads, cfg.head_dim),
                                  lm.ACT_DTYPE),
                cross_v=jnp.zeros((cfg.n_layers, b, cfg.frontend_len,
                                   cfg.n_kv_heads, cfg.head_dim),
                                  lm.ACT_DTYPE),
                length=jnp.zeros((b,), jnp.int32),
            )
            return state

        state = jax.eval_shape(init)
        return token, state
    state = jax.eval_shape(
        functools.partial(lm.init_decode_state, b, s, cfg))
    return token, state


def decode_specs(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.encdec_state_specs(cfg)
    return lm.decode_state_specs(cfg)


# --------------------------------------------------------------------------
# Step functions per cell kind
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig) -> Callable:
    if cfg.is_encoder_decoder:
        def prefill(params, batch):
            return encdec.forward(params, batch["tokens"], batch["embeds"],
                                  cfg)
        return prefill

    def prefill(params, batch):
        return lm.forward(params, batch["tokens"], cfg,
                          embeds=batch.get("embeds")).logits

    return prefill


def make_serve_step(cfg: ModelConfig) -> Callable:
    if cfg.is_encoder_decoder:
        def serve(params, token, state):
            return encdec.decode_step(params, token, state, cfg)
        return serve

    def serve(params, token, state):
        return lm.decode_step(params, token, state, cfg)

    return serve


def default_opt_cfg(cfg: ModelConfig) -> opt_lib.OptimizerConfig:
    return opt_lib.OptimizerConfig(moment_dtype=cfg.optimizer_dtype)
