"""Serving driver: continuous batching with KF-arbitrated scheduling.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --mode kf --requests 48

Runs the reduced config on the host mesh with the bursty synthetic
workload and prints the latency/throughput summary for the chosen
arbitration mode (rr | static | kf) — the serving-side A/B of the paper's
technique (benchmarks/kf_scheduler_ab.py sweeps all three).
"""
from __future__ import annotations

import argparse
import json

import jax

import repro.configs as configs
from repro.models import lm
from repro.serve import batching
from repro.serve.engine import Engine, EngineConfig


def run(arch: str, mode: str, n_requests: int = 48, seed: int = 0,
        max_slots: int = 8, max_len: int = 128, budget: int = 128):
    cfg = configs.smoke(arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("serve driver targets decoder LMs; "
                         "seamless decode is covered by the dry-run")
    params, _ = lm.make_lm(jax.random.PRNGKey(seed), cfg)
    wl = batching.WorkloadConfig(n_requests=n_requests, mean_prompt=48,
                                 mean_gen=12, seed=seed)
    ecfg = EngineConfig(mode=mode, max_slots=max_slots, max_len=max_len,
                        budget_tokens=budget)
    engine = Engine(params, cfg, ecfg, seed=seed)
    stats = engine.run(batching.generate(wl))
    return stats.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--mode", default="kf", choices=["rr", "static", "kf"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    summary = run(args.arch, args.mode, args.requests, args.seed)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
