"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the methodology:

    compute_s    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory_s     = HLO_bytes / (chips x 819 GB/s HBM)
    collective_s = wire_bytes_per_chip / 50 GB/s ICI link

FLOPs / bytes come from `compiled.cost_analysis()` (per-device module after
SPMD partitioning — verified against 6ND in tests; if the backend reports
global numbers the chips divisor normalizes them, and the MODEL_FLOPS ratio
column in EXPERIMENTS.md would expose any mismatch).

Wire bytes are parsed from the PARTITIONED `compiled.as_text()` — summing
result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute, weighted by ring-algorithm wire factors:

    all-reduce      2(g-1)/g x B        all-gather     (g-1) x B_shard
    reduce-scatter  (g-1) x B_out       all-to-all     (g-1)/g x B
    collective-permute  B  (one hop)

(g = replica-group size parsed per op; B = result bytes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional


# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s per ICI link
HBM_CAP = 16e9          # bytes

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shapes like  bf16[256,4096,5120]{2,1,0}  or tuples thereof
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)"
    r"(?!-done)\b(.*)$"
)
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^=]*\}|\[[\d,]+\]<=\[[\d,]+\])")
_PERM_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(attrs: str) -> int:
    m = _GROUPS_RE.search(attrs)
    if not m:
        return 1
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return len([x for x in first.split(",") if x.strip() != ""])
    # iota form: [n_groups, group_size]<=[total]
    dims = g[1:g.index("]")].split(",")
    return int(dims[-1]) if len(dims) >= 2 else int(dims[0])


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                 # per chip
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, wire: float):
        self.wire_bytes += wire
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + wire
        self.count += 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m is None:
            continue
        result_type, op, attrs = m.group(1), m.group(2), m.group(3)
        kind = op.replace("-start", "")
        b = _shape_bytes(result_type)
        if b == 0:
            continue
        g = _group_size(attrs)
        if kind == "all-reduce":
            wire = 2.0 * (g - 1) / g * b
        elif kind == "all-gather":
            # result is the gathered tensor; each chip receives (g-1)/g of it
            wire = (g - 1) / g * b
        elif kind == "reduce-scatter":
            wire = (g - 1) * b          # result is the per-chip shard
        elif kind == "all-to-all":
            wire = (g - 1) / g * b
        else:  # collective-permute
            wire = float(b)
            pm = _PERM_RE.search(attrs)
            if pm and not pm.group(1).strip():
                wire = 0.0
        stats.add(kind, wire)
    return stats


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: overlapped model = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """How close the cell is to the compute roofline (1.0 = compute
        bound at peak): compute_s / max(all terms)."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "compute_fraction": self.compute_fraction,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "n_chips": self.n_chips,
        }


def analyze(cost: dict, collectives: CollectiveStats, n_chips: int) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=collectives.wire_bytes / LINK_BW,
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        wire_bytes_per_chip=collectives.wire_bytes,
        n_chips=n_chips,
    )


def model_flops(cfg, cell, n_tokens: Optional[int] = None) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for the cell's token count;
    x3 total for train (fwd+bwd), x1 for prefill, per-token for decode."""
    n_params = count_params(cfg, active_only=True)
    if n_tokens is None:
        n_tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
    fwd = 2.0 * n_params * n_tokens
    return 3.0 * fwd if cell.kind == "train" else fwd


def count_params(cfg, active_only: bool = False) -> float:
    """Parameter count from the config (embedding included once)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    moe_mask = cfg.moe_layer_mask()
    for i in range(cfg.n_layers):
        if cfg.is_ssm or (cfg.is_hybrid and True):
            di, ds = cfg.d_inner, cfg.ssm_state
            if cfg.ssm_variant == "mamba1":
                r = -(-cfg.d_model // 16)
                total += d * 2 * di + cfg.ssm_conv * di + di * (r + 2 * ds) \
                    + r * di + di * ds + di * d
            else:
                nh = di // cfg.ssm_head_dim
                total += d * (2 * di + 2 * ds + nh) \
                    + cfg.ssm_conv * (di + 2 * ds) + di * d + di
        elif moe_mask[i]:
            att = d * cfg.n_heads * cfg.head_dim * 2 \
                + d * cfg.n_kv_heads * cfg.head_dim * 2
            e_active = cfg.n_experts_active if active_only else cfg.n_experts
            moe = 3 * d * f * e_active + d * cfg.n_experts  # + router
            if cfg.n_shared_experts:
                moe += 3 * d * f * cfg.n_shared_experts
            total += att + moe
        else:
            att = d * cfg.n_heads * cfg.head_dim * 2 \
                + d * cfg.n_kv_heads * cfg.head_dim * 2
            total += att + 3 * d * f
    if cfg.is_hybrid:
        # one shared attention+MLP block (counted once; applied n/period x)
        total += d * cfg.n_heads * cfg.head_dim * 2 \
            + d * cfg.n_kv_heads * cfg.head_dim * 2 + 3 * d * cfg.d_ff
    if cfg.is_encoder_decoder:
        enc = cfg.n_encoder_layers * (
            d * cfg.n_heads * cfg.head_dim * 2
            + d * cfg.n_kv_heads * cfg.head_dim * 2 + 3 * d * f)
        dec_cross = cfg.n_layers * (
            d * cfg.n_heads * cfg.head_dim * 2
            + d * cfg.n_kv_heads * cfg.head_dim * 2)
        total += enc + dec_cross
    return float(total)
