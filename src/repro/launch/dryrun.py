import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract memory / cost / collective
analyses for EXPERIMENTS.md §Dry-run and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod

Results land in results/dryrun/<mesh>_<arch>_<shape>.json.  The 512
placeholder host devices exist ONLY in this process (the env flag above is
set before jax initializes); smoke tests and benches see the host's real
single device.
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.dist import sharding
from repro.launch import hlo_cost, roofline, specs
from repro.launch.mesh import make_production_mesh
from repro.train import step as step_lib

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _sharding_tree(spec_tree, abstract_tree, mesh):
    return jax.tree.map(
        lambda s, a: NamedSharding(
            mesh, sharding.logical_to_mesh(s, getattr(a, "shape", None), mesh)
        ),
        spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_shardings(batch_abs, mesh):
    return jax.tree.map(
        lambda v: NamedSharding(
            mesh,
            sharding.logical_to_mesh(
                P("batch", *([None] * (len(v.shape) - 1))), v.shape, mesh),
        ),
        batch_abs,
    )


def lower_cell(arch: str, shape: str, mesh, *, variant: int = 0,
               remat: str = None, moe_group: int = 0):
    """Returns (lowered, n_chips). Raises on inapplicable cells."""
    import dataclasses as _dc

    cfg = configs.get(arch)
    if remat:
        cfg = _dc.replace(cfg, remat=remat)
    if moe_group:
        cfg = _dc.replace(cfg, moe_groups=moe_group)
    cell = specs.SHAPES[shape]
    if not specs.applicable(arch, shape):
        raise ValueError(f"{arch} x {shape}: skipped (DESIGN.md §5)")

    with sharding.activate(mesh):
        if cell.kind == "train":
            opt_cfg = specs.default_opt_cfg(cfg)
            mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            state_abs, state_specs = specs.abstract_train_state(
                cfg, opt_cfg,
                with_residuals=(variant == step_lib.COMM_PRIORITY
                                and "pod" in mesh.axis_names),
                data_size=mesh_sizes.get("data", 1),
                pod_size=mesh_sizes.get("pod", 1))
            batch_abs = specs.batch_struct(cfg, cell)
            step = step_lib.make_train_step(
                cfg, opt_cfg, mesh=mesh, variant=variant)
            state_sh = _sharding_tree(state_specs, state_abs, mesh)
            batch_sh = _batch_shardings(batch_abs, mesh)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif cell.kind == "prefill":
            params_abs = specs.abstract_params(cfg)
            pspecs = specs.param_specs(cfg)
            batch_abs = specs.batch_struct(cfg, cell)
            step = specs.make_prefill_step(cfg)
            params_sh = _sharding_tree(pspecs, params_abs, mesh)
            batch_sh = _batch_shardings(batch_abs, mesh)
            logits_sh = NamedSharding(
                mesh, sharding.logical_to_mesh(
                    P("batch", None, "vocab"),
                    (cell.batch, cell.seq, cfg.vocab_size), mesh))
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=logits_sh)
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            params_abs = specs.abstract_params(cfg)
            pspecs = specs.param_specs(cfg)
            token_abs, state_abs = specs.abstract_decode_inputs(cfg, cell)
            dspecs = specs.decode_specs(cfg)
            step = specs.make_serve_step(cfg)
            params_sh = _sharding_tree(pspecs, params_abs, mesh)
            state_sh = _sharding_tree(dspecs, state_abs, mesh)
            token_sh = NamedSharding(
                mesh, sharding.logical_to_mesh(
                    P("batch", None), (cell.batch, 1), mesh))
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, token_sh, state_sh),
                out_shardings=(None, state_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, token_abs, state_abs)
    return lowered, mesh.size


def run_cell(arch: str, shape: str, mesh_kind: str, *, variant: int = 0,
             out_dir: str = RESULTS_DIR, flash: bool = False,
             seq_parallel: bool = False, dp_only: bool = False,
             remat: str = None, moe_group: int = 0,
             fused_scan: bool = False, tag: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    record: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "mesh_shape": list(mesh.devices.shape),
        "variant": variant,
        "options": {"flash": flash, "seq_parallel": seq_parallel,
                    "dp_only": dp_only, "remat": remat,
                    "moe_group": moe_group, "fused_scan": fused_scan},
    }
    sharding.set_option("seq_parallel", seq_parallel)
    sharding.set_option("dp_only", dp_only)
    try:
        lowered, n_chips = lower_cell(arch, shape, mesh, variant=variant,
                                      remat=remat, moe_group=moe_group)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        xla_cost = hlo_cost.xla_cost_analysis(compiled)
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        # scan-aware analysis (XLA's cost_analysis counts while bodies once
        # — see launch/hlo_cost.py); the compiled module is per-device.
        _cfg0 = configs.get(arch)
        cost_c = hlo_cost.analyze_hlo(
            hlo_text,
            seq=specs.SHAPES[shape].seq if flash else None,
            assume_flash=flash,
            ssm_state=_cfg0.ssm_state if fused_scan else None,
            assume_fused_scan=fused_scan,
            pod_size=256 if mesh_kind == "multipod" else None)
        rl = roofline.analyze(
            {"flops": cost_c.flops, "bytes accessed": cost_c.bytes},
            roofline.CollectiveStats(
                wire_bytes=cost_c.wire_bytes, by_kind=cost_c.wire_by_kind,
                count=int(cost_c.coll_count)),
            n_chips)
        cfg = configs.get(arch)
        cell = specs.SHAPES[shape]
        mf = roofline.model_flops(cfg, cell)

        record.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost_xla_unscaled": {
                k: float(v) for k, v in xla_cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed")},
            "memory": _mem_dict(mem),
            "collectives": {
                "wire_bytes_per_chip": cost_c.wire_bytes,
                "wire_cross_pod_per_chip": cost_c.wire_cross_pod,
                "count": cost_c.coll_count,
                "by_kind": cost_c.wire_by_kind,
            },
            "roofline": rl.to_dict(),
            "model_flops_global": mf,
            "model_flops_per_chip": mf / n_chips,
            "useful_flops_ratio": (
                (mf / n_chips) / rl.flops_per_chip
                if rl.flops_per_chip else None),
        })
    except ValueError as e:
        if "skipped" in str(e):
            record.update({"status": "skip", "reason": str(e)})
        else:
            record.update({"status": "error", "error": str(e),
                           "traceback": traceback.format_exc()})
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        record.update({"status": "error", "error": str(e)[:2000],
                       "traceback": traceback.format_exc()[-4000:]})

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{mesh_kind}_{arch}_{shape}" + \
        (f"_v{variant}" if variant else "") + \
        (f"_{tag}" if tag else "") + ".json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1, default=float)
    sharding.set_option("seq_parallel", False)
    sharding.set_option("dp_only", False)
    return record


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if out:
        out["total_hbm_bytes"] = (
            out.get("temp_size_in_bytes", 0)
            + out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--variant", type=int, default=0)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    # §Perf hillclimb knobs
    ap.add_argument("--flash", action="store_true",
                    help="analyze with the Pallas flash-attention traffic model")
    ap.add_argument("--fused-scan", action="store_true",
                    help="analyze with the fused mamba-scan kernel traffic model")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "full", "dots",
                                                      "none"])
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = (specs.all_cells() if args.all
             else [(args.arch, args.shape)])
    for arch, shape in cells:
        if arch is None or shape is None:
            ap.error("--arch/--shape required unless --all")
        rec = run_cell(arch, shape, args.mesh, variant=args.variant,
                       out_dir=args.out, flash=args.flash,
                       seq_parallel=args.seq_parallel, dp_only=args.dp_only,
                       remat=args.remat, moe_group=args.moe_group,
                       fused_scan=args.fused_scan, tag=args.tag)
        status = rec["status"]
        extra = ""
        if status == "ok":
            rl = rec["roofline"]
            extra = (f" dom={rl['dominant']} t={rl['step_time_s']:.4f}s "
                     f"compile={rec['compile_s']:.0f}s")
        print(f"[dryrun] {args.mesh} {arch} {shape}: {status}{extra}",
              flush=True)
        if status == "ok":
            print(f"  memory_analysis: {rec['memory']}")
            print(f"  cost_analysis (scan-corrected): "
                  f"flops/chip={rec['roofline']['flops_per_chip']:.3e} "
                  f"bytes/chip={rec['roofline']['hbm_bytes_per_chip']:.3e} "
                  f"wire/chip={rec['roofline']['wire_bytes_per_chip']:.3e}",
                  flush=True)


if __name__ == "__main__":
    main()
