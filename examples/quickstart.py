"""Quickstart: the paper's technique end to end in three acts.

  1. run the Kalman Filter on a synthetic bursty trace (core algorithm);
  2. run the flit-level NoC simulation with KF-reconfigured VC allocation
     vs the static-fair baseline (the paper's evaluation, reduced);
  3. run the TPU adaptation: a tiny LM trained with the KF scheduler
     choosing between pre-compiled step variants.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import kalman
from repro.core.allocator import PolicyConfig, apply_policy, init_policy_state


def act1_kalman():
    print("=== 1. Kalman Filter on a bursty trace (paper Eqs. 1-5) ===")
    rng = np.random.default_rng(0)
    t = np.arange(200)
    burst = (np.sin(t / 15) > 0.4).astype(np.float32)       # bursty phases
    z = np.stack([
        burst * 0.8 + rng.normal(0, 0.15, 200),             # dramfull
        burst * 0.6 + rng.normal(0, 0.15, 200),             # icnt push
        burst * 0.9 + rng.normal(0, 0.15, 200),             # stall icnt
    ], axis=1).astype(np.float32)

    params = kalman.paper_params()
    state = kalman.init_state(1)
    _, (xs, _) = kalman.filter_trace(params, state, jnp.asarray(z))
    signal = kalman.binarize(xs[:, 0])
    agree = float(jnp.mean((signal == burst.astype(jnp.int32)) * 1.0))
    print(f"KF tracks the burst phase on {agree:.0%} of epochs")

    # hysteresis machine (paper §3.2 deployment rules)
    pol, cfg = init_policy_state(), PolicyConfig(warmup=20, hold=5, revert=50)
    applied = []
    for cyc, s in enumerate(np.asarray(signal)):
        pol = apply_policy(cfg, pol, jnp.int32(s), jnp.int32(cyc))
        applied.append(int(pol.config))
    print(f"hysteresis: raw signal on {np.mean(np.asarray(signal)):.0%}, "
          f"applied config on {np.mean(applied):.0%} of epochs "
          f"(warmup+hold smooth the chatter)\n")


def act2_noc():
    print("=== 2. NoC simulation: KF vs static-fair (paper Figs. 9-11) ===")
    from repro.core.noc.sim import run_workload, summarize

    for mode in ("fair", "kf"):
        s = summarize(run_workload(mode, "STO", n_epochs=30))
        print(f"{mode:5s} gpu_ipc={s['gpu_ipc']:.3f} "
              f"cpu_ipc={s['cpu_ipc']:.3f} latency={s['avg_latency']:.1f}")
    print()


def act3_tpu():
    print("=== 3. TPU adaptation: KF scheduler over step variants ===")
    from repro.launch.train import build
    from repro.train import loop as loop_lib

    state, step_fns, make_batch, sched, mesh, cfg = build(
        "llama3.2-3b", "smoke", seq_len=64, global_batch=4,
        total_steps=60, use_kf=True)
    res = loop_lib.run(
        loop_lib.LoopConfig(total_steps=60, log_every=20),
        state, step_fns, make_batch, sched)
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"variants dispatched: {sorted(set(res.variants))}")


if __name__ == "__main__":
    act1_kalman()
    act2_noc()
    act3_tpu()
