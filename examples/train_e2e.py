"""End-to-end training driver: a ~100M-param llama-family model trained for
a few hundred steps on the synthetic corpus, with checkpointing, the KF
scheduler, and restart-safety.

    PYTHONPATH=src python examples/train_e2e.py                # ~25M, 300 steps
    PYTHONPATH=src python examples/train_e2e.py --size 100m --steps 300

On CPU the 25M config runs ~1 s/step; the 100m config is the same driver
at ~100M params (use on real accelerators or be patient).  Loss must drop
substantially from the ~log(V) start — asserted at exit.
"""
import argparse
import math

import jax
import numpy as np

from repro.data import synthetic
from repro.dist import sharding
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

SIZES = {
    # ~25M params: d=384 L=6 H=6 ff=1536 V=8192
    "25m": ModelConfig(name="e2e-25m", n_layers=6, d_model=384, n_heads=6,
                       n_kv_heads=2, d_ff=1536, vocab_size=8192,
                       tie_embeddings=True, remat="none"),
    # ~100M params: d=768 L=10 H=12 ff=3072 V=16384
    "100m": ModelConfig(name="e2e-100m", n_layers=10, d_model=768,
                        n_heads=12, n_kv_heads=4, d_ff=3072,
                        vocab_size=16384, tie_embeddings=True, remat="none"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="25m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = SIZES[args.size]
    from repro.launch.roofline import count_params
    n_params = count_params(cfg)
    print(f"[e2e] {cfg.name}: ~{n_params / 1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq_len}")

    mesh = make_host_mesh()
    opt_cfg = opt_lib.OptimizerConfig(
        lr=args.lr, warmup_steps=20, total_steps=args.steps)
    with sharding.activate(mesh):
        state, specs_tree = step_lib.init_train_state(
            jax.random.PRNGKey(0), cfg, opt_cfg)
        ds = synthetic.make_dataset(cfg, args.seq_len, args.batch)
        step0 = step_lib.make_train_step(cfg, opt_cfg, mesh=mesh, variant=0)
        jitted = step_lib.jit_step(step0, mesh, state, specs_tree,
                                   ds.batch(0))
        result = loop_lib.run(
            loop_lib.LoopConfig(total_steps=args.steps,
                                ckpt_dir=args.ckpt_dir, log_every=25),
            state, {0: jitted}, ds.batch)

    start, end = result.losses[0], float(np.mean(result.losses[-20:]))
    print(f"[e2e] loss: {start:.3f} -> {end:.3f} "
          f"(uniform = ln V = {math.log(cfg.vocab_size):.2f})")
    assert end < start - 0.5, "loss did not drop — training is broken"
    print("[e2e] OK — loss dropped substantially")


if __name__ == "__main__":
    main()
