"""Serving example: continuous batching with the paper's KF arbitration.

Runs the same bursty request workload through the three scheduler modes
(the serving analogue of the paper's four NoC configurations) and prints
the latency/throughput comparison.

    PYTHONPATH=src python examples/serve_kf.py
"""
import jax

import repro.configs as configs
from repro.models import lm
from repro.serve import batching
from repro.serve.engine import Engine, EngineConfig


def main():
    cfg = configs.smoke("llama3.2-3b")
    params, _ = lm.make_lm(jax.random.PRNGKey(0), cfg)
    wl = batching.WorkloadConfig(n_requests=32, mean_prompt=40, mean_gen=10,
                                 burst_rate=6.0, calm_rate=0.2, seed=1)

    print(f"{'mode':8s}{'finished':>9s}{'mean_ttft':>11s}{'p90_ttft':>10s}"
          f"{'latency':>9s}{'tok/s':>8s}{'kf_on':>7s}")
    for mode in ("rr", "static", "kf"):
        ecfg = EngineConfig(mode=mode, max_slots=4, max_len=96,
                            budget_tokens=96, warmup_iters=3)
        eng = Engine(params, cfg, ecfg, seed=1)
        s = eng.run(batching.generate(wl), max_iters=2000).summary()
        print(f"{mode:8s}{s['n_finished']:9d}{s['mean_ttft']:11.4f}"
              f"{s['p90_ttft']:10.4f}{s['mean_latency']:9.4f}"
              f"{s['throughput_tok_s']:8.1f}{s['kf_on_frac']:7.2f}")


if __name__ == "__main__":
    main()
