"""Flight-recorder observability suite (DESIGN.md §14).

Pins the probe contract from three sides:

  1. probes OFF is free — `simulate` returns the same SimResult bit-for-bit
     as before the probe layer existed, still compiling exactly one trace;
  2. probes ON is backend-invariant — the SimTrace is bitwise-identical
     across the ref / pallas / pallas_arb cycle engines (the probe counters
     ride the same lane contract as the architectural counters);
  3. the run ledger and the noc_trace replay tooling round-trip.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.core.noc import sim
from repro.core.noc.sim import NoCConfig
from repro.obs import ledger, probes

TINY = dict(n_epochs=4, epoch_len=60)


def _bitwise_equal(a, b, label):
    for (path, x), (_, y) in zip(
        jax.tree_util.tree_leaves_with_path(a),
        jax.tree_util.tree_leaves_with_path(b),
    ):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{label}: leaf {jax.tree_util.keystr(path)}",
        )


# ---------------------------------------------------------------------------
# 1. probes off: zero-cost contract
# ---------------------------------------------------------------------------

def test_probes_off_result_and_trace_count_unchanged():
    """Probes on must not perturb the simulation: the SimResult is bitwise
    the probes-off result, and each static variant still compiles once."""
    cfg = NoCConfig(mode="kf", seed=2, **TINY)
    sim.reset_trace_count()
    res_off = sim.simulate(cfg, "PATH")
    assert sim.trace_count() == 1
    sim.reset_trace_count()
    res_on, trace = sim.simulate_with_trace(cfg, "PATH")
    assert sim.trace_count() == 1  # the probed variant gets its own trace
    _bitwise_equal(res_off, res_on, "probes on vs off")
    assert isinstance(trace, sim.SimTrace)


def test_probe_config_defaults_off():
    assert NoCConfig(mode="kf", **TINY).probe.enabled is False
    assert NoCConfig(mode="kf", **TINY).static_spec().probe.enabled is False


# ---------------------------------------------------------------------------
# 2. probes on: backend congruence + sanity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def probe_runs():
    cfg = NoCConfig(mode="kf", seed=0, **TINY)
    return {
        be: sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS", backend=be)
        for be in ("ref", "pallas", "pallas_arb")
    }


def test_probe_trace_ref_pallas_congruent(probe_runs):
    """SimTrace is bitwise-equal across all three cycle engines."""
    res_ref, tr_ref = probe_runs["ref"]
    for be in ("pallas", "pallas_arb"):
        res_be, tr_be = probe_runs[be]
        _bitwise_equal(res_ref, res_be, f"SimResult ref vs {be}")
        _bitwise_equal(tr_ref, tr_be, f"SimTrace ref vs {be}")


def test_probe_trace_sanity(probe_runs):
    res, tr = probe_runs["ref"]
    E, L = TINY["n_epochs"], TINY["epoch_len"]
    occ = np.asarray(tr.occ_sum)
    assert occ.shape[0] == E and occ.min() >= 0
    # per-cycle occupancy of one buffer is bounded by its depth
    assert occ.max() <= L * 64
    grant, deny = np.asarray(tr.arb_grant), np.asarray(tr.arb_deny)
    assert grant.min() >= 0 and deny.min() >= 0
    # a router has N_PORTS outputs, each granting <= 1 flit per cycle
    assert grant.max() <= L * 5
    mcq_sum, mcq_max = np.asarray(tr.mcq_sum), np.asarray(tr.mcq_max)
    assert mcq_sum.min() >= 0 and mcq_max.min() >= 0
    assert (mcq_max <= mcq_sum).all()  # max over cycles <= sum over cycles
    assert np.isfinite(np.asarray(tr.kf_gain)).all()
    assert np.isfinite(np.asarray(tr.kf_cov_trace)).all()
    # the emitted signal IS the binarized one-step prediction
    np.testing.assert_array_equal(
        (np.asarray(tr.kf_x_pred) > 0.0).astype(np.int32),
        np.asarray(res.kf_signal),
    )
    summary = probes.summarize_trace(tr)
    assert summary["epochs"] == E
    assert summary["occ_sum_total"] == int(occ.sum())


# ---------------------------------------------------------------------------
# 3. run ledger: schema + append round-trip
# ---------------------------------------------------------------------------

def test_ledger_probe_row_round_trip(tmp_path):
    bench = tmp_path / "BENCH_noc.json"
    rec = {"bench": "noc_obs", "timestamp": "2026-01-01T00:00:00",
           "backend": "cpu", "probe_overhead_steady": 1.1}
    ledger.append(dict(rec), path=str(bench))
    rows = json.loads(bench.read_text())
    assert len(rows) == 1
    row = rows[0]
    # append stamps provenance and the result validates as a stamped row
    assert row["ledger_version"] == ledger.LEDGER_VERSION
    assert set(ledger.STAMP_FIELDS) <= set(row)
    assert ledger.validate_row(row) == []
    # the JSONL mirror carries the same record
    mirror = tmp_path / "LEDGER_noc.jsonl"
    assert json.loads(mirror.read_text().splitlines()[-1]) == row
    # second append extends the array (and keeps it valid JSON)
    ledger.append(dict(rec), path=str(bench))
    assert len(json.loads(bench.read_text())) == 2


def test_ledger_probe_rejects_bad_rows(tmp_path):
    bench = tmp_path / "BENCH_noc.json"
    with pytest.raises(ValueError):
        ledger.append({"timestamp": "t", "backend": "cpu"}, path=str(bench))
    with pytest.raises(ValueError):
        ledger.append({"bench": 7, "timestamp": "t", "backend": "cpu"},
                      path=str(bench))
    assert not bench.exists()  # invalid rows never reach the file
    # legacy (unstamped) rows are tolerated by validate, future versions not
    legacy = {"bench": "b", "timestamp": "t", "backend": "cpu"}
    assert ledger.validate_row(legacy) == []
    future = dict(legacy, ledger_version=ledger.LEDGER_VERSION + 1,
                  git_sha="x", device_kind="cpu")
    assert ledger.validate_row(future) != []


def test_ledger_mirror_append_is_atomic(tmp_path):
    """The JSONL mirror is rewritten via temp file + os.replace, and a torn
    (non-newline-terminated) tail line left by a crashed writer is dropped
    instead of being glued onto the next row."""
    bench = tmp_path / "BENCH_noc.json"
    mirror = tmp_path / "LEDGER_noc.jsonl"
    rec = {"bench": "noc_obs", "timestamp": "t1", "backend": "cpu"}
    ledger.append(dict(rec), path=str(bench))
    with open(mirror, "a") as f:
        f.write('{"bench": "torn')  # crashed writer: partial, no newline
    ledger.append(dict(rec, timestamp="t2"), path=str(bench))
    rows = [json.loads(line) for line in mirror.read_text().splitlines()]
    assert [r["timestamp"] for r in rows] == ["t1", "t2"]
    assert not (tmp_path / "LEDGER_noc.jsonl.tmp").exists()


def test_ledger_mirror_retries_once_on_oserror(tmp_path, monkeypatch):
    """One transient OSError on the atomic rename is absorbed; a second
    consecutive failure propagates."""
    bench = tmp_path / "BENCH_noc.json"
    rec = {"bench": "noc_obs", "timestamp": "t1", "backend": "cpu"}
    real_replace = os.replace
    fails = {"left": 1}

    def flaky(src, dst):
        if fails["left"] > 0:
            fails["left"] -= 1
            raise OSError("transient")
        return real_replace(src, dst)

    monkeypatch.setattr(ledger.os, "replace", flaky)
    ledger.append(dict(rec), path=str(bench))
    mirror = tmp_path / "LEDGER_noc.jsonl"
    assert len(mirror.read_text().splitlines()) == 1

    fails["left"] = 2  # both attempts fail -> the error surfaces
    with pytest.raises(OSError):
        ledger.append(dict(rec, timestamp="t2"), path=str(bench))


def test_ledger_probe_config_hash_stable():
    cfg = NoCConfig(mode="kf", **TINY)
    h1 = ledger.config_hash(cfg)
    assert h1 == ledger.config_hash(NoCConfig(mode="kf", **TINY))
    assert h1 != ledger.config_hash(
        dataclasses.replace(cfg, seed=cfg.seed + 1))


# ---------------------------------------------------------------------------
# 4. noc_trace replay tooling
# ---------------------------------------------------------------------------

def test_noc_trace_probe_capture_round_trip(tmp_path, probe_runs):
    from benchmarks import noc_trace

    res, tr = probe_runs["ref"]
    cap = {f: np.asarray(v) for f, v in zip(sim.SimTrace._fields, tr)}
    cap["kf_signal"] = np.asarray(res.kf_signal)
    cap["applied_config"] = np.asarray(res.applied_config)
    cap["gpu_ipc"] = np.asarray(res.gpu_ipc)
    cap["avg_latency"] = np.asarray(res.avg_latency)
    cap.update(workload="SHIFT_PATH_BFS", mode="kf",
               n_epochs=TINY["n_epochs"], epoch_len=TINY["epoch_len"],
               seed=0, backend="ref")
    path = str(tmp_path / "cap.npz")
    noc_trace.save(cap, path)
    cap2 = noc_trace.load(path)
    for k, v in cap.items():
        np.testing.assert_array_equal(np.asarray(cap2[k]), np.asarray(v),
                                      err_msg=f"round-trip: {k}")
    ascii_lines = noc_trace.render_ascii(cap2)
    csv_lines = noc_trace.render_csv(cap2)
    assert len(ascii_lines) == TINY["n_epochs"] + 2
    assert len(csv_lines) == TINY["n_epochs"] + 1
    assert all("," in ln for ln in csv_lines)
