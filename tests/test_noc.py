"""System-behaviour tests for the NoC simulator (paper §4 evaluation rig)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noc.sim import NoCConfig, run_workload, simulate, summarize
from repro.core.noc.topology import make_topology
from repro.core.noc.traffic import PROFILES

FAST = dict(n_epochs=30, epoch_len=200)


def total_buffer_capacity(cfg: NoCConfig, n_routers=36) -> int:
    per_subnet = n_routers * 5 * cfg.vcs_per_subnet * cfg.buf_depth
    in_mc = n_routers * (cfg.mc_queue_cap + 1)  # queue + staging
    return cfg.n_subnets * per_subnet + in_mc


class TestTopology:
    def test_xy_routing_reaches_destination(self):
        topo = make_topology()
        for src in range(topo.n_routers):
            for dst in range(topo.n_routers):
                cur, hops = src, 0
                while cur != dst:
                    port = topo.route[cur, dst]
                    assert port != 4, "local port before arrival"
                    cur = topo.neighbor[cur, port]
                    hops += 1
                    assert hops <= 12, "path too long on a 6x6 mesh"
                assert topo.route[cur, dst] == 4

    def test_node_census(self):
        topo = make_topology()
        types = np.asarray(topo.node_type)
        assert (types == 2).sum() == 8      # 8 MCs (Table 1)
        assert (types == 1).sum() == 14     # 14 GPU chiplets
        assert (types == 0).sum() == 14     # 14 CPU chiplets


@pytest.mark.parametrize("mode", ["baseline", "fair", "kf", "4subnet"])
def test_modes_run_and_produce_finite_metrics(mode):
    res = run_workload(mode, "PATH", **FAST)
    for leaf in [res.gpu_ipc, res.cpu_ipc, res.avg_latency]:
        assert bool(jnp.all(jnp.isfinite(leaf)))
        assert bool(jnp.all(leaf >= 0))
    assert res.gpu_ipc.shape == (FAST["n_epochs"],)


def test_determinism():
    a = run_workload("kf", "BFS", seed=7, **FAST)
    b = run_workload("kf", "BFS", seed=7, **FAST)
    np.testing.assert_array_equal(a.gpu_ipc, b.gpu_ipc)
    np.testing.assert_array_equal(a.applied_config, b.applied_config)


def test_packet_conservation():
    """Injected packets are either completed or still buffered somewhere:
    0 <= injected - completed <= total buffer capacity (+ MSHR in flight)."""
    cfg = NoCConfig(mode="baseline", n_epochs=40, epoch_len=200)
    res = simulate(cfg, PROFILES["STO"])
    c = res.counters
    injected = int(jnp.sum(c.gpu_push) + jnp.sum(c.cpu_push))
    completed = int(jnp.sum(c.gpu_done) + jnp.sum(c.cpu_done))
    assert completed <= injected
    assert injected - completed <= total_buffer_capacity(cfg)


def test_kf_reconfigures_only_in_kf_mode():
    for mode in ["baseline", "fair", "4subnet"]:
        res = run_workload(mode, "BFS", **FAST)
        assert int(jnp.sum(res.applied_config)) == 0
    res = run_workload("kf", "BFS", n_epochs=100, epoch_len=500, seed=1)
    assert int(jnp.sum(res.applied_config)) > 0


def test_kf_respects_warmup():
    res = run_workload("kf", "BFS", n_epochs=60, epoch_len=500, seed=1)
    # the KF may first act at the epoch boundary that reaches cycle 10,000,
    # i.e. the end of epoch index 19 — everything before must stay config 0
    assert int(jnp.sum(res.applied_config[:19])) == 0


def test_vc_sweep_monotonic_gpu_side():
    """Fig. 2: GPU throughput should not *decrease* when GPUs get more VCs."""
    ipcs = []
    for g in [1, 2, 3]:
        res = run_workload(
            "static", "MUM", static_gpu_vcs=g, n_epochs=60, epoch_len=500, seed=3
        )
        ipcs.append(summarize(res, warmup_epochs=10)["gpu_ipc"])
    assert ipcs[-1] >= ipcs[0] - 0.01  # allow small noise


def test_burst_correlates_with_stalls():
    """Fig. 4: epochs with high GPU injection show more GPU stalls."""
    res = run_workload("baseline", "BFS", n_epochs=100, epoch_len=500, seed=1)
    gen = np.array(res.counters.gpu_gen, dtype=float)
    stalls = np.array(res.counters.gpu_stall_icnt, dtype=float)
    if gen.std() > 0 and stalls.std() > 0:
        corr = np.corrcoef(gen, stalls)[0, 1]
        assert corr > 0.5


def test_four_subnet_low_load_latency_worst():
    """Paper Fig. 11 mechanism: physical partitioning cannot share idle
    bandwidth, so at non-saturated load its latency is the highest."""
    lats = {}
    for mode in ["baseline", "fair", "4subnet"]:
        res = run_workload(mode, "PATH", n_epochs=40, epoch_len=300, seed=5)
        gen = np.array(res.counters.gpu_gen)
        lat = np.array(res.avg_latency)
        low = gen < np.percentile(gen, 60)
        lats[mode] = lat[low].mean()
    assert lats["4subnet"] > lats["baseline"]
    assert lats["4subnet"] > lats["fair"]
