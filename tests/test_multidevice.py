"""Multi-device behaviour, tested via subprocesses with fake host devices
(XLA device count is locked at first jax init, so each case gets its own
interpreter; the main suite stays on 1 device)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, n_dev: int = 8, timeout: int = 600):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_multi_stage_numeric_and_grad():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import pipeline
        mesh = jax.make_mesh((4,), ("stage",))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (4, 8, 8)) * 0.3
        def stage_fn(p, x):
            return jnp.tanh(x @ p)
        mbs = jax.random.normal(key, (6, 2, 8))
        outp = pipeline.pipeline_apply(stage_fn, w, mbs, mesh)
        want = mbs
        for i in range(4):
            want = jnp.tanh(want @ w[i])
        np.testing.assert_allclose(np.asarray(outp), np.asarray(want),
                                   atol=1e-5)
        g = jax.grad(lambda w: jnp.sum(
            pipeline.pipeline_apply(stage_fn, w, mbs, mesh) ** 2))(w)
        assert np.isfinite(np.asarray(g)).all()
        print("PIPE_OK")
    """)
    assert "PIPE_OK" in out


def test_comm_priority_multipod_compiles_with_int8_wire():
    out = _run("""
        import jax, jax.numpy as jnp, re
        import repro.configs as configs
        from repro.dist import sharding
        from repro.launch import specs
        from repro.train import step as step_lib
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = configs.smoke("llama3.2-3b")
        opt_cfg = specs.default_opt_cfg(cfg)
        with sharding.activate(mesh):
            state_abs, st_specs = specs.abstract_train_state(
                cfg, opt_cfg, with_residuals=True, data_size=2, pod_size=2)
            batch = {
                "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                "mask": jax.ShapeDtypeStruct((8, 16), jnp.float32),
            }
            step = step_lib.make_train_step(
                cfg, opt_cfg, mesh=mesh, variant=step_lib.COMM_PRIORITY)
            st_sh = jax.tree.map(
                lambda s, a: NamedSharding(mesh, sharding.logical_to_mesh(
                    s, getattr(a, "shape", None), mesh)),
                st_specs, state_abs, is_leaf=lambda x: isinstance(x, P))
            b_sh = jax.tree.map(
                lambda v: NamedSharding(mesh, sharding.logical_to_mesh(
                    P("batch", None), v.shape, mesh)), batch)
            comp = jax.jit(step, in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, None),
                           donate_argnums=(0,)).lower(
                state_abs, batch).compile()
        txt = comp.as_text()
        n_s8 = len(re.findall(r"s8\\[[^\\]]*\\][^\\n]*all-gather", txt))
        assert n_s8 > 0, "no int8 all-gather on the wire"
        print("INT8_OK", n_s8)
    """)
    assert "INT8_OK" in out


def test_dryrun_one_cell_multipod():
    """End-to-end dry-run driver on the real 512-device multipod mesh."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stablelm-1.6b", "--shape", "decode_32k",
         "--mesh", "multipod", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "decode_32k: ok" in out.stdout


def test_seq_parallel_option_changes_sharding():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.dist import sharding
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with sharding.activate(mesh):
            sharding.set_option("seq_parallel", True)
            x = jnp.ones((2, 8, 4))
            y = jax.jit(lambda x: sharding.constrain(
                x, "batch", sharding.seq_axis(), "embed"))(x)
            spec = y.sharding.spec
            sharding.set_option("seq_parallel", False)
        assert "model" in str(spec), spec
        print("SP_OK", spec)
    """)
    assert "SP_OK" in out


def test_comm_priority_variant_trains_equivalently():
    """Variant 1 (hierarchical int8-EF sync) must track variant 0's loss
    trajectory — the compression is contractive, not a different optimizer."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as configs
        from repro.dist import sharding
        from repro.data import synthetic
        from repro.train import optimizer as opt_lib, step as step_lib
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = configs.smoke("llama3.2-3b")
        opt_cfg = opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=1,
                                          total_steps=20)
        ds = synthetic.make_dataset(cfg, seq_len=32, global_batch=8)

        def run(variant):
            with sharding.activate(mesh):
                state, st_specs = step_lib.init_train_state(
                    jax.random.PRNGKey(0), cfg, opt_cfg,
                    with_residuals=(variant == 1), data_size=2, pod_size=2)
                step = step_lib.make_train_step(
                    cfg, opt_cfg, mesh=mesh, variant=variant)
                jitted = step_lib.jit_step(step, mesh, state, st_specs,
                                           ds.batch(0))
                losses = []
                for i in range(8):
                    state, m = jitted(state, ds.batch(i))
                    losses.append(float(m["loss"]))
            return losses

        l0 = run(0)
        l1 = run(1)
        np.testing.assert_allclose(l0, l1, rtol=0.05)
        assert l0[-1] < l0[0]
        print("VARIANT_EQ_OK", l0[-1], l1[-1])
    """, n_dev=8, timeout=900)
    assert "VARIANT_EQ_OK" in out
