"""Fault-injection + self-healing KF suite (DESIGN.md §16).

Pins the robustness layer from four sides:

  1. fault model — `FaultSchedule` validation, flap periodicity, and the
     symmetric (both-directions) link masking;
  2. zero-cost healthy path — faults=None and an armed-but-idle guard are
     bitwise the pre-fault program, and the healthy x faulty x guarded
     grid still compiles exactly ONE simulate trace;
  3. backend congruence — every registered fault scenario produces a
     bitwise-identical SimResult AND SimTrace on ref / pallas /
     pallas_arb (fault masks ride the same lane contract as the
     architectural state);
  4. self-healing semantics — the innovation gate rejects corrupted
     telemetry, the watchdog resets a poisoned filter, the allocator
     falls back to the fair split while unhealthy and recovers after.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noc import sim
from repro.core.noc.faults import (
    FAULTS,
    TELEM_NAN,
    TELEM_SPIKE,
    FaultEvent,
    FaultSchedule,
    healthy_stream,
    lookup_faults,
    resolve_faults,
)
from repro.core.noc.sim import NoCConfig, SweepSpec
from repro.core.noc.topology import (
    PORT_L,
    PORT_N,
    PORT_S,
    make_topology,
)

TINY = dict(n_epochs=8, epoch_len=80)
BACKENDS = ("ref", "pallas", "pallas_arb")


def _bitwise_equal(a, b, label):
    for (path, x), (_, y) in zip(
        jax.tree_util.tree_leaves_with_path(a),
        jax.tree_util.tree_leaves_with_path(b),
    ):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{label}: leaf {jax.tree_util.keystr(path)}",
        )


# ---------------------------------------------------------------------------
# 1. fault model: schedule validation + materialization
# ---------------------------------------------------------------------------

class TestFaultSchedule:
    def test_rejects_bad_events(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSchedule((FaultEvent(0.0, 0.5, "gamma_ray"),))
        with pytest.raises(ValueError, match="outside"):
            FaultSchedule((FaultEvent(0.5, 0.4, "link"),))
        with pytest.raises(ValueError, match="outside"):
            FaultSchedule((FaultEvent(-0.1, 0.5, "mc"),))
        with pytest.raises(ValueError, match="period"):
            FaultSchedule((FaultEvent(0.0, 0.5, "link", period=-1),))
        with pytest.raises(ValueError, match="telem fault mode"):
            FaultSchedule((FaultEvent(0.0, 0.5, "telem", mode=9),))
        with pytest.raises(ValueError, match="only the four mesh ports"):
            FaultSchedule((
                FaultEvent(0.0, 0.5, "link", ports=(PORT_L,)),
            ))

    def test_rejects_out_of_range_routers_at_materialize(self):
        sched = FaultSchedule((FaultEvent(0.0, 0.5, "router",
                                          routers=(99,)),))
        with pytest.raises(ValueError, match="outside"):
            sched.materialize(8)

    def test_flap_period_alternates(self):
        """period=2 in [0, 1): 2 epochs down, 2 up, repeating."""
        sched = FaultSchedule((
            FaultEvent(0.0, 1.0, "router", routers=(5,), period=2),
        ))
        stream = sched.materialize(8)
        down = ~np.asarray(stream.router_ok)[:, 5]
        assert down.tolist() == [True, True, False, False,
                                 True, True, False, False]

    def test_link_fault_masks_both_directions(self):
        """With a neighbor table, router 8's dead N link also masks the
        reverse (S) direction at its northern neighbor."""
        topo = make_topology()
        sched = FaultSchedule((
            FaultEvent(0.0, 1.0, "link", routers=(8,), ports=(PORT_N,)),
        ))
        stream = sched.materialize(
            4, neighbor=np.asarray(topo.neighbor),
            opposite=np.asarray(topo.opposite))
        link_ok = np.asarray(stream.link_ok)
        nb = int(np.asarray(topo.neighbor)[8, PORT_N])
        assert nb >= 0
        assert not link_ok[:, 8, PORT_N].any()
        assert not link_ok[:, nb, PORT_S].any()
        # nothing else is masked
        assert link_ok.sum() == link_ok.size - 2 * 4

    def test_lookup_suggests_near_miss(self):
        with pytest.raises(ValueError, match="FLAP_BFS"):
            lookup_faults("FLAP_BFSS")

    def test_resolve_rejects_wrong_shape_stream(self):
        stream = healthy_stream(6)
        with pytest.raises(ValueError, match="link_ok"):
            resolve_faults(stream, 8)

    def test_healthy_stream_is_identity(self):
        stream = healthy_stream(5)
        assert np.asarray(stream.link_ok).all()
        assert np.asarray(stream.router_ok).all()
        assert np.asarray(stream.mc_ok).all()
        assert not np.asarray(stream.telem_mode).any()


# ---------------------------------------------------------------------------
# 2. zero-cost healthy path
# ---------------------------------------------------------------------------

def test_fault_none_bitwise_equals_explicit_healthy_stream():
    cfg = NoCConfig(mode="kf", seed=3, **TINY)
    res_none = sim.simulate(cfg, "SHIFT_PATH_BFS")
    explicit = dataclasses.replace(
        cfg, faults=healthy_stream(TINY["n_epochs"]))
    res_stream = sim.simulate(explicit, "SHIFT_PATH_BFS")
    _bitwise_equal(res_none, res_stream, "faults=None vs healthy_stream")


def test_fault_guard_armed_but_idle_is_bitwise_free():
    """Clean telemetry: the armed guard's innovation gate never fires, so
    guard=True is bit-for-bit guard=False."""
    cfg = NoCConfig(mode="kf", seed=3, **TINY)
    res_off = sim.simulate(cfg, "SHIFT_PATH_BFS")
    res_on = sim.simulate(dataclasses.replace(cfg, guard=True),
                          "SHIFT_PATH_BFS")
    _bitwise_equal(res_on, res_off, "guard on vs off (healthy)")


def test_fault_grid_shares_one_simulate_trace():
    """Healthy + every fault scenario x guard settings: one compiled
    program (fault masks are scan xs, guard knobs are traced policy)."""
    specs = [SweepSpec("kf", "SHIFT_PATH_BFS", seed=0, faults=f, guard=g)
             for f in (None, *FAULTS) for g in (False, True)]
    sim.reset_trace_count()
    rows = sim.sweep(specs, **TINY)
    assert sim.trace_count() == 1
    assert len(rows) == len(specs)


# ---------------------------------------------------------------------------
# 3. backend congruence under faults
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=sorted(FAULTS))
def fault_runs(request):
    """One probed guarded run per backend for a given fault scenario."""
    cfg = NoCConfig(mode="kf", seed=1, guard=True,
                    faults=request.param, **TINY)
    return request.param, {
        be: sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS", backend=be)
        for be in BACKENDS
    }


def test_fault_scenarios_backend_congruent(fault_runs):
    """SimResult AND SimTrace bitwise across ref/pallas/pallas_arb for
    every registered fault scenario."""
    name, runs = fault_runs
    res_ref, tr_ref = runs["ref"]
    for be in ("pallas", "pallas_arb"):
        res_be, tr_be = runs[be]
        _bitwise_equal(res_ref, res_be, f"{name}: SimResult ref vs {be}")
        _bitwise_equal(tr_ref, tr_be, f"{name}: SimTrace ref vs {be}")


def test_fault_scenarios_perturb_the_run(fault_runs):
    """Every scenario actually does something: fault epochs are recorded,
    and either the result differs from the healthy run (physical faults)
    or the guard visibly handled telemetry corruption (a successfully
    absorbed telem-only glitch may leave the RESULT bitwise-healthy —
    that is the guard working, so the trace must show the rejections)."""
    name, runs = fault_runs
    res, tr = runs["ref"]
    assert int(np.asarray(tr.faults_active).sum()) > 0
    healthy = sim.simulate(
        NoCConfig(mode="kf", seed=1, guard=True, **TINY), "SHIFT_PATH_BFS")
    diff = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(res), jax.tree.leaves(healthy))
    )
    handled = int(np.asarray(tr.kf_rejected).sum()) > 0
    assert diff or handled, (
        f"{name}: faulty run bitwise-equal to healthy with no guard "
        "activity")


# ---------------------------------------------------------------------------
# 4. fault semantics in the fabric
# ---------------------------------------------------------------------------

def test_fault_brownout_routers_grant_nothing():
    """During a brownout window the affected routers issue zero grants
    (no traversal, no ejection) and recover afterwards."""
    routers = (14, 15)
    sched = FaultSchedule((
        FaultEvent(0.25, 0.75, "router", routers=routers),
    ))
    cfg = NoCConfig(mode="baseline", seed=0, faults=sched, **TINY)
    _, tr = sim.simulate_with_trace(cfg, "PATH")
    grants = np.asarray(tr.arb_grant)  # (E, S, R)
    lo, hi = 2, 6  # round(0.25 * 8), round(0.75 * 8)
    assert grants[lo:hi, :, routers].sum() == 0
    assert grants[hi:, :, routers].sum() > 0  # traffic resumes


def test_fault_masked_flits_backpressure_not_vanish():
    """Flit conservation under link faults: completions never exceed
    injections, and the blocked traffic WAITS (latency rises vs healthy)
    rather than vanishing."""
    sched = FaultSchedule((
        FaultEvent(0.25, 0.5, "link", routers=(8, 9)),
    ))
    cfg = NoCConfig(mode="baseline", seed=0, faults=sched, **TINY)
    res = sim.simulate(cfg, "PATH")
    healthy = sim.simulate(dataclasses.replace(cfg, faults=None), "PATH")
    c = res.counters
    injected = int(np.asarray(c.gpu_push).sum() +
                   np.asarray(c.cpu_push).sum())
    completed = int(np.asarray(c.gpu_done).sum() +
                    np.asarray(c.cpu_done).sum())
    assert 0 < completed <= injected
    assert (float(np.asarray(res.avg_latency)[-1])
            > float(np.asarray(healthy.avg_latency)[-1]))


def test_fault_mc_stall_freezes_service():
    """An all-MC stall for the whole run: memory service is frozen, so
    transaction completions collapse vs the healthy run (queues
    back-pressure instead of dropping)."""
    stall = FaultSchedule((FaultEvent(0.0, 1.0, "mc"),))
    cfg = NoCConfig(mode="baseline", seed=0, faults=stall, **TINY)
    res = sim.simulate(cfg, "PATH")
    healthy = sim.simulate(dataclasses.replace(cfg, faults=None), "PATH")
    assert (int(np.asarray(res.counters.gpu_done).sum())
            < int(np.asarray(healthy.counters.gpu_done).sum()) // 2)


# ---------------------------------------------------------------------------
# 5. self-healing KF semantics
# ---------------------------------------------------------------------------

def _nan_window(start=0.25, stop=0.75):
    return FaultSchedule((FaultEvent(start, stop, "telem",
                                     mode=TELEM_NAN),))


def test_fault_telem_nan_unguarded_poisons_filter():
    cfg = NoCConfig(mode="kf", seed=0, faults=_nan_window(), **TINY)
    _, tr = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
    assert not np.isfinite(np.asarray(tr.kf_x_pred)).all()
    # NaN NIS compares False against the threshold: the unguarded filter
    # never rejects and never resets
    assert int(np.asarray(tr.kf_rejected).sum()) == 0
    assert int(np.asarray(tr.kf_reset).sum()) == 0


def test_fault_telem_nan_guarded_stays_finite_and_recovers():
    cfg = NoCConfig(mode="kf", seed=0, guard=True,
                    faults=_nan_window(), **TINY)
    _, tr = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
    assert np.isfinite(np.asarray(tr.kf_x_pred)).all()
    assert np.isfinite(np.asarray(tr.kf_cov_trace)).all()
    rejected = np.asarray(tr.kf_rejected)
    healthy = np.asarray(tr.kf_healthy)
    lo, hi = 2, 6
    assert rejected[lo:hi].sum() == hi - lo  # every NaN epoch gated
    # watchdog declares unhealthy after watchdog_limit consecutive
    # rejections -> fair-split fallback epochs are recorded ...
    assert (healthy == 0).sum() > 0
    assert int(np.asarray(tr.kf_reset).sum()) >= 1
    # ... and health returns once telemetry is clean again
    assert healthy[-1] == 1


def test_fault_telem_spike_rejected_by_innovation_gate():
    """A +8 spike on normalized-to-[-1, 1] observations is far past the
    NIS threshold: the guarded filter coasts through it and its posterior
    keeps tracking the clean prediction."""
    spike = FaultSchedule((
        FaultEvent(0.5, 0.625, "telem", mode=TELEM_SPIKE, mag=8.0),
    ))
    cfg = NoCConfig(mode="kf", seed=0, guard=True, faults=spike, **TINY)
    _, tr = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
    assert int(np.asarray(tr.kf_rejected)[4:5].sum()) == 1
    # the spiked epoch's NIS is enormous; clean epochs stay modest
    nis = np.asarray(tr.kf_nis)
    assert nis[4] > 50.0


def test_fault_fallback_is_fair_split():
    """While unhealthy, the allocator pins the fair static split: the
    applied config is 0 in every fallback epoch."""
    cfg = NoCConfig(mode="kf", seed=0, guard=True,
                    faults=_nan_window(), **TINY)
    res, tr = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
    healthy = np.asarray(tr.kf_healthy)
    applied = np.asarray(res.applied_config)
    # applied_config[e] records epoch e's post-degrade decision (the VC
    # masks flip one epoch later): every unhealthy epoch decides config 0
    assert (healthy == 0).any()
    assert (applied[healthy == 0] == 0).all()


def test_fault_summarize_trace_counts():
    cfg = NoCConfig(mode="kf", seed=0, guard=True,
                    faults="TELEM_GLITCH", **TINY)
    _, tr = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
    from repro.obs.probes import summarize_trace

    s = summarize_trace(tr)
    assert s["fault_epochs"] == int((np.asarray(tr.faults_active) > 0).sum())
    assert s["kf_rejected_total"] == int(np.asarray(tr.kf_rejected).sum())
    assert s["kf_reset_total"] == int(np.asarray(tr.kf_reset).sum())
    assert s["fallback_epochs"] == int((np.asarray(tr.kf_healthy) == 0).sum())
