"""Checkpoint fault-tolerance: atomicity, corruption recovery, restart
bit-exactness, elastic remesh."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import elastic, io as ckpt_io


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                   "c": [jnp.ones((2,)), jnp.zeros((3, 3))]},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt_io.save(str(tmp_path), 7, tree)
    step, restored = ckpt_io.restore_latest(str(tmp_path), tree)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)


def test_corrupt_checkpoint_skipped(tmp_path):
    tree = _tree()
    ckpt_io.save(str(tmp_path), 1, tree)
    ckpt_io.save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1, tree))
    # corrupt the newest
    npz = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 64)
    step, restored = ckpt_io.restore_latest(str(tmp_path), tree)
    assert step == 1  # fell back to the older valid checkpoint
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)


def test_partial_write_never_visible(tmp_path):
    tree = _tree()
    # a crashed save leaves only a .tmp dir; restore must ignore it
    tmp_dir = os.path.join(str(tmp_path), "step_00000009.tmp")
    os.makedirs(tmp_dir)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump({"step": 9, "arrays": {}}, f)
    assert ckpt_io.restore_latest(str(tmp_path), tree) is None
    ckpt_io.save(str(tmp_path), 3, tree)
    step, _ = ckpt_io.restore_latest(str(tmp_path), tree)
    assert step == 3


def test_keep_last_gc(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(6):
        ckpt_io.save(str(tmp_path), s, tree, keep_last=2)
    dirs = sorted(d for d in os.listdir(str(tmp_path))
                  if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_async_saver(tmp_path):
    tree = _tree(3)
    saver = ckpt_io.AsyncSaver()
    saver.save(str(tmp_path), 11, tree)
    saver.wait()
    step, restored = ckpt_io.restore_latest(str(tmp_path), tree)
    assert step == 11
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)


@pytest.mark.parametrize("healthy,expected_shape", [
    (512, (2, 16, 16)),
    (256, (16, 16)),
    (272, (17, 16)),    # 17 data shards — odd but valid
    (8, (1, 8)),
    (3, (1, 2)),        # drops one straggler
])
def test_plan_remesh(healthy, expected_shape):
    plan = elastic.plan_remesh(healthy)
    assert plan.shape == expected_shape
    assert plan.dropped_devices >= 0


def test_elastic_restore_single_device(tmp_path):
    """Reshard-on-restore path runs (1-device mesh: specs resolve to
    replicated)."""
    from jax.sharding import PartitionSpec as P

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    specs = {"w": P("mlp", None)}
    ckpt_io.save(str(tmp_path), 5, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = elastic.elastic_restore(str(tmp_path), tree, specs, mesh)
    assert out is not None
    step, restored = out
    assert step == 5
    np.testing.assert_array_equal(restored["w"], tree["w"])
