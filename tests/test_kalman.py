"""Unit + property tests for the Kalman Filter core (paper Eqs. 1-5)."""
try:  # property tests are optional; unit tests run without hypothesis
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kalman

jax.config.update("jax_enable_x64", False)


def numpy_kf_step(a, b, h, q, r, x, p, z, u=None):
    """Straightforward numpy oracle of Eqs. (1)-(5)."""
    x_prior = a @ x + (b @ u if u is not None else 0.0)
    p_prior = a @ p @ a.T + q
    s = h @ p_prior @ h.T + r
    k = p_prior @ h.T @ np.linalg.inv(s)
    x_post = x_prior + k @ (z - h @ x_prior)
    p_post = (np.eye(a.shape[0]) - k @ h) @ p_prior
    return x_post, 0.5 * (p_post + p_post.T)


def test_step_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    n, m = 3, 2
    a = rng.normal(size=(n, n)).astype(np.float32) * 0.5
    h = rng.normal(size=(m, n)).astype(np.float32)
    q = np.eye(n, dtype=np.float32) * 0.01
    r = np.eye(m, dtype=np.float32) * 0.1
    params = kalman.make_params(a, np.zeros((n, 1), np.float32), h, q, r)
    state = kalman.init_state(n)
    x, p = np.zeros(n, np.float32), np.eye(n, dtype=np.float32)
    for i in range(20):
        z = rng.normal(size=(m,)).astype(np.float32)
        state, _, _ = kalman.step(params, state, jnp.asarray(z))
        x, p = numpy_kf_step(a, np.zeros((n, 1)), h, q, r, x, p, z)
        np.testing.assert_allclose(state.x, x, rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(state.p, p, rtol=2e-4, atol=2e-5)


def test_filter_converges_on_linear_system():
    """Tracking a slowly drifting scalar through noisy 3-dim observations."""
    rng = np.random.default_rng(1)
    T = 400
    true = np.cumsum(rng.normal(scale=0.02, size=T)).astype(np.float32)
    zs = true[:, None] + rng.normal(scale=0.3, size=(T, 3)).astype(np.float32)
    params = kalman.paper_params(q=1e-3, r=0.3**2)
    _, (xs, _) = kalman.filter_trace(params, kalman.init_state(1), jnp.asarray(zs))
    est = np.asarray(xs)[:, 0]
    # posterior should be much closer to the truth than raw observations
    err_est = np.mean((est[50:] - true[50:]) ** 2)
    err_obs = np.mean((zs[50:, 0] - true[50:]) ** 2)
    assert err_est < 0.25 * err_obs


def test_covariance_decreases_with_observations():
    params = kalman.paper_params()
    state = kalman.init_state(1, p0=10.0)
    p_prev = float(state.p[0, 0])
    for _ in range(5):
        state, _, _ = kalman.step(params, state, jnp.zeros(3))
        assert float(state.p[0, 0]) < p_prev
        p_prev = float(state.p[0, 0])


def test_binarize_semantics():
    assert int(kalman.binarize(jnp.asarray(0.2))) == 1
    assert int(kalman.binarize(jnp.asarray(-0.2))) == 0


def test_normalize_observations_range():
    lo, hi = jnp.zeros(3), jnp.full((3,), 100.0)
    z = kalman.normalize_observations(jnp.asarray([0.0, 50.0, 250.0]), lo, hi)
    np.testing.assert_allclose(z, [-1.0, 0.0, 1.0], atol=1e-6)


# ---------------------------------------------------------------------------
# innovation NIS (the self-healing gate's statistic, DESIGN.md §16)
# ---------------------------------------------------------------------------

def test_innovation_nis_matches_direct_formula():
    params = kalman.paper_params()
    state = kalman.init_state(1)
    z = jnp.asarray([0.3, -0.2, 0.5])
    _, prior, _ = kalman.step(params, state, z)
    nis = float(kalman.innovation_nis(params, prior, z))
    h, p = np.asarray(params.h), np.asarray(prior.p)
    s = h @ p @ h.T + np.asarray(params.r)
    nu = np.asarray(z) - h @ np.asarray(prior.x)
    assert nis == pytest.approx(float(nu @ np.linalg.solve(s, nu)), rel=1e-5)
    assert nis >= 0.0


def test_innovation_nis_grows_with_surprise():
    params = kalman.paper_params()
    state = kalman.init_state(1)
    _, prior, _ = kalman.step(params, state, jnp.zeros(3))
    small = float(kalman.innovation_nis(params, prior, jnp.full((3,), 0.1)))
    spike = float(kalman.innovation_nis(params, prior, jnp.full((3,), 8.0)))
    assert spike > small
    assert spike > 50.0  # the default gate threshold flags a +8 spike


def test_innovation_nis_nan_observation_compares_false():
    """NaN z gives NaN NIS, and NaN > threshold is False — which is why
    the simulator's innovation gate carries an explicit finiteness term
    (predictor.step_probed) instead of relying on the comparison."""
    params = kalman.paper_params()
    state = kalman.init_state(1)
    nis = kalman.innovation_nis(params, state, jnp.full((3,), jnp.nan))
    assert not bool(jnp.isfinite(nis))
    assert not bool(nis > 50.0)


# ---------------------------------------------------------------------------
# numerical robustness at the process/measurement-noise extremes
# (deterministic counterparts of the hypothesis properties below)
# ---------------------------------------------------------------------------

EXTREME_QR = [(1e-12, 1e-12), (1e-12, 1e6), (1e6, 1e-12), (1e6, 1e6)]


@pytest.mark.parametrize("q,r", EXTREME_QR)
def test_state_finite_under_extreme_noise(q, r):
    """x and P stay finite (and P positive) across 50 steps of alternating
    saturated observations at both q/r extremes."""
    params = kalman.paper_params(q=q, r=r)
    state = kalman.init_state(1)
    for t in range(50):
        z = jnp.full((3,), 1.0 if t % 2 == 0 else -1.0)
        state, _, _ = kalman.step(params, state, z)
    assert np.all(np.isfinite(np.asarray(state.x)))
    assert np.all(np.isfinite(np.asarray(state.p)))
    assert float(state.p[0, 0]) > 0.0


@pytest.mark.parametrize("q,r", EXTREME_QR)
def test_state_finite_on_zero_variance_stream(q, r):
    """A constant (zero-variance) observation stream must not degenerate
    the covariance to 0 or NaN."""
    params = kalman.paper_params(q=q, r=r)
    state = kalman.init_state(1)
    for _ in range(100):
        state, _, _ = kalman.step(params, state, jnp.full((3,), 0.7))
    assert np.all(np.isfinite(np.asarray(state.x)))
    assert float(state.p[0, 0]) > 0.0


def test_constant_saturated_counters_converge():
    """Counters pinned at the normalization ceiling (z = +1 forever): the
    estimate converges to the saturated value and stays finite."""
    params = kalman.paper_params()
    state = kalman.init_state(1)
    for _ in range(200):
        state, _, _ = kalman.step(params, state, jnp.ones(3))
    x = float(state.x[0])
    assert np.isfinite(x)
    assert x == pytest.approx(1.0, abs=0.05)


if hypothesis is not None:

    @hypothesis.given(
        q=st.floats(1e-6, 1.0),
        r=st.floats(1e-4, 10.0),
        zs=st.lists(
            st.tuples(*[st.floats(-1, 1) for _ in range(3)]),
            min_size=1, max_size=30,
        ),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_property_covariance_stays_positive(q, r, zs):
        """P_k must remain symmetric positive definite for any trace."""
        params = kalman.paper_params(q=q, r=r)
        state = kalman.init_state(1)
        for z in zs:
            state, _, _ = kalman.step(params, state, jnp.asarray(z, jnp.float32))
        p = np.asarray(state.p)
        assert np.all(np.isfinite(p))
        assert p[0, 0] > 0.0

    @hypothesis.given(
        z=st.tuples(*[st.floats(-1, 1) for _ in range(3)]),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_property_posterior_between_prior_and_obs(z):
        """Scalar-state KF: the update moves the estimate toward the
        observation mean without overshooting it (0 < gain contraction < 1)."""
        params = kalman.paper_params(q=1e-2, r=1e-1)
        state = kalman.init_state(1)
        z = jnp.asarray(z, jnp.float32)
        post, prior, _ = kalman.step(params, state, z)
        zbar = float(jnp.mean(z))
        lo, hi = min(0.0, zbar), max(0.0, zbar)
        assert lo - 1e-5 <= float(post.x[0]) <= hi + 1e-5

    @hypothesis.given(
        log_q=st.floats(-12, 6),
        log_r=st.floats(-12, 6),
        z0=st.floats(-1, 1),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_property_state_finite_at_noise_extremes(log_q, log_r, z0):
        """For ANY q, r in [1e-12, 1e6] driven by a zero-variance stream
        (saturation included at |z0| = 1): x and P stay finite, P stays
        positive, and the NIS statistic the self-healing gate consumes is
        finite and non-negative."""
        params = kalman.paper_params(q=10.0 ** log_q, r=10.0 ** log_r)
        state = kalman.init_state(1)
        z = jnp.full((3,), np.float32(z0))
        prior = state
        for _ in range(20):
            state, prior, _ = kalman.step(params, state, z)
        assert np.all(np.isfinite(np.asarray(state.x)))
        assert np.all(np.isfinite(np.asarray(state.p)))
        assert float(state.p[0, 0]) > 0.0
        nis = float(kalman.innovation_nis(params, prior, z))
        assert np.isfinite(nis)
        assert nis > -1e-3  # quadratic form, up to f32 round-off

else:

    def test_property_suite_needs_hypothesis():
        pytest.skip("hypothesis not installed (pip install -e .[test])")


def test_batched_matches_single():
    params = kalman.paper_params()
    B, T = 4, 10
    rng = np.random.default_rng(2)
    zs = rng.normal(size=(T, B, 3)).astype(np.float32)
    states0 = kalman.KalmanState(
        x=jnp.zeros((B, 1)), p=jnp.broadcast_to(jnp.eye(1), (B, 1, 1))
    )
    _, (xs, _) = kalman.batched_filter_trace(params, states0, jnp.asarray(zs))
    for b in range(B):
        _, (xs_b, _) = kalman.filter_trace(
            params, kalman.init_state(1), jnp.asarray(zs[:, b])
        )
        np.testing.assert_allclose(xs[:, b], xs_b, rtol=1e-5, atol=1e-6)
