"""TrafficSource protocol + trace-driven demand replay (DESIGN.md §15).

Contracts pinned here:

  1. every source kind — workload name, `WorkloadProfile`,
     `ScenarioSchedule`, `RecordedTrace`, bare 5-tuple shim, or a custom
     object implementing the protocol — lowers through ONE path
     (`resolve_source`) to the same `(n_epochs,)` float32 EpochDemand
     pytree;
  2. a trace recorded from scenario X replays bitwise-identical to
     running X directly, including through the npz file round trip
     (`TraceRecorder` capture -> save -> load -> simulate);
  3. mixed source kinds in ONE sweep still share a single compiled
     program (`sim.trace_count() == 1`);
  4. the workload registry: collision refusal, overwrite, unregister,
     and near-miss suggestions on unknown names (the old bare-KeyError
     bug);
  5. `RecordedTrace` fit modes (exact / tile / stretch) and the
     versioned npz schema validation;
  6. the HLO-cost adapter's roofline mapping, and the real lowered
     prefill/decode steps landing on opposite sides of machine balance
     (calm prefill vs saturating decode — the property the serving
     schedule's gate geometry relies on).
"""
import dataclasses
import io
import json

import jax
import numpy as np
import pytest

from repro.core.noc import sim, trace_adapters
from repro.core.noc.sim import NoCConfig, SweepSpec
from repro.core.noc.traffic import (
    PROFILES,
    SCENARIOS,
    TRACE_SCHEMA,
    TRACE_SCHEMA_VERSION,
    EpochDemand,
    RecordedTrace,
    ScenarioSchedule,
    TrafficSource,
    WorkloadProfile,
    lookup_workload,
    materialize,
    register_trace,
    register_workload,
    resolve_source,
    unregister_workload,
    validate_trace_npz,
)
from repro.obs.recorder import TraceRecorder, capture_demand

FAST = dict(n_epochs=8, epoch_len=100)
N = FAST["n_epochs"]


def _rows_equal(a: WorkloadProfile, b: WorkloadProfile, bitwise=True):
    for f in WorkloadProfile._fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if bitwise:
            np.testing.assert_array_equal(x, y, err_msg=f"leaf {f}")
        else:
            np.testing.assert_allclose(x, y, rtol=1e-6, err_msg=f"leaf {f}")


def _results_bitwise_equal(res, ref, label):
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(ref),
                            jax.tree.leaves(res)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"{label}: leaf {jax.tree_util.keystr(path)} diverged"
        )


def _ramp_trace(T=4, fit="exact", name="ramp") -> RecordedTrace:
    """A tiny trace with per-epoch-distinct rows (easy to index-check)."""
    t = np.arange(T, dtype=np.float32)
    return RecordedTrace(
        demand=WorkloadProfile(
            gpu_rate_lo=0.01 * t,
            gpu_rate_hi=0.10 + 0.01 * t,
            p_enter=np.full(T, 0.5, np.float32),
            p_exit=np.full(T, 0.5, np.float32),
            cpu_rate=np.full(T, 0.12, np.float32),
        ),
        fit=fit,
        name=name,
    )


# ---------------------------------------------------------------------------
# 1. resolve_source: one lowering path for every source kind
# ---------------------------------------------------------------------------


class TestResolveSource:
    def test_every_kind_lowers_to_epoch_rows(self):
        """Name, profile object, and bare tuple agree leaf-for-leaf."""
        by_name = resolve_source("PATH", N)
        by_obj = resolve_source(PROFILES["PATH"], N)
        by_tuple = resolve_source(tuple(PROFILES["PATH"]), N)
        assert isinstance(by_name, EpochDemand)
        for demand in (by_name, by_obj, by_tuple):
            for f in WorkloadProfile._fields:
                leaf = getattr(demand, f)
                assert leaf.shape == (N,) and leaf.dtype == np.float32
        _rows_equal(by_name, by_obj)
        _rows_equal(by_name, by_tuple)

    def test_scenario_name_and_object_agree(self):
        by_name = resolve_source("SHIFT_PATH_BFS", N)
        by_obj = resolve_source(SCENARIOS["SHIFT_PATH_BFS"], N)
        _rows_equal(by_name, by_obj)

    def test_materialized_demand_is_itself_a_source(self):
        """EpochDemand implements the protocol, so resolution is idempotent."""
        demand = resolve_source("BFS", N)
        assert isinstance(demand, TrafficSource)
        _rows_equal(resolve_source(demand, N), demand)

    def test_custom_protocol_object(self):
        """Any object with epoch_demand(n) is a first-class source."""

        class Sawtooth:
            def epoch_demand(self, n_epochs):
                t = np.arange(n_epochs, dtype=np.float32) / n_epochs
                return WorkloadProfile(
                    gpu_rate_lo=t * 0.1, gpu_rate_hi=t * 0.3,
                    p_enter=np.zeros(n_epochs, np.float32),
                    p_exit=np.ones(n_epochs, np.float32),
                    cpu_rate=np.full(n_epochs, 0.12, np.float32),
                ).epoch_demand(n_epochs)

        assert isinstance(Sawtooth(), TrafficSource)
        demand = resolve_source(Sawtooth(), N)
        assert np.asarray(demand.gpu_rate_hi)[-1] == pytest.approx(
            0.3 * (N - 1) / N)

    def test_rejects_non_sources(self):
        with pytest.raises(TypeError, match="cannot resolve demand source"):
            resolve_source(42, N)
        with pytest.raises(TypeError, match="cannot resolve"):
            resolve_source(("PATH",), N)  # wrong-arity tuple is not a shim

    def test_rejects_wrong_shape_from_custom_source(self):
        """A source emitting the wrong epoch axis is caught at the boundary."""

        class Liar:
            def epoch_demand(self, n_epochs):
                return PROFILES["PATH"].epoch_demand(n_epochs + 1)

        with pytest.raises(ValueError, match="needs \\(8,\\) float32"):
            resolve_source(Liar(), N)

    def test_profile_rejects_wrong_length_per_epoch_leaf(self):
        prof = PROFILES["PATH"]._replace(
            gpu_rate_hi=np.ones(N + 2, np.float32))
        with pytest.raises(ValueError, match="per-epoch profile leaf"):
            resolve_source(prof, N)

    def test_rejects_non_finite_demand_values(self):
        """A NaN/inf demand row is stopped at the resolution boundary —
        silently feeding it to the simulator would poison every counter
        (and, unguarded, the KF state) downstream."""

        class Poisoned:
            def epoch_demand(self, n_epochs):
                demand = PROFILES["PATH"].epoch_demand(n_epochs)
                row = np.asarray(demand.cpu_rate).copy()
                row[1] = np.nan
                return demand._replace(cpu_rate=row)

        with pytest.raises(ValueError, match="non-finite demand"):
            resolve_source(Poisoned(), N)

    def test_rejects_negative_demand_values(self):
        """Rates and probabilities are non-negative by construction; a
        negative row can only come from a buggy or corrupted source."""

        class Negative:
            def epoch_demand(self, n_epochs):
                demand = PROFILES["PATH"].epoch_demand(n_epochs)
                row = np.asarray(demand.gpu_rate_hi).copy()
                row[0] = -0.5
                return demand._replace(gpu_rate_hi=row)

        with pytest.raises(ValueError, match="negative demand"):
            resolve_source(Negative(), N)

    def test_materialize_shim_matches_resolve_source(self):
        """The deprecated pre-§15 entrypoint stays value-identical."""
        _rows_equal(materialize("SHIFT_PATH_BFS", N),
                    resolve_source("SHIFT_PATH_BFS", N))


# ---------------------------------------------------------------------------
# 2. workload registry + near-miss lookup
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_unknown_name_suggests_near_misses(self):
        """ValueError (not bare KeyError) naming the close matches."""
        with pytest.raises(ValueError) as ei:
            lookup_workload("SHIFT_PATH_BSF")
        msg = str(ei.value)
        assert "SHIFT_PATH_BSF" in msg and "SHIFT_PATH_BFS" in msg
        assert "did you mean" in msg

    def test_unknown_name_without_near_miss_lists_known(self):
        with pytest.raises(ValueError, match="known workloads"):
            lookup_workload("zzzzqqqq")

    def test_register_lookup_unregister(self):
        trace = _ramp_trace(T=N)
        try:
            register_workload("RAMP_TEST_WL", trace)
            assert lookup_workload("RAMP_TEST_WL") is trace
            _rows_equal(resolve_source("RAMP_TEST_WL", N),
                        trace.epoch_demand(N))
        finally:
            unregister_workload("RAMP_TEST_WL")
        with pytest.raises(ValueError):
            lookup_workload("RAMP_TEST_WL")

    def test_collision_refused_unless_overwrite(self):
        trace = _ramp_trace(T=N)
        with pytest.raises(ValueError, match="already exists"):
            register_workload("PATH", trace)  # builtin profile
        with pytest.raises(ValueError, match="already exists"):
            register_workload("SHIFT_PATH_BFS", trace)  # builtin scenario
        try:
            register_workload("PATH", trace, overwrite=True)
            assert lookup_workload("PATH") is trace  # registry wins
        finally:
            unregister_workload("PATH")
        assert lookup_workload("PATH") is PROFILES["PATH"]  # builtin restored

    def test_register_rejects_non_source(self):
        with pytest.raises(TypeError, match="TrafficSource"):
            register_workload("BAD_WL", object())

    def test_register_trace_from_file(self, tmp_path):
        path = tmp_path / "ramp.npz"
        _ramp_trace(T=N).save(path)
        try:
            trace = register_trace("RAMP_FILE_WL", path, fit="tile")
            assert trace.fit == "tile"
            assert lookup_workload("RAMP_FILE_WL") is trace
        finally:
            unregister_workload("RAMP_FILE_WL")


# ---------------------------------------------------------------------------
# 3. RecordedTrace: fit modes, construction guards, npz schema
# ---------------------------------------------------------------------------


class TestRecordedTrace:
    def test_exact_passthrough_and_mismatch(self):
        trace = _ramp_trace(T=N, fit="exact")
        _rows_equal(trace.epoch_demand(N), trace.demand)
        with pytest.raises(ValueError, match="fit='tile' or fit='stretch'"):
            trace.epoch_demand(N + 1)

    def test_tile_repeats_cyclically(self):
        trace = _ramp_trace(T=4, fit="tile")
        demand = trace.epoch_demand(10)
        lo = np.asarray(demand.gpu_rate_lo)
        expected = np.asarray(trace.demand.gpu_rate_lo)[
            np.arange(10) % 4]
        np.testing.assert_array_equal(lo, expected)

    def test_stretch_resamples_linearly(self):
        trace = _ramp_trace(T=4, fit="stretch")
        demand = trace.epoch_demand(7)
        lo = np.asarray(demand.gpu_rate_lo)
        # the ramp 0..0.03 over 4 points resampled to 7 stays a ramp
        np.testing.assert_allclose(
            lo, np.linspace(0.0, 0.03, 7), rtol=1e-5)

    def test_all_fits_passthrough_when_lengths_match(self):
        """T == n_epochs short-circuits every fit mode bitwise."""
        for fit in ("exact", "tile", "stretch"):
            trace = _ramp_trace(T=N, fit=fit)
            _rows_equal(trace.epoch_demand(N), trace.demand)

    def test_with_fit(self):
        trace = _ramp_trace(T=4)
        assert trace.with_fit("stretch").fit == "stretch"
        with pytest.raises(ValueError, match="fit must be one of"):
            trace.with_fit("nearest")

    def test_rejects_scalar_ragged_empty(self):
        with pytest.raises(ValueError, match="scalar"):
            RecordedTrace(demand=PROFILES["PATH"])
        ragged = _ramp_trace(T=4).demand._replace(
            cpu_rate=np.zeros(5, np.float32))
        with pytest.raises(ValueError, match="disagree on length"):
            RecordedTrace(demand=ragged)
        empty = jax.tree.map(lambda x: np.asarray(x)[:0],
                             _ramp_trace(T=4).demand)
        with pytest.raises(ValueError, match="at least one epoch"):
            RecordedTrace(demand=empty)

    def test_npz_roundtrip_preserves_everything(self, tmp_path):
        path = tmp_path / "trace.npz"
        meta = {"source": "unit", "n_epochs": 4, "nested": {"a": [1, 2]}}
        trace = dataclasses.replace(_ramp_trace(T=4, name="rt"), meta=meta)
        trace.save(path)
        loaded = RecordedTrace.load(path, fit="tile")
        assert loaded.name == "rt" and loaded.fit == "tile"
        assert loaded.meta == meta
        _rows_equal(loaded.demand, trace.demand)

    def test_load_rejects_non_trace_npz(self, tmp_path):
        path = tmp_path / "other.npz"
        np.savez(path, schema="something_else", schema_version=1,
                 name="x", meta_json="{}")
        with pytest.raises(ValueError, match=TRACE_SCHEMA):
            RecordedTrace.load(path)


class TestTraceSchemaValidation:
    def _valid_payload(self, T=4):
        payload = {
            "schema": np.asarray(TRACE_SCHEMA),
            "schema_version": np.asarray(TRACE_SCHEMA_VERSION),
            "name": np.asarray("t"),
            "meta_json": np.asarray("{}"),
        }
        for f in WorkloadProfile._fields:
            payload[f"demand_{f}"] = np.zeros(T, np.float32)
        return payload

    def test_valid_payload_passes(self):
        assert validate_trace_npz(self._valid_payload()) == []

    def test_missing_keys_flagged(self):
        payload = self._valid_payload()
        del payload["schema_version"], payload["demand_cpu_rate"]
        problems = "; ".join(validate_trace_npz(payload))
        assert "schema_version" in problems
        assert "demand_cpu_rate" in problems

    def test_wrong_schema_and_future_version(self):
        payload = self._valid_payload()
        payload["schema"] = np.asarray("not_a_trace")
        payload["schema_version"] = np.asarray(TRACE_SCHEMA_VERSION + 1)
        problems = "; ".join(validate_trace_npz(payload))
        assert "not_a_trace" in problems and "newer than supported" in problems

    def test_ragged_and_nonfinite_rows(self):
        payload = self._valid_payload(T=4)
        payload["demand_p_exit"] = np.zeros(6, np.float32)
        bad = np.zeros(4, np.float32)
        bad[2] = np.nan
        payload["demand_cpu_rate"] = bad
        problems = "; ".join(validate_trace_npz(payload))
        assert "length" in problems and "non-finite" in problems

    def test_scalar_row_and_bad_meta(self):
        payload = self._valid_payload()
        payload["demand_gpu_rate_lo"] = np.float32(0.1)
        payload["meta_json"] = np.asarray("{not json")
        problems = "; ".join(validate_trace_npz(payload))
        assert "expected (T,)" in problems and "not valid JSON" in problems

    def test_negative_rows_flagged(self):
        payload = self._valid_payload(T=4)
        bad = np.zeros(4, np.float32)
        bad[1] = -0.25
        payload["demand_gpu_rate_hi"] = bad
        problems = "; ".join(validate_trace_npz(payload))
        assert "negative" in problems

    def test_real_file_validates_via_np_load(self, tmp_path):
        path = tmp_path / "t.npz"
        _ramp_trace(T=3).save(path)
        with np.load(path, allow_pickle=False) as data:
            assert validate_trace_npz(data) == []

    def test_hand_corrupted_npz_rejected(self, tmp_path):
        """Regression: a trace file corrupted ON DISK (negative demand in
        one row, inf in another) must fail validation — a replay driven by
        it would otherwise launder the corruption into results."""
        path = tmp_path / "t.npz"
        _ramp_trace(T=4).save(path)
        with np.load(path, allow_pickle=False) as data:
            payload = {k: np.array(data[k]) for k in data.files}
        payload["demand_cpu_rate"][2] = -1.0
        payload["demand_p_enter"][0] = np.inf
        corrupted = tmp_path / "corrupted.npz"
        np.savez(corrupted, **payload)
        with np.load(corrupted, allow_pickle=False) as data:
            problems = "; ".join(validate_trace_npz(data))
        assert "negative" in problems and "non-finite" in problems

    def test_save_never_pickles(self, tmp_path):
        """meta with nested structures still loads under allow_pickle=False."""
        path = tmp_path / "t.npz"
        trace = dataclasses.replace(
            _ramp_trace(T=3), meta={"deep": {"list": [1.5, "s"]}})
        trace.save(path)
        buf = io.BytesIO(path.read_bytes())
        with np.load(buf, allow_pickle=False) as data:
            meta = json.loads(str(np.asarray(data["meta_json"]).item()))
        assert meta == {"deep": {"list": [1.5, "s"]}}


# ---------------------------------------------------------------------------
# 4. record -> replay: the bitwise contract
# ---------------------------------------------------------------------------


class TestRecordReplay:
    def test_scenario_capture_replays_bitwise(self):
        """TraceRecorder capture of scenario X == running X, bit for bit."""
        cfg = NoCConfig(mode="kf", **FAST)
        trace = TraceRecorder(observe=False).record(cfg, "SHIFT_PATH_BFS")
        assert trace.n_epochs_recorded == N and trace.fit == "exact"
        ref = sim.simulate(cfg, "SHIFT_PATH_BFS")
        rep = sim.simulate(cfg, trace)
        _results_bitwise_equal(rep, ref, "scenario capture replay")

    def test_capture_survives_npz_roundtrip_bitwise(self, tmp_path):
        """record -> save -> load -> simulate is still bitwise identical."""
        path = tmp_path / "capture.npz"
        cfg = NoCConfig(mode="kf", **FAST)
        TraceRecorder(name="rr", observe=False).record_to(
            path, cfg, "SHIFT_PATH_BFS")
        loaded = RecordedTrace.load(path)
        ref = sim.simulate(cfg, "SHIFT_PATH_BFS")
        rep = sim.simulate(cfg, loaded)
        _results_bitwise_equal(rep, ref, "npz roundtrip replay")

    def test_capture_meta_provenance(self):
        cfg = NoCConfig(mode="fair", seed=7, **FAST)
        trace = TraceRecorder(observe=False).record(cfg, "PATH")
        meta = trace.meta
        assert meta["source"] == "PATH" and meta["mode"] == "fair"
        assert meta["n_epochs"] == N and meta["seed"] == 7

    def test_observing_capture_attaches_telemetry(self):
        """observe=True rides the §14 flight recorder without changing rows."""
        cfg = NoCConfig(mode="kf", **FAST)
        silent = TraceRecorder(observe=False).record(cfg, "PATH")
        observed = TraceRecorder(observe=True).record(cfg, "PATH")
        _rows_equal(observed.demand, silent.demand)
        assert "observed" in observed.meta and "result" in observed.meta
        assert "observed" not in silent.meta

    def test_capture_demand_oneshot(self, tmp_path):
        path = tmp_path / "one.npz"
        cfg = NoCConfig(mode="baseline", **FAST)
        trace = capture_demand(cfg, "BFS", path=path, name="one")
        assert path.exists()
        _rows_equal(RecordedTrace.load(path).demand, trace.demand)


# ---------------------------------------------------------------------------
# 5. mixed sources through the sweep: one compiled program
# ---------------------------------------------------------------------------


class TestSweepIntegration:
    def test_mixed_source_kinds_share_one_trace(self):
        """Profile + scenario + registered trace in one sweep: 1 trace."""
        trace = _ramp_trace(T=N, name="mix")
        try:
            register_workload("MIX_TRACE_WL", trace)
            specs = [
                SweepSpec("kf", wl, seed=s)
                for wl in ("PATH", "SHIFT_PATH_BFS", "MIX_TRACE_WL")
                for s in (0, 1)
            ]
            sim.reset_trace_count()
            rows = sim.sweep(specs, **FAST)
            # <= 1: an earlier test with the same dims may have warmed the
            # jit cache, in which case the mixed grid adds ZERO traces
            assert sim.trace_count() <= 1
        finally:
            unregister_workload("MIX_TRACE_WL")
        # the trace-backed row equals its standalone simulate
        cfg = NoCConfig(mode="kf", seed=0, **FAST)
        ref = sim.simulate(cfg, trace)
        _results_bitwise_equal(rows[4], ref, "trace row in mixed sweep")

    def test_simulate_batch_single_source_broadcast(self):
        """One source object fans out across the batch (tuple-safe)."""
        cfgs = [NoCConfig(mode="baseline", seed=s, **FAST) for s in (0, 1)]
        batch = sim.simulate_batch(cfgs, PROFILES["PATH"])
        per = [sim.simulate(c, "PATH") for c in cfgs]
        for i, ref in enumerate(per):
            row = jax.tree.map(lambda x: x[i], batch)
            _results_bitwise_equal(row, ref, f"broadcast row {i}")


# ---------------------------------------------------------------------------
# 6. HLO-cost adapter
# ---------------------------------------------------------------------------


class TestHloAdapter:
    def test_roofline_mapping(self):
        r = trace_adapters.ChipletRoofline()
        balance = r.peak_flops_per_cycle / r.peak_hbm_bytes_per_cycle
        # memory-bound: intensity saturates at 1, rate at peak
        assert r.intensity(flops=1.0, bytes_moved=1e6) == pytest.approx(1.0)
        assert r.gpu_rate(1.0, 1e6) == pytest.approx(r.peak_rate)
        # exactly at machine balance: still fully memory-bound
        assert r.intensity(balance * 64.0, 64.0) == pytest.approx(1.0)
        # compute-bound at 4x balance: quarter intensity
        assert r.intensity(4 * balance * 64.0, 64.0) == pytest.approx(0.25)
        assert r.intensity(0.0, 0.0) == 0.0

    def test_demand_from_costs_schedule_layout(self):
        costs = {
            "prefill": {"flops": 4096.0, "bytes": 64.0},   # 16x balance
            "decode": {"flops": 1.0, "bytes": 1024.0},     # memory-bound
        }
        schedule = (("prefill", 3), ("decode", 2), ("sync", 1))
        trace = trace_adapters.demand_from_costs(costs, schedule,
                                                 name="unit")
        assert trace.n_epochs_recorded == 6
        lo = np.asarray(trace.demand.gpu_rate_lo)
        r = trace_adapters.ChipletRoofline()
        np.testing.assert_allclose(lo[:3], r.peak_rate / 16, rtol=1e-6)
        np.testing.assert_allclose(lo[3:5], r.peak_rate, rtol=1e-6)
        assert lo[5] == 0.0  # sync carries no GPU fabric demand
        # deterministic rows: no Markov dynamics in a replayed trace
        np.testing.assert_array_equal(lo, np.asarray(trace.demand.gpu_rate_hi))
        assert np.all(np.asarray(trace.demand.p_enter) == 0.0)
        assert np.all(np.asarray(trace.demand.p_exit) == 1.0)
        assert trace.meta["phases"]["decode"]["intensity"] == pytest.approx(
            1.0)

    def test_demand_from_costs_unknown_phase(self):
        with pytest.raises(ValueError, match="no cost entry"):
            trace_adapters.demand_from_costs(
                {"prefill": {"flops": 1.0, "bytes": 1.0}},
                (("warmup", 2),))

    def test_real_steps_straddle_machine_balance(self):
        """Lowered prefill is compute-bound, decode memory-bound.

        This is the adapter's load-bearing property: the serving schedule
        only produces the calm/saturating arcs the predictor gate needs if
        the repo's own prefill and decode HLO sit on opposite sides of the
        roofline knee.
        """
        prefill = trace_adapters.step_cost("prefill", batch=2)
        decode = trace_adapters.step_cost("decode", batch=4)
        assert prefill["flops"] > 0 and prefill["bytes"] > 0
        assert decode["flops"] > 0 and decode["bytes"] > 0
        r = trace_adapters.ChipletRoofline()
        balance = r.peak_flops_per_cycle / r.peak_hbm_bytes_per_cycle
        assert prefill["flops"] / prefill["bytes"] > balance
        assert decode["flops"] / decode["bytes"] < balance
        assert r.intensity(prefill["flops"], prefill["bytes"]) < 0.5
        assert r.intensity(decode["flops"], decode["bytes"]) == pytest.approx(
            1.0)

    def test_step_cost_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown phase kind"):
            trace_adapters.step_cost("training")

    def test_serving_trace_runs_through_simulator(self):
        """The adapter trace is a runnable workload end to end (stretch-fit
        onto a short run so the test stays cheap)."""
        costs = {
            "prefill": {"flops": 4096.0, "bytes": 64.0},
            "decode": {"flops": 1.0, "bytes": 1024.0},
        }
        trace = trace_adapters.demand_from_costs(
            costs, name="unit_serve").with_fit("stretch")
        try:
            register_workload("UNIT_SERVE_WL", trace)
            cfg = NoCConfig(mode="kf", **FAST)
            res = sim.simulate(cfg, "UNIT_SERVE_WL")
        finally:
            unregister_workload("UNIT_SERVE_WL")
        ipc = np.asarray(res.gpu_ipc)
        assert ipc.shape == (N,) and np.all(np.isfinite(ipc))
