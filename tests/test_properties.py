"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[test])"
)
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from repro.core import kalman
from repro.core.allocator import PolicyConfig, apply_policy, init_policy_state

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(
    q=st.floats(1e-5, 1e-1), r=st.floats(1e-3, 1.0),
    z_seed=st.integers(0, 2**31 - 1),
)
def test_kalman_variance_contracts_and_stays_positive(q, r, z_seed):
    """Posterior variance is positive and bounded by prior variance + Q."""
    params = kalman.paper_params(q=q, r=r)
    state = kalman.init_state(1, p0=1.0)
    zs = jax.random.normal(jax.random.PRNGKey(z_seed), (50, 3))
    for i in range(50):
        prior = kalman.time_update(params, state)
        state, _ = kalman.measurement_update(params, prior, zs[i])
        assert float(state.p[0, 0]) > 0.0
        assert float(state.p[0, 0]) <= float(prior.p[0, 0]) + 1e-6


@settings(max_examples=25, deadline=None)
@given(
    signals=st.lists(st.integers(0, 1), min_size=30, max_size=120),
    warmup=st.integers(0, 20), hold=st.integers(1, 10),
)
def test_policy_hysteresis_invariants(signals, warmup, hold):
    """(1) no change before warmup; (2) changes >= hold apart."""
    cfg = PolicyConfig(warmup=warmup, hold=hold, revert=10_000)
    pol = init_policy_state()
    trace = []
    for cyc, s in enumerate(signals):
        pol = apply_policy(cfg, pol, jnp.int32(s), jnp.int32(cyc))
        trace.append(int(pol.config))
    for cyc in range(min(warmup, len(trace))):
        assert trace[cyc] == 0
    changes = [i for i in range(1, len(trace)) if trace[i] != trace[i - 1]]
    for a, b in zip(changes, changes[1:]):
        assert b - a >= hold


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3), L=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([4, 8]), s=st.sampled_from([2, 4]),
    chunk=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1),
)
def test_chunked_scan_equals_naive(b, L, d, s, chunk, seed):
    """Chunked associative scan == sequential recurrence for any shape."""
    from repro.models import mamba
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.uniform(ks[0], (b, L, d, s), jnp.float32, 0.3, 0.999)
    bb = jax.random.normal(ks[1], (b, L, d, s))
    h0 = jax.random.normal(ks[2], (b, d, s))
    hs_c, hl_c = mamba.chunked_scan(a, bb, h0, chunk)
    hs_r, hl_r = mamba.ref_scan(a, bb, h0)
    np.testing.assert_allclose(np.asarray(hs_c), np.asarray(hs_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl_c), np.asarray(hl_r),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-6, 1e3))
def test_quantize_ef_error_bound(seed, scale):
    """|g - deq(q)| <= scale/2 elementwise and residual == error."""
    from repro.dist import compress
    g = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * scale
    q, s, r = compress.quantize_ef(g, jnp.zeros((128,)))
    deq = compress.dequantize(q, s)
    assert float(jnp.max(jnp.abs(g - deq))) <= float(s) * 0.5 + 1e-9 * scale
    np.testing.assert_allclose(np.asarray(g - deq), np.asarray(r),
                               rtol=1e-5, atol=1e-5 * scale)


@settings(max_examples=15, deadline=None)
@given(
    sq=st.sampled_from([64, 128]), kv=st.sampled_from([1, 2, 4]),
    rep=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**31 - 1),
    causal=st.booleans(),
)
def test_flash_kernel_property(sq, kv, rep, seed, causal):
    """Flash kernel == oracle across GQA ratios / causality / seeds."""
    from repro.kernels.flash_attn import ops, ref

    h = kv * rep
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, h, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, sq, kv, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, sq, kv, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 2**31 - 1))
def test_data_pipeline_is_pure_function_of_step(step, seed):
    """Restart safety depends on batch(step) being deterministic."""
    from repro.data import synthetic

    cfg = synthetic.DataConfig(vocab_size=128, seq_len=16, global_batch=2,
                               seed=seed)
    ds1 = synthetic.SyntheticDataset(cfg)
    ds2 = synthetic.SyntheticDataset(cfg)
    b1, b2 = ds1.batch(step), ds2.batch(step)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # and labels are shifted tokens (next-token objective)
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
