"""Cycle-engine equivalence suite (DESIGN.md §11).

The packed-lane engine rewrote the hot loop under a bitwise contract: every
rewrite (epoch-hoisted masks, precomputed RNG streams, the merged inject,
packed narrow-dtype state, the scatter-free dense writes, the Pallas
arbitration kernel) must leave all observable outputs exactly as the PR-3
engine produced them.  Three layers of pinning:

  1. golden outputs — `tests/golden_cycle_engine.json` was captured from the
     PR-3 padded program; the new engine must reproduce it bit-for-bit;
  2. rewrite micro-tests — each equivalence-preserving rewrite is checked
     directly against the formulation it replaced;
  3. ref <-> Pallas congruence — `kernels.noc_cycle` (interpret mode off
     TPU) must agree with `router.arbitrate` exactly, from a single
     arbitration step up to a whole `simulate(backend="pallas")` run.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import PolicyConfig
from repro.core.noc import router as rt
from repro.core.noc import sim
from repro.core.noc.sim import NoCConfig
from repro.core.noc.topology import N_PORTS, make_topology
from repro.core.noc.traffic import PROFILES

FAST = dict(n_epochs=8, epoch_len=100)
GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden_cycle_engine.json"
)


# ---------------------------------------------------------------------------
# 1. golden pinning vs the PR-3 engine
# ---------------------------------------------------------------------------

def test_outputs_match_pr3_golden_capture():
    """Counters/config/latency match the pre-rewrite padded program exactly.

    The golden file was captured from the PR-3 engine (per-cycle RNG
    splits, separate injects, int32 scatter state) before this refactor
    landed; equality here proves the whole rewrite chain is value-preserving,
    not just self-consistent.
    """
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for key, g in golden.items():
        mode, wl, gs, ss = key.split("/")
        cfg = NoCConfig(mode=mode, static_gpu_vcs=int(gs[1:]),
                        seed=int(ss[1:]), **FAST)
        res = sim.simulate(cfg, PROFILES[wl])
        sums = {n: int(np.sum(np.asarray(leaf)))
                for n, leaf in zip(res.counters._fields, res.counters)}
        assert sums == g["counter_sums"], f"{key}: counter drift"
        assert np.asarray(res.applied_config).tolist() == g["applied_config"]
        assert np.asarray(res.kf_signal).tolist() == g["kf_signal"]
        np.testing.assert_allclose(
            float(np.asarray(res.avg_latency)[-1]), g["avg_latency_last"],
            rtol=0, atol=1e-6, err_msg=key,
        )


# ---------------------------------------------------------------------------
# 2. rewrite micro-tests
# ---------------------------------------------------------------------------

def test_batched_rng_streams_match_per_cycle_splits():
    """The per-epoch vmapped RNG precompute == the old per-cycle splits."""
    epoch_key = jax.random.PRNGKey(42)
    ep_len, R, n_mc = 37, 36, 8
    keys = jax.random.split(epoch_key, ep_len)

    # old engine: draw inside the loop, one cycle at a time
    u_ph_ref, u_gen_ref, d_ref = [], [], []
    for i in range(ep_len):
        k_phase, k_gen, k_dest = jax.random.split(keys[i], 3)
        u_ph_ref.append(jax.random.uniform(k_phase, ()))
        u_gen_ref.append(jax.random.uniform(k_gen, (R,), jnp.float32))
        d_ref.append(jax.random.randint(k_dest, (R,), 0, n_mc))

    # new engine: one batched draw per epoch (sim.epoch_body's precompute)
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
    u_phase = jax.vmap(lambda k: jax.random.uniform(k, ()))(k3[:, 0])
    u_gen = jax.vmap(
        lambda k: jax.random.uniform(k, (R,), jnp.float32)
    )(k3[:, 1])
    d_idx = jax.vmap(
        lambda k: jax.random.randint(k, (R,), 0, n_mc)
    )(k3[:, 2])

    np.testing.assert_array_equal(np.asarray(u_phase), np.stack(u_ph_ref))
    np.testing.assert_array_equal(np.asarray(u_gen), np.stack(u_gen_ref))
    np.testing.assert_array_equal(np.asarray(d_idx), np.stack(d_ref))


def _random_subnet_state(rng, S=4, R=36, P=N_PORTS, V=4, B=4):
    dest = rng.integers(0, R, (S, R, P, V, B))
    src = rng.integers(0, R, (S, R, P, V, B))
    cls = rng.integers(0, 2, (S, R, P, V, B))
    return rt.SubnetState(
        buf_meta=jnp.asarray(
            dest + (src << rt.META_SRC_SHIFT) + (cls << rt.META_CLS_SHIFT),
            jnp.int16,
        ),
        buf_binj=jnp.asarray(
            rng.integers(0, 5000, (S, R, P, V, B)), jnp.uint16
        ),
        head=jnp.asarray(rng.integers(0, B, (S, R, P, V)), jnp.int8),
        count=jnp.asarray(rng.integers(0, B + 1, (S, R, P, V)), jnp.int8),
        rr_ptr=jnp.asarray(rng.integers(0, P * V, (S, R, P)), jnp.int8),
    )


def _states_equal(a, b):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"state leaf {name}"
        )


def test_merged_inject_equals_separate_injects():
    """One inject over the union want-matrix == two per-kind injects.

    The cycle engine fuses the MC-reply and source injections into one
    `inject_all` pass; they target disjoint subnet rows, so the merged call
    must be exactly the composition of the separate ones.
    """
    rng = np.random.default_rng(7)
    S, R, V = 4, 36, 4
    state = _random_subnet_state(rng)
    sub_is_req = jnp.asarray([True, False, True, False])

    want_src = jnp.asarray(rng.random((S, R)) < 0.5) & sub_is_req[:, None]
    want_rep = jnp.asarray(rng.random((S, R)) < 0.5) & ~sub_is_req[:, None]
    dest = jnp.asarray(rng.integers(0, R, (S, R)), jnp.int32)
    src = jnp.asarray(rng.integers(0, R, (S, R)), jnp.int32)
    cls = jnp.asarray(rng.integers(0, 2, (S, R)), jnp.int32)
    binj = jnp.asarray(rng.integers(0, 5000, (S, R)), jnp.int32)
    gmask = jnp.asarray(rng.random((S, V)) < 0.7)
    cmask = jnp.asarray(rng.random((S, V)) < 0.7)

    merged, ok_m = rt.inject_all(
        state, want_src | want_rep, dest, src, cls, binj, gmask, cmask
    )
    step1, ok_rep = rt.inject_all(
        state, want_rep, dest, src, cls, binj, gmask, cmask
    )
    sep, ok_src = rt.inject_all(
        step1, want_src, dest, src, cls, binj, gmask, cmask
    )
    _states_equal(merged, sep)
    np.testing.assert_array_equal(np.asarray(ok_m), np.asarray(ok_rep | ok_src))


def test_packed_state_roundtrips_and_wrap_exact_latency():
    """Packed vs int32 state: every field a packet can carry survives the
    int16 meta pack exactly, and the uint16 injection stamps give the same
    latency as int32 arithmetic for every age the engine can produce."""
    R = make_topology().n_routers
    dest, src, cls = np.meshgrid(
        np.arange(R), np.arange(R), np.arange(2), indexing="ij"
    )
    d, s, c = (jnp.asarray(x.ravel(), jnp.int32) for x in (dest, src, cls))
    meta = rt.pack_meta(d, s, c)
    assert meta.dtype == jnp.int16
    d2, s2, c2 = rt.unpack_meta(meta)
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(c))

    # wraparound-exact uint16 age: (cycle - binj) mod 2^16 == true age
    total = 60_001  # default paper run: 120 epochs x 500 cycles (+1 stamp)
    binj = jnp.asarray([0, 1, 40_000, 60_000, 65_000], jnp.uint16)
    cycle = jnp.int32(total - 1)
    age16 = (cycle.astype(jnp.uint16) - binj).astype(jnp.int32)
    true_age = cycle - jnp.asarray([0, 1, 40_000, 60_000, 65_000], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(age16)[true_age >= 0], np.asarray(true_age)[true_age >= 0]
    )


def test_policy_boundary_masks_flip_exactly_one_epoch_after_config():
    """Guard the epoch-level mask hoisting against an off-by-one epoch.

    `apply_policy_gated` runs at the END of epoch e, so the masks applied
    DURING epoch e must reflect `applied_config[e-1]` — never `[e]` (that
    would mean the hoist reads the config too early) and never `[e-2]`
    (stale by one).  `gpu_vc_quota` reports the hoisted mask the epoch
    actually used; with warmup/hold disabled the KF toggles mid-run.
    """
    cfg = NoCConfig(mode="kf", n_epochs=15, epoch_len=300, seed=1,
                    policy=PolicyConfig(warmup=0, hold=0, revert=10**9))
    res = sim.simulate(cfg, PROFILES["BFS"])
    conf = np.asarray(res.applied_config)
    quota = np.asarray(res.gpu_vc_quota)
    assert (np.diff(conf) != 0).any(), "scenario no longer toggles the KF"
    # kf-mode partitions: config 0 -> GPU {0,1} (2 VCs), config 1 -> 3 VCs
    used_config = np.concatenate([[0], conf[:-1]])
    np.testing.assert_array_equal(quota, np.where(used_config > 0, 3, 2))


# ---------------------------------------------------------------------------
# 3. ref <-> Pallas congruence (interpret mode off-TPU)
# ---------------------------------------------------------------------------

def _random_arbitrate_inputs(rng, lead, P=N_PORTS, V=4, B=4):
    PV = P * V
    gm = jnp.asarray(rng.random(lead[:-1] + (1, V)) < 0.7)
    cm = jnp.asarray(rng.random(lead[:-1] + (1, V)) < 0.7)
    return dict(
        valid=jnp.asarray(rng.random(lead + (PV,)) < 0.5),
        cls=jnp.asarray(rng.integers(0, 2, lead + (PV,)), jnp.int32),
        out_port=jnp.asarray(rng.integers(0, P, lead + (PV,)), jnp.int32),
        rr_ptr=jnp.asarray(rng.integers(0, PV, lead + (P,)), jnp.int32),
        down_count=jnp.asarray(
            rng.integers(0, B + 1, lead + (P, V)), jnp.int32
        ),
        down_exists=jnp.asarray(rng.random(lead + (P,)) < 0.8),
        gpu_vc_mask=jnp.broadcast_to(gm, lead + (V,)),
        cpu_vc_mask=jnp.broadcast_to(cm, lead + (V,)),
        sa_pref=jnp.asarray(rng.integers(-1, 2, lead), jnp.int32),
        accept=jnp.asarray(rng.random(lead) < 0.7),
        active=jnp.asarray(rng.random(lead) < 0.9),
    )


def test_noc_cycle_kernel_matches_ref_on_random_states():
    """Every `Arbitration` output agrees exactly — including the ragged
    lane tail (S*R = 144 pads up to the 256-lane grid)."""
    from repro.kernels.noc_cycle.ops import arbitrate_lanes
    from repro.kernels.noc_cycle.ref import noc_cycle_ref

    rng = np.random.default_rng(3)
    for lead in [(4, 36), (2, 36), (1, 7)]:
        inp = _random_arbitrate_inputs(rng, lead)
        ref = noc_cycle_ref(**inp, depth=4)
        ker = arbitrate_lanes(**inp, depth=4)
        for name, a, b in zip(ref._fields, ref, ker):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"lead={lead}: arbitration output {name}",
            )


def test_noc_cycle_kernel_matches_ref_on_full_router_cycle():
    """A whole `router_cycle` step (peek -> arbitrate -> dequeue/traverse)
    agrees exactly between the ref and Pallas arbitration backends."""
    from repro.kernels.noc_cycle.ops import arbitrate_lanes

    rng = np.random.default_rng(11)
    topo = make_topology()
    route_t, nb_t, opp_t, ntype, _ = rt.device_tables(topo)
    S, V = 4, 4
    state = _random_subnet_state(rng)
    gmask = jnp.asarray(rng.random((S, V)) < 0.7)
    cmask = jnp.asarray(rng.random((S, V)) < 0.7)
    sa = jnp.int32(1)
    accept = jnp.asarray(rng.random((S, topo.n_routers)) < 0.8)
    active = jnp.asarray([True, True, False, True])

    ref_state, ref_ev = rt.router_cycle(
        state, route_t, nb_t, opp_t, gmask, cmask, sa, accept, active
    )
    pal_state, pal_ev = rt.router_cycle(
        state, route_t, nb_t, opp_t, gmask, cmask, sa, accept, active,
        arbitrate_fn=arbitrate_lanes,
    )
    _states_equal(ref_state, pal_state)
    for name, a, b in zip(ref_ev._fields, ref_ev, pal_ev):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"event {name}"
        )


def test_simulate_pallas_backend_runs_fig2_3_smoke():
    """`simulate(..., backend="pallas")` runs a Fig. 2/3 grid point end to
    end and reproduces the default backend bit-for-bit (the backend is its
    own `SimStatic`, so this never disturbs the paper sweep's single
    compiled program)."""
    tiny = dict(n_epochs=2, epoch_len=40)
    cfg = NoCConfig(mode="static", static_gpu_vcs=3, **tiny)
    ref = sim.simulate(cfg, PROFILES["PATH"])
    pal = sim.simulate(cfg, PROFILES["PATH"], backend="pallas")
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(ref),
        jax.tree_util.tree_leaves_with_path(pal),
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"leaf {jax.tree_util.keystr(path)}",
        )


def test_unknown_backend_rejected():
    cfg = NoCConfig(mode="baseline", n_epochs=1, epoch_len=10)
    with pytest.raises(ValueError, match="backend"):
        sim.simulate(cfg, PROFILES["PATH"], backend="cuda")
