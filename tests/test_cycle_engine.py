"""Cycle-engine equivalence suite (DESIGN.md §11).

The packed-lane engine rewrote the hot loop under a bitwise contract: every
rewrite (epoch-hoisted masks, precomputed RNG streams, the merged inject,
packed narrow-dtype state, the scatter-free dense writes, the Pallas
arbitration kernel) must leave all observable outputs exactly as the PR-3
engine produced them.  Three layers of pinning:

  1. golden outputs — `tests/golden_cycle_engine.json` was captured from the
     PR-3 padded program; the new engine must reproduce it bit-for-bit;
  2. rewrite micro-tests — each equivalence-preserving rewrite is checked
     directly against the formulation it replaced;
  3. ref <-> Pallas congruence — `kernels.noc_cycle` (interpret mode off
     TPU) must agree with `router.arbitrate` exactly, from a single
     arbitration step up to a whole `simulate(backend="pallas")` run.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import PolicyConfig
from repro.core.noc import router as rt
from repro.core.noc import sim
from repro.core.noc.sim import NoCConfig
from repro.core.noc.topology import N_PORTS, make_topology
from repro.core.noc.traffic import PROFILES

FAST = dict(n_epochs=8, epoch_len=100)
GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden_cycle_engine.json"
)


# ---------------------------------------------------------------------------
# 1. golden pinning vs the PR-3 engine
# ---------------------------------------------------------------------------

def test_outputs_match_pr3_golden_capture():
    """Counters/config/latency match the pre-rewrite padded program exactly.

    The golden file was captured from the PR-3 engine (per-cycle RNG
    splits, separate injects, int32 scatter state) before this refactor
    landed; equality here proves the whole rewrite chain is value-preserving,
    not just self-consistent.
    """
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for key, g in golden.items():
        mode, wl, gs, ss = key.split("/")
        cfg = NoCConfig(mode=mode, static_gpu_vcs=int(gs[1:]),
                        seed=int(ss[1:]), **FAST)
        res = sim.simulate(cfg, PROFILES[wl])
        sums = {n: int(np.sum(np.asarray(leaf)))
                for n, leaf in zip(res.counters._fields, res.counters)}
        assert sums == g["counter_sums"], f"{key}: counter drift"
        assert np.asarray(res.applied_config).tolist() == g["applied_config"]
        assert np.asarray(res.kf_signal).tolist() == g["kf_signal"]
        np.testing.assert_allclose(
            float(np.asarray(res.avg_latency)[-1]), g["avg_latency_last"],
            rtol=0, atol=1e-6, err_msg=key,
        )


# ---------------------------------------------------------------------------
# 2. rewrite micro-tests
# ---------------------------------------------------------------------------

def test_batched_rng_streams_match_per_cycle_splits():
    """The per-epoch vmapped RNG precompute == the old per-cycle splits."""
    epoch_key = jax.random.PRNGKey(42)
    ep_len, R, n_mc = 37, 36, 8
    keys = jax.random.split(epoch_key, ep_len)

    # old engine: draw inside the loop, one cycle at a time
    u_ph_ref, u_gen_ref, d_ref = [], [], []
    for i in range(ep_len):
        k_phase, k_gen, k_dest = jax.random.split(keys[i], 3)
        u_ph_ref.append(jax.random.uniform(k_phase, ()))
        u_gen_ref.append(jax.random.uniform(k_gen, (R,), jnp.float32))
        d_ref.append(jax.random.randint(k_dest, (R,), 0, n_mc))

    # new engine: one batched draw per epoch (sim.epoch_body's precompute)
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
    u_phase = jax.vmap(lambda k: jax.random.uniform(k, ()))(k3[:, 0])
    u_gen = jax.vmap(
        lambda k: jax.random.uniform(k, (R,), jnp.float32)
    )(k3[:, 1])
    d_idx = jax.vmap(
        lambda k: jax.random.randint(k, (R,), 0, n_mc)
    )(k3[:, 2])

    np.testing.assert_array_equal(np.asarray(u_phase), np.stack(u_ph_ref))
    np.testing.assert_array_equal(np.asarray(u_gen), np.stack(u_gen_ref))
    np.testing.assert_array_equal(np.asarray(d_idx), np.stack(d_ref))


def _random_subnet_state(rng, S=4, R=36, P=N_PORTS, V=4, B=4):
    dest = rng.integers(0, R, (S, R, P, V, B))
    src = rng.integers(0, R, (S, R, P, V, B))
    cls = rng.integers(0, 2, (S, R, P, V, B))
    return rt.SubnetState(
        buf_meta=jnp.asarray(
            dest + (src << rt.META_SRC_SHIFT) + (cls << rt.META_CLS_SHIFT),
            jnp.int16,
        ),
        buf_binj=jnp.asarray(
            rng.integers(0, 5000, (S, R, P, V, B)), jnp.uint16
        ),
        head=jnp.asarray(rng.integers(0, B, (S, R, P, V)), jnp.int8),
        count=jnp.asarray(rng.integers(0, B + 1, (S, R, P, V)), jnp.int8),
        rr_ptr=jnp.asarray(rng.integers(0, P * V, (S, R, P)), jnp.int8),
    )


def _states_equal(a, b):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"state leaf {name}"
        )


def test_merged_inject_equals_separate_injects():
    """One inject over the union want-matrix == two per-kind injects.

    The cycle engine fuses the MC-reply and source injections into one
    `inject_all` pass; they target disjoint subnet rows, so the merged call
    must be exactly the composition of the separate ones.
    """
    rng = np.random.default_rng(7)
    S, R, V = 4, 36, 4
    state = _random_subnet_state(rng)
    sub_is_req = jnp.asarray([True, False, True, False])

    want_src = jnp.asarray(rng.random((S, R)) < 0.5) & sub_is_req[:, None]
    want_rep = jnp.asarray(rng.random((S, R)) < 0.5) & ~sub_is_req[:, None]
    dest = jnp.asarray(rng.integers(0, R, (S, R)), jnp.int32)
    src = jnp.asarray(rng.integers(0, R, (S, R)), jnp.int32)
    cls = jnp.asarray(rng.integers(0, 2, (S, R)), jnp.int32)
    binj = jnp.asarray(rng.integers(0, 5000, (S, R)), jnp.int32)
    gmask = jnp.asarray(rng.random((S, V)) < 0.7)
    cmask = jnp.asarray(rng.random((S, V)) < 0.7)

    merged, ok_m = rt.inject_all(
        state, want_src | want_rep, dest, src, cls, binj, gmask, cmask
    )
    step1, ok_rep = rt.inject_all(
        state, want_rep, dest, src, cls, binj, gmask, cmask
    )
    sep, ok_src = rt.inject_all(
        step1, want_src, dest, src, cls, binj, gmask, cmask
    )
    _states_equal(merged, sep)
    np.testing.assert_array_equal(np.asarray(ok_m), np.asarray(ok_rep | ok_src))


def test_packed_state_roundtrips_and_wrap_exact_latency():
    """Packed vs int32 state: every field a packet can carry survives the
    int16 meta pack exactly, and the uint16 injection stamps give the same
    latency as int32 arithmetic for every age the engine can produce."""
    R = make_topology().n_routers
    dest, src, cls = np.meshgrid(
        np.arange(R), np.arange(R), np.arange(2), indexing="ij"
    )
    d, s, c = (jnp.asarray(x.ravel(), jnp.int32) for x in (dest, src, cls))
    meta = rt.pack_meta(d, s, c)
    assert meta.dtype == jnp.int16
    d2, s2, c2 = rt.unpack_meta(meta)
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(c))

    # wraparound-exact uint16 age: (cycle - binj) mod 2^16 == true age
    total = 60_001  # default paper run: 120 epochs x 500 cycles (+1 stamp)
    binj = jnp.asarray([0, 1, 40_000, 60_000, 65_000], jnp.uint16)
    cycle = jnp.int32(total - 1)
    age16 = (cycle.astype(jnp.uint16) - binj).astype(jnp.int32)
    true_age = cycle - jnp.asarray([0, 1, 40_000, 60_000, 65_000], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(age16)[true_age >= 0], np.asarray(true_age)[true_age >= 0]
    )


def test_policy_boundary_masks_flip_exactly_one_epoch_after_config():
    """Guard the epoch-level mask hoisting against an off-by-one epoch.

    `apply_policy_gated` runs at the END of epoch e, so the masks applied
    DURING epoch e must reflect `applied_config[e-1]` — never `[e]` (that
    would mean the hoist reads the config too early) and never `[e-2]`
    (stale by one).  `gpu_vc_quota` reports the hoisted mask the epoch
    actually used; with warmup/hold disabled the KF toggles mid-run.
    """
    cfg = NoCConfig(mode="kf", n_epochs=15, epoch_len=300, seed=1,
                    policy=PolicyConfig(warmup=0, hold=0, revert=10**9))
    res = sim.simulate(cfg, PROFILES["BFS"])
    conf = np.asarray(res.applied_config)
    quota = np.asarray(res.gpu_vc_quota)
    assert (np.diff(conf) != 0).any(), "scenario no longer toggles the KF"
    # kf-mode partitions: config 0 -> GPU {0,1} (2 VCs), config 1 -> 3 VCs
    used_config = np.concatenate([[0], conf[:-1]])
    np.testing.assert_array_equal(quota, np.where(used_config > 0, 3, 2))


# ---------------------------------------------------------------------------
# 3. ref <-> Pallas congruence (interpret mode off-TPU)
# ---------------------------------------------------------------------------

def _random_arbitrate_inputs(rng, lead, P=N_PORTS, V=4, B=4):
    PV = P * V
    gm = jnp.asarray(rng.random(lead[:-1] + (1, V)) < 0.7)
    cm = jnp.asarray(rng.random(lead[:-1] + (1, V)) < 0.7)
    return dict(
        valid=jnp.asarray(rng.random(lead + (PV,)) < 0.5),
        cls=jnp.asarray(rng.integers(0, 2, lead + (PV,)), jnp.int32),
        out_port=jnp.asarray(rng.integers(0, P, lead + (PV,)), jnp.int32),
        rr_ptr=jnp.asarray(rng.integers(0, PV, lead + (P,)), jnp.int32),
        down_count=jnp.asarray(
            rng.integers(0, B + 1, lead + (P, V)), jnp.int32
        ),
        down_exists=jnp.asarray(rng.random(lead + (P,)) < 0.8),
        gpu_vc_mask=jnp.broadcast_to(gm, lead + (V,)),
        cpu_vc_mask=jnp.broadcast_to(cm, lead + (V,)),
        sa_pref=jnp.asarray(rng.integers(-1, 2, lead), jnp.int32),
        accept=jnp.asarray(rng.random(lead) < 0.7),
        active=jnp.asarray(rng.random(lead) < 0.9),
    )


def test_noc_cycle_kernel_matches_ref_on_random_states():
    """Every `Arbitration` output agrees exactly — including the ragged
    lane tail (S*R = 144 pads up to the 256-lane grid)."""
    from repro.kernels.noc_cycle.ops import arbitrate_lanes
    from repro.kernels.noc_cycle.ref import noc_cycle_ref

    rng = np.random.default_rng(3)
    for lead in [(4, 36), (2, 36), (1, 7)]:
        inp = _random_arbitrate_inputs(rng, lead)
        ref = noc_cycle_ref(**inp, depth=4)
        ker = arbitrate_lanes(**inp, depth=4)
        for name, a, b in zip(ref._fields, ref, ker):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"lead={lead}: arbitration output {name}",
            )


def test_noc_cycle_kernel_matches_ref_on_full_router_cycle():
    """A whole `router_cycle` step (peek -> arbitrate -> dequeue/traverse)
    agrees exactly between the ref and Pallas arbitration backends."""
    from repro.kernels.noc_cycle.ops import arbitrate_lanes

    rng = np.random.default_rng(11)
    topo = make_topology()
    route_t, nb_t, opp_t, ntype, _ = rt.device_tables(topo)
    S, V = 4, 4
    state = _random_subnet_state(rng)
    gmask = jnp.asarray(rng.random((S, V)) < 0.7)
    cmask = jnp.asarray(rng.random((S, V)) < 0.7)
    sa = jnp.int32(1)
    accept = jnp.asarray(rng.random((S, topo.n_routers)) < 0.8)
    active = jnp.asarray([True, True, False, True])

    ref_state, ref_ev = rt.router_cycle(
        state, route_t, nb_t, opp_t, gmask, cmask, sa, accept, active
    )
    pal_state, pal_ev = rt.router_cycle(
        state, route_t, nb_t, opp_t, gmask, cmask, sa, accept, active,
        arbitrate_fn=arbitrate_lanes,
    )
    _states_equal(ref_state, pal_state)
    for name, a, b in zip(ref_ev._fields, ref_ev, pal_ev):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"event {name}"
        )


def test_simulate_pallas_backend_runs_fig2_3_smoke():
    """`simulate(..., backend="pallas")` runs a Fig. 2/3 grid point end to
    end and reproduces the default backend bit-for-bit (the backend is its
    own `SimStatic`, so this never disturbs the paper sweep's single
    compiled program)."""
    tiny = dict(n_epochs=2, epoch_len=40)
    cfg = NoCConfig(mode="static", static_gpu_vcs=3, **tiny)
    ref = sim.simulate(cfg, PROFILES["PATH"])
    pal = sim.simulate(cfg, PROFILES["PATH"], backend="pallas")
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(ref),
        jax.tree_util.tree_leaves_with_path(pal),
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"leaf {jax.tree_util.keystr(path)}",
        )


def test_simulate_pallas_arb_backend_matches_ref():
    """`backend="pallas_arb"` (dense body + arbitration lane kernel — the
    pre-fusion Pallas path) still reproduces the ref engine bit-for-bit,
    and each backend compiles exactly one program (its own `SimStatic`)."""
    tiny = dict(n_epochs=2, epoch_len=40)
    cfg = NoCConfig(mode="static", static_gpu_vcs=3, **tiny)
    sim.reset_trace_count()
    ref = sim.simulate(cfg, PROFILES["PATH"])
    pal = sim.simulate(cfg, PROFILES["PATH"], backend="pallas_arb")
    # at most one trace per backend (jit cache hits from earlier tests on
    # the same SimStatic may make it fewer, never more)
    assert sim.trace_count() <= 2
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(ref),
        jax.tree_util.tree_leaves_with_path(pal),
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"leaf {jax.tree_util.keystr(path)}",
        )


def test_unknown_backend_rejected():
    cfg = NoCConfig(mode="baseline", n_epochs=1, epoch_len=10)
    with pytest.raises(ValueError, match="backend"):
        sim.simulate(cfg, PROFILES["PATH"], backend="cuda")


# ---------------------------------------------------------------------------
# 4. fused full-cycle kernel (DESIGN.md §13): golden pinning + stage twins
# ---------------------------------------------------------------------------

def _lane_dims(S=4, V=4, B=4):
    from repro.kernels.noc_cycle import fused

    return fused.lane_dims(
        S=S, R=36, V=V, B=B, Q=16, width=6, mc_service_period=2,
        mshr_limit=16, bcap=64, stamp_mask=0xFFFF,
    )


def _sv_mask_rows(x):
    """Per-subnet (S, V) bool masks -> (V, S*64) int32 lane rows (more
    general than the engine's own subnet-uniform masks — the stage twins
    must honor per-lane variation)."""
    from repro.kernels.noc_cycle import fused

    S, V = x.shape
    return jnp.concatenate(
        [
            jnp.broadcast_to(x[s].astype(jnp.int32)[:, None], (V, fused.R_PAD))
            for s in range(S)
        ],
        axis=1,
    )


def _sr_row(x, R=36):
    """(S, R) -> (1, S*64) int32 lane row."""
    from repro.kernels.noc_cycle import fused

    x = jnp.pad(x.astype(jnp.int32), ((0, 0), (0, fused.R_PAD - R)))
    return x.reshape(1, -1)


def test_fused_backend_matches_golden_capture():
    """The fused kernel runs the golden grid (static/baseline/4subnet/kf x
    workloads) bitwise-identical to the PR-3 capture — the engine-level
    acceptance gate for `backend="pallas"`."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for key, g in golden.items():
        mode, wl, gs, ss = key.split("/")
        cfg = NoCConfig(mode=mode, static_gpu_vcs=int(gs[1:]),
                        seed=int(ss[1:]), **FAST)
        res = sim.simulate(cfg, PROFILES[wl], backend="pallas")
        sums = {n: int(np.sum(np.asarray(leaf)))
                for n, leaf in zip(res.counters._fields, res.counters)}
        assert sums == g["counter_sums"], f"{key}: fused counter drift"
        assert np.asarray(res.applied_config).tolist() == g["applied_config"]
        assert np.asarray(res.kf_signal).tolist() == g["kf_signal"]
        np.testing.assert_allclose(
            float(np.asarray(res.avg_latency)[-1]), g["avg_latency_last"],
            rtol=0, atol=1e-6, err_msg=key,
        )


def test_fused_pack_unpack_roundtrip():
    """Lane pack -> unpack is the identity on every carry leaf (localizes
    layout/transpose bugs away from the stage math)."""
    from repro.kernels.noc_cycle import fused

    rng = np.random.default_rng(5)
    d = _lane_dims()
    R, Q = 36, 16
    subs = _random_subnet_state(rng)
    mc = sim.MCState(
        q_meta=jnp.asarray(rng.integers(0, 100, (R, Q)), jnp.int8),
        head=jnp.asarray(rng.integers(0, Q, (R,)), jnp.int32),
        count=jnp.asarray(rng.integers(0, Q + 1, (R,)), jnp.int32),
        timer=jnp.asarray(rng.integers(0, 3, (R,)), jnp.int32),
        stage_valid=jnp.asarray(rng.random((R,)) < 0.5),
        stage_dst=jnp.asarray(rng.integers(0, R, (R,)), jnp.int32),
        stage_cls=jnp.asarray(rng.integers(0, 2, (R,)), jnp.int32),
    )
    outst = jnp.asarray(rng.integers(0, 16, (R,)), jnp.int32)
    backlog = jnp.asarray(rng.integers(0, 64, (R,)), jnp.int32)
    phase = jnp.int32(1)

    ls = fused.pack_state(d, subs, mc, outst, backlog, phase)
    subs2, mc2, outst2, backlog2, phase2 = fused.unpack_state(
        d, ls, sim.MCState, subs.buf_binj.dtype
    )
    _states_equal(subs, subs2)
    _states_equal(mc, mc2)
    np.testing.assert_array_equal(np.asarray(outst), np.asarray(outst2))
    np.testing.assert_array_equal(np.asarray(backlog), np.asarray(backlog2))
    assert int(phase2) == int(phase)


def test_fused_inject_stage_matches_inject_all():
    """`fused.inject_lanes` == `router.inject_all` on random states with
    per-subnet VC masks: buffer writes, counts, and the ok row."""
    from repro.kernels.noc_cycle import fused

    rng = np.random.default_rng(13)
    d = _lane_dims()
    S, R, V = 4, 36, 4
    subs = _random_subnet_state(rng)
    want = jnp.asarray(rng.random((S, R)) < 0.6)
    dest = jnp.asarray(rng.integers(0, R, (S, R)), jnp.int32)
    src = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32), (S, R))
    cls = jnp.asarray(rng.integers(0, 2, (S, R)), jnp.int32)
    binj = jnp.asarray(rng.integers(0, 5000, (S, R)), jnp.int32)
    gmask = jnp.asarray(rng.random((S, V)) < 0.7)
    cmask = jnp.asarray(rng.random((S, V)) < 0.7)

    ref_state, ref_ok = rt.inject_all(
        subs, want, dest, src, cls, binj, gmask, cmask
    )

    ls = fused.pack_state(
        d, subs,
        sim.MCState(*[jnp.zeros((R, 16), jnp.int8)]
                    + [jnp.zeros((R,), jnp.int32)] * 3
                    + [jnp.zeros((R,), bool)]
                    + [jnp.zeros((R,), jnp.int32)] * 2),
        jnp.zeros((R,), jnp.int32), jnp.zeros((R,), jnp.int32), jnp.int32(0),
    )
    src_lane = jax.lax.broadcasted_iota(jnp.int32, (1, d.lanes_sr), 1) % 64
    bm, bb, ct, ok = fused.inject_lanes(
        d, ls.buf_meta, ls.buf_binj, ls.head, ls.count,
        _sr_row(want) != 0, _sr_row(dest), src_lane, _sr_row(cls),
        _sr_row(binj), _sv_mask_rows(gmask) != 0, _sv_mask_rows(cmask) != 0,
    )
    lane_state, *_ = fused.unpack_state(
        d, ls._replace(buf_meta=bm, buf_binj=bb, count=ct),
        sim.MCState, subs.buf_binj.dtype,
    )
    _states_equal(ref_state, lane_state)
    ok_sr = np.asarray(ok).reshape(S, 64)[:, :R]
    np.testing.assert_array_equal(ok_sr, np.asarray(ref_ok))


def test_fused_mc_service_stage_matches_dense():
    """`fused.mc_service_lanes` == the dense cycle_body MC-service stage
    (timers, queue-head unpack, ring advance, staging)."""
    from repro.kernels.noc_cycle import fused

    rng = np.random.default_rng(17)
    d = _lane_dims()
    topo = make_topology()
    R, Q, period = topo.n_routers, 16, 2
    ntype = jnp.asarray(topo.node_type)
    is_mc = ntype == 2
    mc = sim.MCState(
        q_meta=jnp.asarray(rng.integers(0, 100, (R, Q)), jnp.int8),
        head=jnp.asarray(rng.integers(0, Q, (R,)), jnp.int32),
        count=jnp.asarray(rng.integers(0, Q + 1, (R,)), jnp.int32),
        timer=jnp.asarray(rng.integers(0, 3, (R,)), jnp.int32),
        stage_valid=jnp.asarray(rng.random((R,)) < 0.3),
        stage_dst=jnp.asarray(rng.integers(0, R, (R,)), jnp.int32),
        stage_cls=jnp.asarray(rng.integers(0, 2, (R,)), jnp.int32),
    )

    # dense twin: cycle_body stage 1 verbatim
    can_serve = is_mc & (mc.count > 0) & ~mc.stage_valid
    timer = jnp.where(can_serve, jnp.maximum(mc.timer - 1, 0), mc.timer)
    done = can_serve & (timer == 0)
    q_head = jnp.take_along_axis(
        mc.q_meta, mc.head[:, None], axis=1
    )[:, 0].astype(jnp.int32)
    src_out = q_head & ((1 << rt.META_SRC_SHIFT) - 1)
    cls_out = q_head >> rt.META_SRC_SHIFT
    ref = sim.MCState(
        q_meta=mc.q_meta,
        head=jnp.where(done, (mc.head + 1) % Q, mc.head),
        count=mc.count - done.astype(jnp.int32),
        timer=jnp.where(done, period, timer),
        stage_valid=mc.stage_valid | done,
        stage_dst=jnp.where(done, src_out, mc.stage_dst),
        stage_cls=jnp.where(done, cls_out, mc.stage_cls),
    )

    ls = fused.pack_state(
        d, _random_subnet_state(rng), mc,
        jnp.zeros((R,), jnp.int32), jnp.zeros((R,), jnp.int32), jnp.int32(0),
    )
    ntype_row = jnp.pad(ntype, (0, 128 - R), constant_values=-1)[None, :]
    head, count, timer_l, svalid, sdst, scls = fused.mc_service_lanes(
        d, ls.mc, ls.mcq, ntype_row
    )
    for name, ref_v, lane_row in [
        ("head", ref.head, head), ("count", ref.count, count),
        ("timer", ref.timer, timer_l),
        ("stage_valid", ref.stage_valid, svalid),
        ("stage_dst", ref.stage_dst, sdst),
        ("stage_cls", ref.stage_cls, scls),
    ]:
        np.testing.assert_array_equal(
            np.asarray(ref_v).astype(np.int32),
            np.asarray(lane_row)[0, :R].astype(np.int32),
            err_msg=f"mc service field {name}",
        )


def test_fused_router_stage_matches_router_cycle():
    """`fused.router_stage_lanes` == `router.router_cycle` on random states:
    buffer dequeue/enqueue writes, RR pointers, and every event field
    (including the garbage-site convention on eject_src/cls/binj)."""
    from repro.kernels.noc_cycle import fused

    rng = np.random.default_rng(23)
    d = _lane_dims()
    topo = make_topology()
    R = topo.n_routers
    route_t, nb_t, opp_t, _, _ = rt.device_tables(topo)
    S, V = 4, 4
    subs = _random_subnet_state(rng)
    gmask = jnp.asarray(rng.random((S, V)) < 0.7)
    cmask = jnp.asarray(rng.random((S, V)) < 0.7)
    sa = jnp.int32(1)
    accept = jnp.asarray(rng.random((S, R)) < 0.8)
    active = jnp.asarray([True, True, False, True])

    ref_state, ref_ev = rt.router_cycle(
        subs, route_t, nb_t, opp_t, gmask, cmask, sa, accept, active
    )

    ls = fused.pack_state(
        d, subs,
        sim.MCState(*[jnp.zeros((R, 16), jnp.int8)]
                    + [jnp.zeros((R,), jnp.int32)] * 3
                    + [jnp.zeros((R,), bool)]
                    + [jnp.zeros((R,), jnp.int32)] * 2),
        jnp.zeros((R,), jnp.int32), jnp.zeros((R,), jnp.int32), jnp.int32(0),
    )
    route_rows, exists_rows, _ = fused.run_consts(d, topo)
    active_rows = jnp.repeat(active.astype(jnp.int32), fused.R_PAD)[None, :]
    sa_row = jnp.full((1, d.lanes_sr), sa, jnp.int32)
    (bm, bb, hd, ct, rr2, ej, e_src, e_cls, e_binj, moved, dram_gpu,
     grant_cnt, deny_cnt,
     ) = fused.router_stage_lanes(
        d, ls.buf_meta, ls.buf_binj, ls.head, ls.count, ls.rr,
        _sv_mask_rows(gmask) != 0, _sv_mask_rows(cmask) != 0,
        sa_row, _sr_row(accept) != 0, active_rows != 0,
        route_rows, exists_rows != 0,
    )
    lane_state, *_ = fused.unpack_state(
        d, ls._replace(buf_meta=bm, buf_binj=bb, head=hd, count=ct, rr=rr2),
        sim.MCState, subs.buf_binj.dtype,
    )
    _states_equal(ref_state, lane_state)

    def sr(row):
        return np.asarray(row).reshape(S, 64)[:, :R]

    np.testing.assert_array_equal(sr(ej), np.asarray(ref_ev.eject_valid))
    np.testing.assert_array_equal(sr(e_src), np.asarray(ref_ev.eject_src))
    np.testing.assert_array_equal(sr(e_cls), np.asarray(ref_ev.eject_cls))
    np.testing.assert_array_equal(
        sr(e_binj), np.asarray(ref_ev.eject_binj).astype(np.int32)
    )
    assert int(moved) == int(ref_ev.moved)
    assert int(dram_gpu) == int(ref_ev.dram_block_gpu)
    # probe rows (DESIGN.md §14): the lane twin of CycleEvents.grant_cnt
    # and deny_cnt must agree even when probes are off (they feed the
    # flight recorder only when ProbeConfig.enabled compiles them in)
    np.testing.assert_array_equal(sr(grant_cnt), np.asarray(ref_ev.grant_cnt))
    np.testing.assert_array_equal(sr(deny_cnt), np.asarray(ref_ev.deny_cnt))


def test_fused_single_cycle_counters_match_ref():
    """One-cycle runs pin the counter-update stage: every EpochCounters
    lane agrees with the dense engine after exactly one simulated cycle
    (and after three, covering the carry add)."""
    for mode, ep_len in [("kf", 1), ("4subnet", 1), ("kf", 3)]:
        cfg = NoCConfig(mode=mode, n_epochs=1, epoch_len=ep_len, seed=3)
        ref = sim.simulate(cfg, PROFILES["BFS"])
        pal = sim.simulate(cfg, PROFILES["BFS"], backend="pallas")
        for name, a, b in zip(
            ref.counters._fields, ref.counters, pal.counters
        ):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{mode}/L{ep_len}: counter {name}",
            )
