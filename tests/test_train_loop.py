"""Training loop fault tolerance: crash/restart bit-exactness, KF scheduler
dispatch, loss-goes-down, comm-priority variant equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data import synthetic
from repro.dist.kf_scheduler import KFScheduler, SchedulerConfig
from repro.dist.telemetry import StaticCosts, Telemetry
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

ARCH = "llama3.2-3b"


def _setup(total_steps=30, seed=0):
    cfg = configs.smoke(ARCH)
    opt_cfg = opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=5,
                                      total_steps=total_steps)
    state, _ = step_lib.init_train_state(jax.random.PRNGKey(seed), cfg,
                                         opt_cfg)
    ds = synthetic.make_dataset(cfg, seq_len=32, global_batch=2, seed=seed)
    step = jax.jit(step_lib.make_train_step(cfg, opt_cfg))
    return cfg, state, {0: step}, ds


def test_loss_decreases():
    _, state, steps, ds = _setup(total_steps=40)
    res = loop_lib.run(loop_lib.LoopConfig(total_steps=40, log_every=0),
                       state, steps, ds.batch, log=lambda s: None)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


def test_crash_restart_is_bit_identical(tmp_path):
    """Run A: 0..30 uninterrupted.  Run B: crash at 18, restart from the
    step-15 checkpoint, continue to 30.  Loss traces must agree exactly
    from the restore point (same data stream, same state)."""
    cfgdir = str(tmp_path / "ck")
    _, state, steps, ds = _setup()
    full = loop_lib.run(
        loop_lib.LoopConfig(total_steps=30, log_every=0),
        state, steps, ds.batch, log=lambda s: None)

    _, state_b, steps_b, ds_b = _setup()
    with pytest.raises(loop_lib.SimulatedFailure):
        loop_lib.run(
            loop_lib.LoopConfig(total_steps=30, ckpt_dir=cfgdir,
                                ckpt_every=15, log_every=0),
            state_b, steps_b, ds_b.batch, fail_at=18, log=lambda s: None)
    _, state_c, steps_c, ds_c = _setup()
    resumed = loop_lib.run(
        loop_lib.LoopConfig(total_steps=30, ckpt_dir=cfgdir,
                            ckpt_every=15, log_every=0),
        state_c, steps_c, ds_c.batch, log=lambda s: None)
    assert resumed.restored_from == 15
    np.testing.assert_allclose(resumed.losses, full.losses[15:], rtol=1e-5)


def test_kf_scheduler_switches_variants():
    cfg, state, steps, ds = _setup(total_steps=60)
    steps[1] = steps[0]  # same executable; dispatch path is what's tested
    telemetry = Telemetry(costs_by_variant={
        0: StaticCosts(flops=0, hbm_bytes=20e9, collective_bytes=2e9),
        1: StaticCosts(flops=0, hbm_bytes=20e9, collective_bytes=5e8),
    }, comm_scale=1e9)
    sched = KFScheduler(SchedulerConfig(
        epoch_steps=5, warmup_steps=10, hold_steps=5, revert_steps=1000),
        telemetry)
    res = loop_lib.run(loop_lib.LoopConfig(total_steps=60, log_every=0),
                       state, steps, ds.batch, sched, log=lambda s: None)
    # pressure is high (hbm 20/16GB) -> KF must engage the boost
    assert 1 in res.variants
    # and hysteresis: no flapping every epoch
    flips = sum(1 for a, b in zip(res.variants, res.variants[1:]) if a != b)
    assert flips <= 6


def test_comm_priority_singlepod_matches_balanced():
    """Microbatched grad accumulation == single-batch gradients (same
    update within fp tolerance)."""
    cfg = configs.smoke(ARCH)
    opt_cfg = opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=1,
                                      total_steps=10)
    state, _ = step_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    ds = synthetic.make_dataset(cfg, seq_len=32, global_batch=4)
    batch = ds.batch(0)
    s0 = jax.jit(step_lib.make_train_step(cfg, opt_cfg, variant=0))
    s1 = jax.jit(step_lib.make_train_step(cfg, opt_cfg, variant=1))
    new0, m0 = s0(state, batch)
    new1, m1 = s1(state, batch)
    # losses computed identically (mean over same tokens)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=2e-2)
    # parameters land in the same place (accumulated grads == full grads;
    # bf16 params -> loose tolerance)
    d0 = jax.tree.leaves(new0.params)[0].astype(jnp.float32)
    d1 = jax.tree.leaves(new1.params)[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               atol=2e-2, rtol=2e-2)


def test_straggler_detection():
    import time

    _, state, steps, ds = _setup(total_steps=12)
    calls = {"n": 0}
    inner = steps[0]

    def slow_step(s, b):
        calls["n"] += 1
        out = inner(s, b)
        jax.block_until_ready(out[1]["loss"])
        if calls["n"] == 9:
            time.sleep(1.0)  # inject a straggler
        return out

    res = loop_lib.run(
        loop_lib.LoopConfig(total_steps=12, log_every=0,
                            straggler_factor=2.5),
        state, {0: slow_step}, ds.batch, log=lambda s: None)
    assert res.straggler_events >= 1
