"""Batched sweep engine: equivalence with per-config runs + compile budget.

The contract of `sim.simulate_batch` / `sim.sweep` (DESIGN.md §4, §10):

  1. a batch row is bit-for-bit the same simulation as a standalone
     `simulate()` with the same config/workload/seed;
  2. the S/V-padded shared program is bit-for-bit the mode's dedicated
     (unpadded) trace — padding must be invisible in every counter;
  3. the whole paper evaluation (Fig 2/3 + Fig 9/10/11 + Fig 12) costs
     exactly ONE trace of the simulator — 4-subnet included;
  4. `sweep_sharded` returns `sweep`'s rows exactly, including on a ragged
     (non-divisible) point count.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.noc import sim
from repro.core.noc.sim import NoCConfig, SweepSpec
from repro.core.noc.traffic import PROFILES

FAST = dict(n_epochs=8, epoch_len=100)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _assert_rows_equal(row, ref, label):
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(row),
        jax.tree_util.tree_leaves_with_path(ref),
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6,
            err_msg=f"{label}: leaf {jax.tree_util.keystr(path)}",
        )


SPECS = [
    SweepSpec(mode, wl, seed=seed)
    for mode in ("baseline", "fair", "kf", "4subnet")
    for wl in ("PATH", "BFS")
    for seed in (0, 3)
] + [
    SweepSpec("static", wl, static_gpu_vcs=g, seed=1)
    for wl in ("PATH", "BFS")
    for g in (1, 2, 3)
]


def test_sweep_rows_match_per_config_simulate():
    """Every mode/workload/ratio/seed: batch row == standalone simulate."""
    rows = sim.sweep(SPECS, batch_tile=4, **FAST)
    for sp, row in zip(SPECS, rows):
        cfg = NoCConfig(mode=sp.mode, static_gpu_vcs=sp.static_gpu_vcs,
                        seed=sp.seed, **FAST)
        ref = sim.simulate(cfg, PROFILES[sp.workload])
        _assert_rows_equal(row, ref, f"{sp.mode}/{sp.workload}/g{sp.static_gpu_vcs}/s{sp.seed}")


def test_paper_sweeps_compile_exactly_once():
    """Fig 2/3 + Fig 9/10/11 + Fig 12 together: ONE trace (DESIGN.md §10).

    Since the subnet axis is S-padded and the structure traced, the
    4-subnet network no longer compiles its own program — the entire paper
    evaluation is one executable.  (Tightened from <= 2 when S-padding
    landed.)
    """
    from benchmarks import fig2_3_vc_sweep, fig9_10_11_configs, fig12_dynamic_kf

    mini = dict(n_epochs=3, epoch_len=150, seeds=(0,))
    sim.reset_trace_count()
    fig2_3_vc_sweep.run(**mini)
    fig9_10_11_configs.run(**mini)
    fig12_dynamic_kf.run(**mini)
    assert sim.trace_count() == 1, (
        f"paper sweeps traced simulate {sim.trace_count()} times; all modes "
        "(4subnet included) must share the one S/V-padded program"
    )


def test_padded_program_matches_dedicated_trace():
    """S/V-padding equivalence: the shared padded program reproduces the
    mode's dedicated trace bit-for-bit — per-seed counters included.

    4subnet is the load-bearing case (padded V with masked upper VCs AND
    a re-indexed switch-allocation requester space); one 2-subnet mode
    guards the padded-subnet direction.
    """
    for mode, wl in (("4subnet", "STO"), ("kf", "PATH")):
        for seed in (0, 1):
            cfg = NoCConfig(mode=mode, seed=seed, **FAST)
            pad = sim.simulate(cfg, PROFILES[wl])
            ded = sim.simulate(cfg, PROFILES[wl], padded=False)
            label = f"{mode}/{wl}/s{seed}"
            _assert_rows_equal(pad, ded, f"padded vs dedicated {label}")
            for name, a, b in zip(
                pad.counters._fields, pad.counters, ded.counters
            ):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{label}: counter {name} not bitwise equal",
                )


def test_sweep_sharded_matches_sweep_on_ragged_batch():
    """`sweep_sharded` == `sweep` on a point count that does NOT divide the
    device count (5 points, 4 devices -> one pad row per the padding rule).

    Runs in a subprocess because the XLA device count is locked at first
    jax init (same pattern as tests/test_multidevice.py).
    """
    body = """
        import jax, numpy as np
        from repro.core.noc import sim
        from repro.core.noc.sim import SweepSpec
        FAST = dict(n_epochs=2, epoch_len=50)
        specs = [
            SweepSpec("baseline", "PATH"),
            SweepSpec("4subnet", "LIB", seed=1),
            SweepSpec("kf", "STO", seed=2),
            SweepSpec("static", "PATH", static_gpu_vcs=3, seed=3),
            SweepSpec("fair", "BFS", seed=4),
        ]
        assert len(jax.devices()) == 4
        rows = sim.sweep(specs, **FAST)
        rows_sh = sim.sweep_sharded(specs, devices=4, **FAST)
        for i, (a, b) in enumerate(zip(rows, rows_sh)):
            for (p, x), (_, y) in zip(
                jax.tree_util.tree_leaves_with_path(a),
                jax.tree_util.tree_leaves_with_path(b),
            ):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"row {i} {jax.tree_util.keystr(p)}")
        print("SHARDED_RAGGED_OK")
    """
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_RAGGED_OK" in out.stdout


def test_batch_profile_broadcast_and_seed_override():
    cfgs = [NoCConfig(mode="fair", seed=9, **FAST)] * 2
    res = sim.simulate_batch(cfgs, PROFILES["LIB"], seeds=(9, 9))
    _assert_rows_equal(
        jax.tree.map(lambda x: x[0], res),
        jax.tree.map(lambda x: x[1], res),
        "identical rows",
    )
    ref = sim.simulate(cfgs[0], PROFILES["LIB"])
    _assert_rows_equal(jax.tree.map(lambda x: x[0], res), ref, "vs single")


def test_batch_rejects_mixed_structures():
    """Genuinely structural differences still refuse to batch — but mode is
    no longer one of them: 2-subnet and 4-subnet rows share the padded
    program (DESIGN.md §10) and batch together."""
    cfgs = [NoCConfig(mode="baseline", **FAST),
            NoCConfig(mode="baseline", n_epochs=4, epoch_len=100)]
    with pytest.raises(ValueError, match="structural"):
        sim.simulate_batch(cfgs, PROFILES["PATH"])

    mixed = [NoCConfig(mode="baseline", **FAST),
             NoCConfig(mode="4subnet", **FAST)]
    res = sim.simulate_batch(mixed, PROFILES["PATH"])
    assert res.gpu_ipc.shape[0] == 2


def test_summarize_seeds_reports_mean_and_std():
    specs = [SweepSpec("fair", "PATH", seed=s) for s in (0, 1)]
    rows = sim.sweep(specs, **FAST)
    agg = sim.summarize_seeds(rows, warmup_epochs=2)
    per = [sim.summarize(r, warmup_epochs=2) for r in rows]
    assert agg["gpu_ipc"] == pytest.approx(
        (per[0]["gpu_ipc"] + per[1]["gpu_ipc"]) / 2
    )
    assert agg["gpu_ipc_std"] >= 0.0
    assert "avg_latency_std" in agg
