"""Batched sweep engine: equivalence with per-config runs + compile budget.

The contract of `sim.simulate_batch` / `sim.sweep` (DESIGN.md §4):

  1. a batch row is bit-for-bit the same simulation as a standalone
     `simulate()` with the same config/workload/seed;
  2. the whole paper evaluation (Fig 2/3 grid + Fig 9/10/11 grid) costs at
     most TWO traces of the simulator — the unified 2-subnet program and
     the structurally different 4-subnet one.
"""
import jax
import numpy as np
import pytest

from repro.core.noc import sim
from repro.core.noc.sim import NoCConfig, SweepSpec
from repro.core.noc.traffic import PROFILES

FAST = dict(n_epochs=8, epoch_len=100)


def _assert_rows_equal(row, ref, label):
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(row),
        jax.tree_util.tree_leaves_with_path(ref),
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6,
            err_msg=f"{label}: leaf {jax.tree_util.keystr(path)}",
        )


SPECS = [
    SweepSpec(mode, wl, seed=seed)
    for mode in ("baseline", "fair", "kf", "4subnet")
    for wl in ("PATH", "BFS")
    for seed in (0, 3)
] + [
    SweepSpec("static", wl, static_gpu_vcs=g, seed=1)
    for wl in ("PATH", "BFS")
    for g in (1, 2, 3)
]


def test_sweep_rows_match_per_config_simulate():
    """Every mode/workload/ratio/seed: batch row == standalone simulate."""
    rows = sim.sweep(SPECS, batch_tile=4, **FAST)
    for sp, row in zip(SPECS, rows):
        cfg = NoCConfig(mode=sp.mode, static_gpu_vcs=sp.static_gpu_vcs,
                        seed=sp.seed, **FAST)
        ref = sim.simulate(cfg, PROFILES[sp.workload])
        _assert_rows_equal(row, ref, f"{sp.mode}/{sp.workload}/g{sp.static_gpu_vcs}/s{sp.seed}")


def test_paper_sweeps_compile_at_most_twice():
    """Fig 2/3 + Fig 9/10/11 together: <= 2 traces (2-subnet + 4-subnet)."""
    from benchmarks import fig2_3_vc_sweep, fig9_10_11_configs

    mini = dict(n_epochs=3, epoch_len=150, seeds=(0,))
    sim.reset_trace_count()
    fig2_3_vc_sweep.run(**mini)
    fig9_10_11_configs.run(**mini)
    assert sim.trace_count() <= 2, (
        f"paper sweeps traced simulate {sim.trace_count()} times; the "
        "2-subnet modes must share one program and 4subnet adds the other"
    )


def test_batch_profile_broadcast_and_seed_override():
    cfgs = [NoCConfig(mode="fair", seed=9, **FAST)] * 2
    res = sim.simulate_batch(cfgs, PROFILES["LIB"], seeds=(9, 9))
    _assert_rows_equal(
        jax.tree.map(lambda x: x[0], res),
        jax.tree.map(lambda x: x[1], res),
        "identical rows",
    )
    ref = sim.simulate(cfgs[0], PROFILES["LIB"])
    _assert_rows_equal(jax.tree.map(lambda x: x[0], res), ref, "vs single")


def test_batch_rejects_mixed_structures():
    cfgs = [NoCConfig(mode="baseline", **FAST), NoCConfig(mode="4subnet", **FAST)]
    with pytest.raises(ValueError, match="structural"):
        sim.simulate_batch(cfgs, PROFILES["PATH"])


def test_summarize_seeds_reports_mean_and_std():
    specs = [SweepSpec("fair", "PATH", seed=s) for s in (0, 1)]
    rows = sim.sweep(specs, **FAST)
    agg = sim.summarize_seeds(rows, warmup_epochs=2)
    per = [sim.summarize(r, warmup_epochs=2) for r in rows]
    assert agg["gpu_ipc"] == pytest.approx(
        (per[0]["gpu_ipc"] + per[1]["gpu_ipc"]) / 2
    )
    assert agg["gpu_ipc_std"] >= 0.0
    assert "avg_latency_std" in agg
