"""Predictor-ablation + scenario-schedule subsystem (DESIGN.md §12), plus
the PR's bugfix regressions.

Contracts pinned here:

  1. the predictor bank's KF lane is the legacy
     `binarize(kalman.step(...).x[0])` path bit-for-bit (so the golden
     pinning in test_cycle_engine keeps covering the bank);
  2. scenario schedules materialize with EXACT epoch boundaries, and a
     constant schedule is value-invisible versus the plain profile;
  3. ablation x scenario x workload points batch into the simulator's ONE
     compiled program (`sim.trace_count() == 1`);
  4. bugfix regressions that fail on the pre-fix code: the `summarize`
     warmup clamp (NaN on short runs), the uint16 injection-stamp gate at
     the 2^16-cycle boundary, and the `gpu_ipc_proxy` zero/low-demand
     deflation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kalman, predictor
from repro.core.noc import metrics, sim
from repro.core.noc.sim import NoCConfig, SweepSpec, init_sim_state
from repro.core.noc.traffic import (
    PROFILES,
    SCENARIOS,
    ScenarioSchedule,
    Segment,
    WorkloadProfile,
    materialize,
    phase_shift,
    program_mix,
    rate_ramp,
)

FAST = dict(n_epochs=8, epoch_len=100)


def _rows_equal(a, b, label):
    for (path, x), (_, y) in zip(
        jax.tree_util.tree_leaves_with_path(a),
        jax.tree_util.tree_leaves_with_path(b),
    ):
        key = jax.tree_util.keystr(path)
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{label}: leaf {key}"
        )


# ---------------------------------------------------------------------------
# 1. predictor bank
# ---------------------------------------------------------------------------

def test_predictor_bank_kf_lane_matches_legacy_path():
    """kind=kf through the bank == the pre-refactor KF update, bitwise,
    along a whole observation sequence (state included)."""
    params = kalman.paper_params(q=1e-3, r=2e-1)
    pp = predictor.predictor_policy("kf")
    bank = predictor.init_state()
    legacy = kalman.init_state(1)
    rng = np.random.default_rng(0)
    for _ in range(25):
        z = jnp.asarray(rng.uniform(-1, 1, (3,)), jnp.float32)
        bank, sig = predictor.step(pp, params, bank, z)
        legacy, _, _ = kalman.step(params, legacy, z)
        ref_sig = kalman.binarize(legacy.x[0])
        assert int(sig) == int(ref_sig)
        np.testing.assert_array_equal(np.asarray(bank.kf.x), np.asarray(legacy.x))
        np.testing.assert_array_equal(np.asarray(bank.kf.p), np.asarray(legacy.p))


def test_predictor_bank_naive_members():
    """EMA recurrence, last-value thresholding, and the constant members
    emit exactly their definitions."""
    params = kalman.paper_params()
    zs = [jnp.asarray(v, jnp.float32) for v in
          ([0.5, 0.5, 0.5], [-1.0, -1.0, -1.0], [0.2, 0.2, 0.2])]

    states = {n: predictor.init_state() for n in predictor.PREDICTORS}
    pols = {n: predictor.predictor_policy(n, ema_alpha=0.5)
            for n in predictor.PREDICTORS}
    ema_ref, out = 0.0, {n: [] for n in predictor.PREDICTORS}
    for z in zs:
        zbar = float(jnp.mean(z))
        ema_ref = 0.5 * zbar + 0.5 * ema_ref
        for n in predictor.PREDICTORS:
            states[n], sig = predictor.step(pols[n], params, states[n], z)
            out[n].append(int(sig))
        assert out["last"][-1] == int(zbar > 0)
        assert out["ema"][-1] == int(ema_ref > 0)
        assert float(states["ema"].ema) == pytest.approx(ema_ref)
    assert out["always_on"] == [1, 1, 1]
    assert out["always_off"] == [0, 0, 0]
    assert out["last"] == [1, 0, 1]
    assert out["ema"] == [1, 0, 0]  # 0.5*0.2 + 0.5*(-0.375) < 0: smoothed


def test_unknown_predictor_rejected():
    with pytest.raises(ValueError, match="predictor"):
        predictor.predictor_policy("oracle")
    with pytest.raises(ValueError, match="predictor"):
        sim.simulate(
            NoCConfig(mode="kf", predictor="oracle", **FAST), PROFILES["PATH"]
        )


def test_kf_predictor_row_in_mixed_batch_matches_standalone():
    """Selection survives vmap: a kf-predictor row batched next to every
    naive predictor reproduces the standalone default run bitwise."""
    preds = list(predictor.PREDICTORS)
    cfgs = [NoCConfig(mode="kf", predictor=p, **FAST) for p in preds]
    res = sim.simulate_batch(cfgs, PROFILES["BFS"])
    ref = sim.simulate(NoCConfig(mode="kf", **FAST), PROFILES["BFS"])
    _rows_equal(jax.tree.map(lambda x: x[0], res), ref, "kf row vs standalone")


def test_always_off_predictor_matches_fair_network():
    """always_off never requests a boost, so the kf-mode network must be
    indistinguishable from the static fair split (same VC partition, SA
    pattern gated off at config 0) — except for the reported raw signal."""
    off = sim.simulate(
        NoCConfig(mode="kf", predictor="always_off", **FAST), PROFILES["STO"]
    )
    fair = sim.simulate(NoCConfig(mode="fair", **FAST), PROFILES["STO"])
    assert int(jnp.sum(off.applied_config)) == 0
    # the raw signal trace legitimately differs (fair reports the KF's
    # signal, always_off a constant 0) — everything else must be bitwise
    _rows_equal(off._replace(kf_signal=fair.kf_signal), fair,
                "always_off vs fair")


def test_always_on_predictor_boosts_after_warmup():
    cfg = NoCConfig(
        mode="kf", predictor="always_on", n_epochs=10, epoch_len=100,
        policy=sim.PolicyConfig(warmup=300, hold=100, revert=10**9),
    )
    res = sim.simulate(cfg, PROFILES["PATH"])
    conf = np.asarray(res.applied_config)
    assert conf[:2].sum() == 0            # warmup covers epochs 0-2's starts
    assert conf[3:].all()                 # then boosted for good (no revert)


# ---------------------------------------------------------------------------
# 2. scenario schedules
# ---------------------------------------------------------------------------

def test_constant_schedule_is_value_invisible():
    """A one-segment schedule == the plain profile, bitwise, and plain
    profiles materialize to exact broadcasts of their scalars."""
    sched = ScenarioSchedule((Segment(0.0, "PATH"),))
    a = sim.simulate(NoCConfig(mode="kf", **FAST), PROFILES["PATH"])
    b = sim.simulate(NoCConfig(mode="kf", **FAST), sched)
    _rows_equal(a, b, "constant schedule vs plain profile")

    rows = materialize(PROFILES["MUM"], 7)
    for f in WorkloadProfile._fields:
        leaf = np.asarray(getattr(rows, f))
        assert leaf.shape == (7,) and leaf.dtype == np.float32
        np.testing.assert_array_equal(
            leaf, np.full((7,), np.float32(getattr(PROFILES["MUM"], f)))
        )


def test_phase_shift_boundary_is_exact():
    """PATH -> BFS at fraction 0.5 of 10 epochs: epochs 0-4 carry PATH's
    rows, epochs 5-9 BFS's — no blending, no off-by-one."""
    rows = materialize(phase_shift("PATH", "BFS", at=0.5), 10)
    for f in WorkloadProfile._fields:
        leaf = np.asarray(getattr(rows, f))
        np.testing.assert_array_equal(
            leaf[:5], np.full((5,), np.float32(getattr(PROFILES["PATH"], f))),
            err_msg=f"{f} before the shift",
        )
        np.testing.assert_array_equal(
            leaf[5:], np.full((5,), np.float32(getattr(PROFILES["BFS"], f))),
            err_msg=f"{f} after the shift",
        )


def test_rate_ramp_endpoints_and_linearity():
    base = PROFILES["LIB"]
    rows = materialize(rate_ramp("LIB", 0.5, 1.5), 5)
    hi = np.asarray(rows.gpu_rate_hi)
    assert hi[0] == pytest.approx(0.5 * base.gpu_rate_hi)
    assert hi[-1] == pytest.approx(1.5 * base.gpu_rate_hi)
    np.testing.assert_allclose(np.diff(hi), np.diff(hi)[0], rtol=1e-5)
    # phase dynamics are untouched by the ramp
    np.testing.assert_allclose(
        np.asarray(rows.p_enter), np.float32(base.p_enter), rtol=1e-6)


def test_pinned_phase_segments_force_the_markov_phase():
    sched = ScenarioSchedule((
        Segment(0.0, "BFS", pin_phase=0), Segment(0.5, "BFS", pin_phase=1),
    ))
    rows = materialize(sched, 4)
    np.testing.assert_array_equal(np.asarray(rows.p_enter), [0, 0, 1, 1])
    np.testing.assert_array_equal(np.asarray(rows.p_exit), [1, 1, 0, 0])


def test_program_mix_cycles_programs():
    rows = materialize(program_mix(("PATH", "STO"), repeats=2), 8)
    lo = np.asarray(rows.gpu_rate_lo)
    p, s = np.float32(PROFILES["PATH"].gpu_rate_lo), np.float32(
        PROFILES["STO"].gpu_rate_lo)
    np.testing.assert_array_equal(lo, [p, p, s, s, p, p, s, s])


def test_schedule_validation():
    with pytest.raises(ValueError, match="sorted"):
        ScenarioSchedule((Segment(0.0, "PATH"), Segment(0.6, "BFS"),
                          Segment(0.3, "STO")))
    with pytest.raises(ValueError, match="start at 0.0"):
        ScenarioSchedule((Segment(0.25, "PATH"),))
    with pytest.raises(ValueError, match="at least one"):
        ScenarioSchedule(())
    # §15 bugfix: unknown names raise ValueError (was a bare KeyError),
    # listing near-misses when any exist
    with pytest.raises(ValueError, match="unknown workload"):
        sim.run_workload("kf", "NOT_A_WORKLOAD", **FAST)
    with pytest.raises(ValueError, match="did you mean"):
        sim.run_workload("kf", "SHIFT_PATH_BSF", **FAST)
    with pytest.raises(ValueError, match="shape"):
        bad = materialize(PROFILES["PATH"], 6)
        sim.simulate(NoCConfig(mode="kf", **FAST), bad)  # 6 rows, 8 epochs


# ---------------------------------------------------------------------------
# 3. one-trace contract over the full ablation x scenario x workload grid
# ---------------------------------------------------------------------------

def test_ablation_scenario_workload_grid_is_single_trace():
    """Predictors, scenario schedules, stationary workloads, and every
    network mode (4subnet included) batch into ONE compiled program."""
    dims = dict(n_epochs=5, epoch_len=60)  # unique to this test -> 1 fresh trace
    specs = (
        [SweepSpec("kf", sc, seed=1, predictor=p)
         for sc in SCENARIOS for p in predictor.PREDICTORS]
        + [SweepSpec(m, wl) for m in ("baseline", "fair", "4subnet")
           for wl in ("PATH", "SHIFT_PATH_BFS")]
        + [SweepSpec("static", "RAMP_LIB", static_gpu_vcs=3)]
    )
    sim.reset_trace_count()
    rows = sim.sweep(specs, **dims)
    assert sim.trace_count() == 1, (
        f"ablation x scenario grid traced simulate {sim.trace_count()} times"
    )
    assert len(rows) == len(specs)
    for row in rows:
        assert bool(jnp.all(jnp.isfinite(row.gpu_ipc)))


# ---------------------------------------------------------------------------
# 4. bugfix regressions (each fails on the pre-fix code)
# ---------------------------------------------------------------------------

def test_summarize_short_run_is_finite():
    """n_epochs <= warmup_epochs used to take the mean of an empty slice
    (NaN); the clamp keeps at least the final epoch in view."""
    res = sim.simulate(NoCConfig(mode="kf", **FAST), PROFILES["PATH"])
    s = sim.summarize(res, warmup_epochs=10)  # 8 epochs < 10 warmup
    assert all(np.isfinite(v) for v in s.values()), s
    agg = sim.summarize_seeds([res, res], warmup_epochs=50)
    assert all(np.isfinite(v) for v in agg.values()), agg
    # the clamped slice is the tail epoch, not a silent full-run mean
    assert s["gpu_ipc"] == pytest.approx(float(res.gpu_ipc[-1]))


def test_stamp_dtype_gate_boundaries():
    """uint16 stamps are exact up to total == 2^16 cycles (max age is
    total - 1): the gate must pick uint16 at 65535 AND 65536 total cycles
    (the pre-fix `total + 1 <= 0xFFFF` gate wrongly fell back to int32
    there) and int32 from 65537 on."""
    for epoch_len, n_epochs, want in (
        (13107, 5, jnp.uint16),   # 65535
        (8192, 8, jnp.uint16),    # 65536
        (65537, 1, jnp.int32),    # 65537
    ):
        stc = NoCConfig(mode="kf", n_epochs=n_epochs,
                        epoch_len=epoch_len).static_spec()
        subs, _, _, _ = init_sim_state(stc)
        assert subs.buf_binj.dtype == want, (
            f"{epoch_len * n_epochs} total cycles -> {subs.buf_binj.dtype}"
        )
    with pytest.raises(ValueError, match="stamp_dtype"):
        init_sim_state(NoCConfig(stamp_dtype="uint8").static_spec())


def test_stamp_uint16_wraparound_exact_at_max_age():
    """The stamp subtraction is exact for every age a 65536-cycle run can
    produce — including the maximal age 65535, which the pre-fix gate
    never allowed uint16 to reach."""
    total = 2**16
    binj = jnp.asarray([0, 1, 2, 30_000, 65_535], jnp.uint16)
    cycle = jnp.int32(total - 1)  # last cycle of the run
    age16 = (cycle.astype(jnp.uint16) - binj).astype(jnp.int32)
    true_age = cycle - jnp.asarray(binj, jnp.int32)
    np.testing.assert_array_equal(np.asarray(age16), np.asarray(true_age))
    assert int(age16[0]) == 65_535


def test_stamp_uint16_boundary_matches_int32_simulation():
    """Full-sim pin at exactly 2^16 total cycles: auto (uint16) stamps
    reproduce forced-int32 stamps bit-for-bit, latencies included."""
    dims = dict(mode="baseline", n_epochs=2, epoch_len=32_768, seed=3)
    auto = sim.simulate(NoCConfig(**dims), PROFILES["STO"])
    stc16 = NoCConfig(**dims).static_spec()
    subs, _, _, _ = init_sim_state(stc16)
    assert subs.buf_binj.dtype == jnp.uint16
    wide = sim.simulate(NoCConfig(stamp_dtype="int32", **dims), PROFILES["STO"])
    _rows_equal(auto, wide, "uint16 vs int32 stamps at the 2^16 boundary")


def test_gpu_ipc_proxy_low_demand():
    """Zero demand is idleness (base IPC), not a stall; sub-unit demand is
    divided exactly instead of being clamped to 1 (pre-fix: both deflated)."""
    assert float(metrics.gpu_ipc_proxy(jnp.float32(0.0), jnp.float32(0.0))) == 1.0
    assert float(metrics.gpu_ipc_proxy(jnp.float32(0.25), jnp.float32(0.5))
                 ) == pytest.approx(0.5)
    # integer-demand epochs (what the sim produces) are untouched: the
    # divisor clamp only ever engaged below 1 packet/epoch
    served = jnp.asarray([3.0, 7.0, 0.0], jnp.float32)
    demand = jnp.asarray([4.0, 7.0, 2.0], jnp.float32)
    old = jnp.minimum(served / jnp.maximum(demand, 1.0), 1.0)
    np.testing.assert_array_equal(
        np.asarray(metrics.gpu_ipc_proxy(served, demand)), np.asarray(old)
    )
