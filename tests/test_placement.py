"""Traced-placement + arbitrary-topology suite (DESIGN.md §17).

Pins the placement refactor from four sides:

  1. topology generalization — `validate_topology_args` rejects grids
     that cannot host the MC rows or the CPU/GPU tiling (the old code
     silently backfilled colliding MC columns), and non-paper grids
     build exact layouts;
  2. placement model — `PlacementSchedule` validation, plan builders
     (class counts preserved, MC tiles never reassigned), registry
     lookup/registration errors, `resolve_placement` shape checks;
  3. zero-cost identity path — the refactor guard: placement=None runs
     replay the committed PR-4 goldens bitwise on ALL three backends,
     an explicit identity stream is bitwise placement-free, a
     bandwidth-control row CARRYING a relocation stream is bitwise a
     row with no stream at all (a disarmed lever is free), and the
     control x placement grid compiles exactly ONE simulate trace;
  4. relocation semantics — a scheduled SWAP_MID migration moves every
     non-MC tile at the midpoint epoch (visible in `SimTrace.place_cls`
     and `place_moves_total`), and an active-relocation run is bitwise
     congruent across ref / pallas / pallas_arb, on 6x6 and on a
     non-paper 4x4 grid.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noc import sim
from repro.core.noc.placement import (
    PLACEMENTS,
    PlacementEvent,
    PlacementSchedule,
    PlacementStream,
    lookup_placement,
    register_placement,
    resolve_placement,
    static_placement,
)
from repro.core.noc.sim import NoCConfig, SweepSpec
from repro.core.noc.topology import (
    MAX_ROUTERS,
    NT_CPU,
    NT_GPU,
    NT_MC,
    make_topology,
    validate_topology_args,
)

TINY = dict(n_epochs=8, epoch_len=80)
FAST = dict(n_epochs=8, epoch_len=100)  # the golden capture's dims
BACKENDS = ("ref", "pallas", "pallas_arb")
GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden_cycle_engine.json"
)


def _bitwise_equal(a, b, label):
    for (path, x), (_, y) in zip(
        jax.tree_util.tree_leaves_with_path(a),
        jax.tree_util.tree_leaves_with_path(b),
    ):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{label}: leaf {jax.tree_util.keystr(path)}",
        )


# ---------------------------------------------------------------------------
# 1. topology generalization: validation + non-paper grids
# ---------------------------------------------------------------------------

class TestTopologyValidation:
    def test_rejects_non_int_dims(self):
        with pytest.raises(ValueError, match="width must be an int"):
            validate_topology_args(6.0, 6, 8)
        with pytest.raises(ValueError, match="height must be an int"):
            validate_topology_args(6, True, 8)
        with pytest.raises(ValueError, match="n_mc must be an int"):
            validate_topology_args(6, 6, "8")

    def test_rejects_degenerate_mesh(self):
        with pytest.raises(ValueError, match="width >= 2 and height >= 2"):
            validate_topology_args(1, 6, 2)
        with pytest.raises(ValueError, match="n_mc must be >= 1"):
            validate_topology_args(6, 6, 0)

    def test_rejects_mc_row_overflow(self):
        # 9 MCs on a width-4 mesh: bottom row needs ceil(9/2)=5 > 4 slots
        with pytest.raises(ValueError, match="does not fit on the top"):
            validate_topology_args(4, 4, 9)

    def test_rejects_all_mc_mesh(self):
        with pytest.raises(ValueError, match="non-MC tile"):
            validate_topology_args(2, 2, 4)

    def test_rejects_over_64_routers(self):
        with pytest.raises(ValueError, match="packed\n?.*lane layout caps"):
            validate_topology_args(9, 8, 8)
        assert 8 * 8 == MAX_ROUTERS
        validate_topology_args(8, 8, 8)  # exactly at the cap is fine

    def test_make_topology_rejects_via_validate(self):
        with pytest.raises(ValueError, match="does not fit"):
            make_topology(2, 8, 5)

    def test_default_grid_unchanged(self):
        """The paper layout is pinned: any drift breaks every golden."""
        topo = make_topology()
        assert topo.mc_ids.tolist() == [0, 2, 3, 5, 30, 32, 33, 35]
        nt = topo.node_type
        assert int((nt == NT_GPU).sum()) == 14
        assert int((nt == NT_CPU).sum()) == 14
        assert int((nt == NT_MC).sum()) == 8

    def test_non_paper_grid_builds_exactly(self):
        topo = make_topology(4, 5, 6)
        nt = topo.node_type
        assert topo.n_routers == 20
        assert int((nt == NT_MC).sum()) == 6
        # MCs only on top and bottom rows, all distinct
        rows = set(int(r) // 4 for r in topo.mc_ids)
        assert rows <= {0, 4}
        assert len(set(topo.mc_ids.tolist())) == 6
        # remaining tiles alternate GPU/CPU
        assert int((nt == NT_GPU).sum()) == 7
        assert int((nt == NT_CPU).sum()) == 7


# ---------------------------------------------------------------------------
# 2. placement model: schedules, plans, registry, resolution
# ---------------------------------------------------------------------------

class TestPlacementModel:
    def test_rejects_bad_events(self):
        with pytest.raises(ValueError, match="unknown placement plan"):
            PlacementSchedule((PlacementEvent(0.0, 1.0, "teleport"),))
        with pytest.raises(ValueError, match="slot"):
            PlacementSchedule((
                PlacementEvent(0.0, 1.0, "gpu_near_mc", "turbo"),
            ))
        with pytest.raises(ValueError, match="outside"):
            PlacementSchedule((
                PlacementEvent(0.7, 0.3, "gpu_near_mc"),
            ))

    def test_plans_preserve_counts_and_mc_tiles(self):
        topo = make_topology()
        nt = np.asarray(topo.node_type)
        for name in ("GPU_NEAR_MC", "GPU_NEAR_MC_ALWAYS", "SWAP_MID"):
            stream = lookup_placement(name).materialize(8, topo)
            for plan in (np.asarray(stream.cls0), np.asarray(stream.cls1)):
                # MC rows are physical: never reassigned, in any epoch
                assert (plan[:, nt == NT_MC] == NT_MC).all(), name
                # relocation conserves compute: class counts fixed
                assert ((plan == NT_GPU).sum(axis=1) == 14).all(), name
                assert ((plan == NT_CPU).sum(axis=1) == 14).all(), name

    def test_gpu_near_mc_moves_gpu_toward_mcs(self):
        topo = make_topology()
        base = np.asarray(topo.node_type)
        plan = np.asarray(
            lookup_placement("GPU_NEAR_MC").materialize(4, topo).cls1[0]
        )
        ids = np.arange(topo.n_routers)
        xy = np.stack([ids % 6, ids // 6], axis=1)
        mc_xy = xy[np.asarray(topo.mc_ids)]
        dist = np.abs(xy[:, None, :] - mc_xy[None, :, :]).sum(-1).min(-1)
        assert dist[plan == NT_GPU].mean() < dist[base == NT_GPU].mean()

    def test_registry_errors(self):
        with pytest.raises(ValueError, match="did you mean"):
            lookup_placement("GPU_NEAR_MCC")
        with pytest.raises(TypeError, match="must be a PlacementSchedule"):
            register_placement("BAD", object())
        with pytest.raises(ValueError, match="already exists"):
            register_placement("GPU_NEAR_MC", PLACEMENTS["GPU_NEAR_MC"])

    def test_resolve_shapes_and_types(self):
        topo = make_topology()
        for src in (None, "SWAP_MID", PLACEMENTS["GPU_NEAR_MC"],
                    static_placement(8, topo)):
            stream = resolve_placement(src, 8, topo)
            assert stream.cls0.shape == (8, 36)
            assert stream.cls1.shape == (8, 36)
        with pytest.raises(TypeError, match="cannot resolve placement"):
            resolve_placement(42, 8, topo)
        with pytest.raises(ValueError, match="has shape"):
            resolve_placement(static_placement(4, topo), 8, topo)

    def test_identity_stream_is_the_topology_layout(self):
        topo = make_topology()
        stream = static_placement(3, topo)
        want = np.tile(np.asarray(topo.node_type), (3, 1))
        np.testing.assert_array_equal(np.asarray(stream.cls0), want)
        np.testing.assert_array_equal(np.asarray(stream.cls1), want)


# ---------------------------------------------------------------------------
# 3. the refactor guard: identity placement is bitwise-free
# ---------------------------------------------------------------------------

class TestIdentityPlacement:
    def test_goldens_replay_on_all_backends(self):
        """Committed PR-4 goldens replay bitwise with the placement layer
        in the loop, on every backend — the tentpole's no-regression pin."""
        with open(GOLDEN_PATH) as f:
            golden = json.load(f)
        for backend in BACKENDS:
            for key, g in golden.items():
                mode, wl, gs, ss = key.split("/")
                cfg = NoCConfig(mode=mode, static_gpu_vcs=int(gs[1:]),
                                seed=int(ss[1:]), placement=None, **FAST)
                res = sim.simulate(cfg, wl, backend=backend)
                sums = {n: int(np.sum(np.asarray(leaf)))
                        for n, leaf in zip(res.counters._fields,
                                           res.counters)}
                assert sums == g["counter_sums"], \
                    f"{backend}/{key}: counter drift"
                assert (np.asarray(res.applied_config).tolist()
                        == g["applied_config"]), f"{backend}/{key}"

    def test_explicit_identity_stream_is_bitwise_free(self):
        cfg = NoCConfig(mode="kf", **TINY)
        a = sim.simulate(cfg, "SHIFT_PATH_BFS")
        b = sim.simulate(
            dataclasses_replace(cfg, placement=static_placement(
                TINY["n_epochs"], make_topology())),
            "SHIFT_PATH_BFS",
        )
        _bitwise_equal(a, b, "explicit identity stream")

    def test_armed_but_idle_lever_is_bitwise_free(self):
        """Bandwidth control carrying the GPU_NEAR_MC stream == no stream:
        `place_enable` False must make the relocation rows unreachable."""
        cfg = NoCConfig(mode="kf", control="bandwidth", **TINY)
        a = sim.simulate(cfg, "SHIFT_PATH_BFS")
        b = sim.simulate(
            dataclasses_replace(cfg, placement="GPU_NEAR_MC"),
            "SHIFT_PATH_BFS",
        )
        _bitwise_equal(a, b, "armed-but-idle placement lever")

    def test_control_x_placement_grid_is_one_trace(self):
        specs = [
            SweepSpec("kf", "SHIFT_PATH_BFS", seed=s, placement=plc,
                      control=ctl)
            for s in (0, 1)
            for plc in (None, "GPU_NEAR_MC", "SWAP_MID")
            for ctl in ("bandwidth", "placement", "joint")
        ]
        sim.reset_trace_count()
        # epoch_len unique to this test: other suites compile (8, 80)
        # batched programs, and a jit-cache hit would count 0 traces
        rows = sim.sweep(specs, n_epochs=8, epoch_len=96)
        assert len(rows) == len(specs)
        assert sim.trace_count() == 1, (
            f"control x placement grid traced {sim.trace_count()}x"
        )


# ---------------------------------------------------------------------------
# 4. relocation semantics + backend congruence
# ---------------------------------------------------------------------------

class TestRelocation:
    def test_swap_mid_migrates_at_midpoint(self):
        cfg = NoCConfig(mode="kf", placement="SWAP_MID", control="joint",
                        **TINY)
        _, trace = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
        cls = np.asarray(trace.place_cls)
        moves = (np.diff(cls, axis=0) != 0).sum(axis=1)
        # exactly one migration epoch: the midpoint swap of all 28 non-MC
        # tiles; the boost slot never engages in this warmup-short run
        assert moves.tolist() == [0, 0, 0, 28, 0, 0, 0]
        from repro.obs.probes import summarize_trace

        assert summarize_trace(trace)["place_moves_total"] == 28

    def test_identity_run_has_zero_moves(self):
        cfg = NoCConfig(mode="kf", **TINY)
        _, trace = sim.simulate_with_trace(cfg, "SHIFT_PATH_BFS")
        from repro.obs.probes import summarize_trace

        assert summarize_trace(trace)["place_moves_total"] == 0

    @pytest.mark.parametrize("backend", BACKENDS[1:])
    def test_active_relocation_congruent_across_backends(self, backend):
        cfg = NoCConfig(mode="kf", placement="SWAP_MID", control="joint",
                        faults="FLAP_BFS", guard=True, **TINY)
        ref = sim.simulate(cfg, "SHIFT_PATH_BFS", backend="ref")
        other = sim.simulate(cfg, "SHIFT_PATH_BFS", backend=backend)
        _bitwise_equal(ref, other, f"relocation+faults ref vs {backend}")

    @pytest.mark.parametrize("backend", BACKENDS[1:])
    def test_non_paper_grid_congruent_across_backends(self, backend):
        cfg = NoCConfig(mode="kf", width=4, height=4, placement="SWAP_MID",
                        control="joint", **TINY)
        ref = sim.simulate(cfg, "SHIFT_PATH_BFS", backend="ref")
        other = sim.simulate(cfg, "SHIFT_PATH_BFS", backend=backend)
        _bitwise_equal(ref, other, f"4x4 ref vs {backend}")

    def test_non_paper_grid_runs_and_differs(self):
        base = sim.simulate(NoCConfig(mode="kf", **TINY), "SHIFT_PATH_BFS")
        small = sim.simulate(
            NoCConfig(mode="kf", width=4, height=4, **TINY),
            "SHIFT_PATH_BFS",
        )
        assert np.isfinite(np.asarray(small.gpu_ipc)).all()
        # a 4x4/8-MC grid is a different machine: outputs must move
        assert not np.array_equal(np.asarray(base.counters.gpu_gen),
                                  np.asarray(small.counters.gpu_gen))

    def test_bench_sweep_seed_style_tracks_impl_signature(self):
        # bench_sweep's serial baseline jits sim._simulate_impl directly
        # (by design: it times fresh-trace recompiles, so it can't go
        # through the public cached wrappers) — a new positional arg on
        # the impl, like this PR's placement stream, breaks it without
        # any public-API test noticing.  One tiny point keeps it in sync.
        from benchmarks import bench_sweep

        cfgs, profs = bench_sweep._grid(
            ["PATH"], [2], [0], n_epochs=2, epoch_len=8
        )
        assert bench_sweep.time_serial_seed_style(cfgs, profs) > 0.0


def dataclasses_replace(cfg, **kw):
    import dataclasses

    return dataclasses.replace(cfg, **kw)
