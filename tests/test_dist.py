"""Distribution layer: sharding rules (divisibility + conflict fallback),
int8-EF compression, pipeline parallelism (single-device degenerate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import compress, sharding


@pytest.fixture()
def mesh_2d():
    # single host device: mesh validation happens on SHAPES, so fabricate a
    # 1x1; rule RESOLUTION is tested against a fake 16x16 via axis sizes
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Duck-typed mesh for rule resolution (no devices needed)."""

    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np

        self.devices = _np.empty(shape)
        self.size = int(_np.prod(shape))


M16 = FakeMesh((16, 16), ("data", "model"))
M3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    spec = sharding.logical_to_mesh(P("batch", None, "embed"),
                                    (256, 128, 1024), M16)
    assert spec == P(("data",), None, None)


def test_non_divisible_falls_back_to_replication():
    # kv=2 heads on model=16: replicate
    spec = sharding.logical_to_mesh(P("batch", None, "kv", None),
                                    (256, 128, 2, 64), M16)
    assert spec[2] is None


def test_multipod_batch_uses_pod_and_data():
    spec = sharding.logical_to_mesh(P("batch", None), (256, 64), M3)
    assert spec[0] == ("pod", "data")


def test_conflict_fallback_moe_weights():
    # (expert, embed, mlp): expert claims model -> mlp falls to data (FSDP)
    spec = sharding.logical_to_mesh(P("expert", "embed", "mlp"),
                                    (128, 5120, 8192), M16)
    assert spec == P(("model",), None, ("data",))


def _axes(entry):
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def test_conflict_fallback_kv_seq():
    # kv divisible: kv takes model, kv_seq replicates
    s1 = sharding.logical_to_mesh(P(None, "batch", "kv_seq", "kv", None),
                                  (24, 128, 32768, 32, 64), M16)
    assert _axes(s1[3]) == ("model",) and _axes(s1[2]) == ()
    # kv NOT divisible: kv_seq claims model (seq-sharded cache)
    s2 = sharding.logical_to_mesh(P(None, "batch", "kv_seq", "kv", None),
                                  (24, 128, 32768, 8, 64), M16)
    assert _axes(s2[3]) == () and _axes(s2[2]) == ("model",)


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, "batch", None) is x


def test_quantize_ef_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
    r = jnp.zeros((256,))
    q, scale, new_r = compress.quantize_ef(g, r)
    deq = compress.dequantize(q, scale)
    # quantization error <= scale/2 per element, and residual == error
    np.testing.assert_allclose(np.asarray(g - deq), np.asarray(new_r),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(g - deq))) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """With EF, the bias of repeated quantization vanishes: sum of
    dequantized updates converges to the sum of true gradients."""
    rng = np.random.default_rng(1)
    true_g = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32) * 1e-3
    r = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for _ in range(50):
        q, s, r = compress.quantize_ef(true_g, r)
        total = total + compress.dequantize(q, s)
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(true_g * 50), atol=1e-3)


def test_pipeline_single_stage_identity(mesh_2d):
    """n_stages=1 degenerate pipeline == plain apply (the multi-stage path
    is exercised by the dry-run's pp mode and the 8-device CI variant)."""
    from repro.dist import pipeline

    mesh = jax.make_mesh((1,), ("stage",))
    w = jnp.full((1, 4, 4), 2.0)

    def stage_fn(p, x):
        return x @ p

    mbs = jnp.ones((3, 2, 4))
    out = pipeline.pipeline_apply(stage_fn, w, mbs, mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mbs @ w[0]))


def test_split_stages():
    from repro.dist import pipeline

    params = {"w": jnp.arange(24).reshape(6, 2, 2)}
    out = pipeline.split_stages(params, 3)
    assert out["w"].shape == (3, 2, 2, 2)


def test_fleet_kf_matches_single_filter():
    """FleetKF on n=1 == the paper-form NoC predictor (core.kalman),
    step-for-step — the two KF implementations cannot drift."""
    from repro.core import kalman
    from repro.dist.kf_scheduler import FleetKF, SchedulerConfig

    q, r = 3e-3, 2e-1
    fleet = FleetKF(1, SchedulerConfig(kf_q=q, kf_r=r))
    params = kalman.paper_params(q=q, r=r)
    state = kalman.init_state(1)

    zs = np.random.default_rng(7).normal(0, 0.7, (25, 3)).astype(np.float32)
    for t in range(25):
        z = jnp.asarray(zs[t])
        sig_fleet = fleet.epoch(z[None, :])
        state, _, _ = kalman.step(params, state, z)
        np.testing.assert_allclose(np.asarray(fleet.x),
                                   np.asarray(state.x), atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(fleet.p),
                                   np.asarray(state.p[0]), atol=1e-6,
                                   rtol=1e-4)
        assert int(sig_fleet[0]) == int(kalman.binarize(state.x[0]))


# ---------------------------------------------------------------------------
# telemetry: StepTimer phase accounting + Telemetry.observe normalization


def _timed_step(timer, step_s, wait_s, t=[100.0]):
    """Drive one begin/ready/end cycle with a fake clock."""
    import repro.dist.telemetry as telemetry

    orig = telemetry.time.perf_counter
    try:
        telemetry.time.perf_counter = lambda: t[0]
        timer.step_begin()
        t[0] += wait_s
        timer.mark_input_ready()
        t[0] += step_s - wait_s
        timer.step_end()
    finally:
        telemetry.time.perf_counter = orig


def test_step_timer_first_step_seeds_ema():
    from repro.dist.telemetry import StepTimer

    timer = StepTimer(ema=0.8)
    _timed_step(timer, step_s=1.0, wait_s=0.25)
    # first sample SEEDS the EMA (no decay from the 0.0 prior)
    assert timer.wait_frac == pytest.approx(0.25)
    assert timer.step_time == pytest.approx(1.0)
    _timed_step(timer, step_s=1.0, wait_s=0.75)
    assert timer.wait_frac == pytest.approx(0.8 * 0.25 + 0.2 * 0.75)


def test_step_timer_end_without_begin_clears_ready_mark():
    from repro.dist.telemetry import StepTimer

    timer = StepTimer()
    # a stray ready+end without a begin must not leak the ready mark into
    # the next step's wait accounting
    timer.mark_input_ready()  # no-op: no step in flight
    timer._t_ready = 12345.0  # simulate a stale mark from a torn-down step
    timer.step_end()
    assert timer._t_ready is None and timer._t0 is None
    _timed_step(timer, step_s=1.0, wait_s=0.0)
    assert timer.wait_frac == pytest.approx(0.0)


def test_step_timer_ready_at_counter_zero_counts():
    from repro.dist.telemetry import StepTimer

    import repro.dist.telemetry as telemetry

    timer = StepTimer()
    t = [0.0]
    orig = telemetry.time.perf_counter
    try:
        telemetry.time.perf_counter = lambda: t[0]
        timer.step_begin()          # t0 = 0.0
        t[0] = 0.0
        timer.mark_input_ready()    # t_ready = 0.0 — falsy but valid
        t[0] = 2.0
        timer.step_end()
    finally:
        telemetry.time.perf_counter = orig
    # wait of 0.0s measured from a 0.0-valued counter is a real sample, and
    # the step must fully reset for the next cycle
    assert timer.wait_frac == pytest.approx(0.0)
    assert timer.step_time == pytest.approx(2.0)
    assert timer._t0 is None and timer._t_ready is None


def test_telemetry_observe_normalized():
    from repro.dist.telemetry import StaticCosts, Telemetry

    tel = Telemetry(
        costs_by_variant={0: StaticCosts(hbm_bytes=8e9,
                                         collective_bytes=1e9)},
        comm_scale=1e9, hbm_capacity=16e9,
    )
    tel.timer.wait_frac = 0.5
    z = np.asarray(tel.observe())
    assert z.shape == (3,)
    assert np.all(z >= -1.0) and np.all(z <= 1.0)
    # raw = [0.5, 1.0, 0.5] over hi = [1, 2, 1] -> all normalize identically
    assert z[0] == pytest.approx(z[1]) and z[0] == pytest.approx(z[2])
    # no costs at all -> only the stall channel moves the vector
    tel_empty = Telemetry(costs_by_variant={})
    tel_empty.timer.wait_frac = 0.5
    z2 = np.asarray(tel_empty.observe())
    assert z2[2] == pytest.approx(z[2])
    assert z2[0] == pytest.approx(np.min(z2))
