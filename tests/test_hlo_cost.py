"""Scan-aware HLO cost model vs XLA's own cost_analysis."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost

W = jax.ShapeDtypeStruct((256, 256), jnp.float32)
X = jax.ShapeDtypeStruct((256, 256), jnp.float32)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


_cost = hlo_cost.xla_cost_analysis


def test_matches_xla_on_straightline():
    def f(w, x):
        for _ in range(4):
            x = x @ w
        return x

    c = _compile(f, W, X)
    mine = hlo_cost.analyze_hlo(c.as_text())
    np.testing.assert_allclose(mine.flops, _cost(c)["flops"], rtol=0.01)


def test_xla_undercounts_scan_and_we_fix_it():
    """The motivating bug: XLA counts a while body once."""
    def scanned(w, x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def unrolled(w, x):
        for _ in range(10):
            x = x @ w
        return x

    cs = _compile(scanned, W, X)
    cu = _compile(unrolled, W, X)
    xla_s = _cost(cs)["flops"]
    xla_u = _cost(cu)["flops"]
    assert xla_s < xla_u / 5  # XLA undercounts the scan ~10x

    mine_s = hlo_cost.analyze_hlo(cs.as_text()).flops
    mine_u = hlo_cost.analyze_hlo(cu.as_text()).flops
    np.testing.assert_allclose(mine_s, mine_u, rtol=0.01)
    np.testing.assert_allclose(mine_s, xla_u, rtol=0.01)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = _compile(f, W, X)
    mine = hlo_cost.analyze_hlo(c.as_text())
    # 15 matmuls of 2*256^3
    np.testing.assert_allclose(mine.flops, 15 * 2 * 256 ** 3, rtol=0.05)


def test_dot_flops_with_contracting_dims():
    def f(w, x):
        return jnp.einsum("ab,cb->ac", x, w)  # contracting dim 1 of lhs

    c = _compile(f, W, X)
    mine = hlo_cost.analyze_hlo(c.as_text())
    np.testing.assert_allclose(mine.flops, 2 * 256 ** 3, rtol=0.01)


def test_flash_assumption_drops_score_bytes_not_flops():
    B, H, S, D = 2, 4, 512, 64

    def attn(q, k, v):
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k)   # (B, H, S, S) scores
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, v)

    q = jax.ShapeDtypeStruct((B, H, S, D), jnp.float32)
    c = _compile(attn, q, q, q)
    base = hlo_cost.analyze_hlo(c.as_text(), seq=S, assume_flash=False)
    flash = hlo_cost.analyze_hlo(c.as_text(), seq=S, assume_flash=True)
    assert flash.bytes < base.bytes      # score traffic dropped
    np.testing.assert_allclose(flash.flops, base.flops, rtol=1e-6)
    # weights/activations with a dim == seq are NOT dropped (ndim < 4)
    def mlp(x, w):
        return jnp.tanh(x @ w) @ w.T

    x = jax.ShapeDtypeStruct((S, S), jnp.float32)
    c2 = _compile(mlp, x, x)
    b2 = hlo_cost.analyze_hlo(c2.as_text(), seq=S, assume_flash=False)
    f2 = hlo_cost.analyze_hlo(c2.as_text(), seq=S, assume_flash=True)
    np.testing.assert_allclose(f2.bytes, b2.bytes, rtol=1e-6)


def test_collective_wire_factors():
    hlo = """
HloModule m, entry_computation_layout={()->f32[1024]}

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    c = hlo_cost.analyze_hlo(hlo)
    # ring all-reduce: 2*(4-1)/4 * 4096 bytes
    np.testing.assert_allclose(c.wire_bytes, 2 * 0.75 * 4096, rtol=1e-6)
