"""Sharding-spec trees must mirror parameter trees exactly for all 10
archs — the invariant every jit in_shardings resolution relies on."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.launch import specs as launch_specs


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_and_spec_trees_congruent(arch):
    cfg = configs.get(arch)   # FULL config — abstract only, no allocation
    params_abs = launch_specs.abstract_params(cfg)
    spec_tree = launch_specs.param_specs(cfg)

    # identical structure: zip succeeds leaf-for-leaf
    pairs = []

    def pair(s, p):
        assert isinstance(s, P), (arch, s)
        assert len(s) <= len(p.shape), (arch, s, p.shape)
        pairs.append((s, p))

    jax.tree.map(pair, spec_tree, params_abs,
                 is_leaf=lambda x: isinstance(x, P))
    assert len(pairs) == len(jax.tree.leaves(params_abs))


@pytest.mark.parametrize("arch", ["glm4-9b", "falcon-mamba-7b",
                                  "zamba2-2.7b", "grok-1-314b",
                                  "seamless-m4t-large-v2"])
def test_train_state_spec_congruence(arch):
    cfg = configs.get(arch)
    opt_cfg = launch_specs.default_opt_cfg(cfg)
    state_abs, state_specs = launch_specs.abstract_train_state(cfg, opt_cfg)
    n_leaves = len(jax.tree.leaves(state_abs))
    n_specs = len(jax.tree.leaves(
        state_specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_leaves == n_specs
    # opt moments mirror params
    assert int(state_abs.opt.step.shape == ()) == 1


def test_fleet_kf_bank():
    """Fleet deployment: one filter per (pod x class); banked updates via
    the Pallas kernel track a burst on every filter independently."""
    import jax.numpy as jnp
    import numpy as np

    from repro.dist.kf_scheduler import FleetKF, SchedulerConfig

    n = 64
    fleet = FleetKF(n, SchedulerConfig(kf_q=1e-2, kf_r=1e-1))
    rng = np.random.default_rng(0)
    hot = rng.random(n) < 0.5     # half the links saturate
    for _ in range(20):
        z = np.where(hot[:, None], 0.8, -0.8) + rng.normal(0, 0.1, (n, 3))
        sig = fleet.epoch(jnp.asarray(z, jnp.float32))
    sig = np.asarray(sig)
    assert (sig[hot] == 1).mean() > 0.9
    assert (sig[~hot] == 0).mean() > 0.9
