"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.flash_attn import ref as fa_ref
from repro.kernels.kf_bank import ops as kf_ops
from repro.kernels.kf_bank import ref as kf_ref
from repro.kernels.mamba_scan import ops as ms_ops
from repro.kernels.mamba_scan import ref as ms_ref


@pytest.mark.parametrize(
    "b,s,h,kv,d,causal,window,cap",
    [
        (2, 256, 4, 2, 64, True, None, None),
        (1, 384, 4, 4, 128, True, None, 30.0),    # grok softcap
        (2, 256, 8, 2, 64, True, 64, None),        # sliding window
        (1, 256, 4, 2, 64, False, None, None),     # encoder (bidirectional)
        (1, 200, 4, 2, 64, True, None, None),      # non-divisible seq (pad)
        (1, 128, 2, 1, 32, True, None, None),      # MQA
    ],
)
def test_flash_attention_matches_ref(b, s, h, kv, d, causal, window, cap):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 logit_cap=cap, block_q=128, block_k=128)
    want = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        logit_cap=cap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 256, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 256, 2, 64)).astype(dtype)
    out = fa_ops.flash_attention(q, k, v, block_q=128, block_k=128)
    want = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)
    assert out.dtype == dtype


@pytest.mark.parametrize(
    "b,L,d,s,chunk,bd",
    [(2, 64, 32, 8, 16, 16), (1, 128, 64, 16, 32, 32), (2, 32, 16, 4, 32, 16),
     (1, 64, 128, 8, 64, 64)],
)
def test_mamba_scan_matches_ref(b, L, d, s, chunk, bd):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.random.uniform(ks[0], (b, L, d, s), jnp.float32, 0.5, 0.999)
    bb = jax.random.normal(ks[1], (b, L, d, s), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (b, d, s), jnp.float32)
    hs, hl = ms_ops.mamba_chunk_scan(a, bb, h0, chunk=chunk, block_d=bd)
    hs_w, hl_w = ms_ref.scan_ref(a, bb, h0)
    np.testing.assert_allclose(hs, hs_w, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hl, hl_w, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,M,a,q", [
    (1024, 3, 1.0, 1e-3), (4096, 3, 0.9, 1e-2), (100, 5, 0.95, 1e-3),
    (7, 3, 1.0, 1e-4),
])
def test_kf_bank_matches_paper_form(B, M, a, q):
    """Information-form kernel == paper Eqs. 3-5 (core.kalman oracle)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B,))
    p = jax.random.uniform(ks[1], (B,), jnp.float32, 0.1, 2.0)
    z = jax.random.normal(ks[2], (B, M))
    h = jax.random.uniform(ks[3], (M,), jnp.float32, 0.5, 1.5)
    r = jax.random.uniform(ks[4], (M,), jnp.float32, 0.05, 0.5)
    xn, pn = kf_ops.kf_bank_step(x, p, z, h, r, a=a, q=q)
    xw, pw = kf_ref.kf_bank_ref(x, p, z, h, r, a=a, q=q)
    np.testing.assert_allclose(xn, xw, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(pn, pw, atol=1e-6, rtol=1e-4)


def test_fused_mamba_paths_match_ref_scan():
    """The fused chunked scans (production path) == naive recurrence."""
    from repro.models import mamba
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=0,
                      n_kv_heads=0, d_ff=0, vocab_size=64, ssm_state=8,
                      ssm_variant="mamba1", ssm_chunk=16)
    key = jax.random.PRNGKey(4)
    p = mamba.make_mamba1(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, 32), jnp.float32)
    y_fused = mamba.apply_mamba1(p, x, cfg)
    # force ref path with an odd length slice
    y_ref = mamba.apply_mamba1(p, x[:, :63], cfg)
    np.testing.assert_allclose(y_fused[:, :63], y_ref, atol=2e-3, rtol=2e-3)


def test_mamba_decode_matches_full_sequence():
    """Step-by-step decode == full-sequence scan (falcon-mamba family)."""
    from repro.models import mamba
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=16, n_heads=0,
                      n_kv_heads=0, d_ff=0, vocab_size=64, ssm_state=4,
                      ssm_variant="mamba1", ssm_chunk=8)
    key = jax.random.PRNGKey(5)
    p = mamba.make_mamba1(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 16, 16), jnp.float32)
    y_full = mamba.apply_mamba1(p, x, cfg)
    st = mamba.init_mamba1_state(1, cfg, jnp.float32)
    ys = []
    for t in range(16):
        y, st = mamba.apply_mamba1_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_steps, y_full, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize(
    "B,L,D,S,chunk,bd",
    [(2, 64, 32, 8, 16, 16), (1, 128, 64, 16, 32, 32)],
)
def test_fused_mamba_kernel_v2(B, L, D, S, chunk, bd):
    """v2 kernel (decay/input built in VMEM, C-projection fused) == the
    model-level fused scan (itself validated against the naive recurrence)."""
    from repro.kernels.mamba_scan import fused
    from repro.models import mamba

    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    dt = jax.random.uniform(ks[0], (B, L, D), jnp.float32, 0.001, 0.1)
    xc = jax.random.normal(ks[1], (B, L, D))
    b = jax.random.normal(ks[2], (B, L, S))
    c = jax.random.normal(ks[3], (B, L, S))
    a_mat = -jnp.exp(jax.random.normal(ks[4], (D, S)) * 0.3)
    y, hl = fused.fused_mamba_scan(dt, xc, b, c, a_mat, chunk=chunk,
                                   block_d=bd)
    y_w, hl_w = mamba.fused_chunked_scan_m1(
        dt, xc, b, c, a_mat, jnp.zeros((B, D, S)), chunk)
    np.testing.assert_allclose(y, y_w, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(hl, hl_w, atol=2e-4, rtol=2e-4)
