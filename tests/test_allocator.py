"""Tests for the reconfiguration policy (paper §3.2 rules, §3.3 tables)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests are optional; unit tests run without hypothesis
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.core.allocator import (
    PolicyConfig,
    apply_policy,
    apply_policy_gated,
    class_vc_masks,
    init_policy_state,
    mode_policy,
    sa_priority_pattern,
    vc_partition,
)

CFG = PolicyConfig(warmup=10_000, hold=5_000, revert=10_000)


def run_policy(signals_cycles, cfg=CFG):
    """Apply the policy at (signal, cycle) pairs, returning applied configs."""
    st_ = init_policy_state()
    out = []
    for sig, cyc in signals_cycles:
        st_ = apply_policy(cfg, st_, jnp.int32(sig), jnp.int32(cyc))
        out.append(int(st_.config))
    return out


def test_warmup_blocks_reconfiguration():
    # paper: KF not activated until 10,000 cycles after start
    configs = run_policy([(1, 1_000), (1, 5_000), (1, 9_999)])
    assert configs == [0, 0, 0]
    configs = run_policy([(1, 10_000)])
    assert configs == [1]


def test_hold_prevents_flapping():
    # after a change, configuration is frozen for >= 5,000 cycles
    configs = run_policy([(1, 10_000), (0, 12_000), (0, 14_999), (0, 15_000)])
    assert configs == [1, 1, 1, 0]


def test_revert_rule():
    # staying boosted for > 10,000 cycles forces a fallback to equal share
    configs = run_policy([(1, 10_000), (1, 15_000), (1, 20_001)])
    assert configs == [1, 1, 0]


def test_vc_partition_tables():
    g0, c0 = vc_partition(jnp.int32(0), 4)
    np.testing.assert_array_equal(g0, [True, True, False, False])
    np.testing.assert_array_equal(c0, [False, False, True, True])
    g1, c1 = vc_partition(jnp.int32(1), 4)
    np.testing.assert_array_equal(g1, [True, True, True, False])
    np.testing.assert_array_equal(c1, [False, False, False, True])


def test_mode_policy_tables():
    """The traced policy tensors reproduce each mode's trace-time branches."""
    mp = mode_policy("baseline", 4)
    np.testing.assert_array_equal(mp.gpu_mask0, [True] * 4)  # fully shared
    np.testing.assert_array_equal(mp.cpu_mask0, [True] * 4)
    assert not bool(mp.kf_enable) and not bool(mp.sa_enable)

    mp = mode_policy("fair", 4)
    np.testing.assert_array_equal(mp.gpu_mask0, [True, True, False, False])

    mp = mode_policy("static", 4, static_gpu_vcs=3)
    np.testing.assert_array_equal(mp.gpu_mask0, [True, True, True, False])
    np.testing.assert_array_equal(mp.cpu_mask0, [False, False, False, True])

    mp = mode_policy("kf", 4)
    assert bool(mp.kf_enable) and bool(mp.sa_enable)
    g0, c0 = class_vc_masks(mp, jnp.int32(0))
    g1, c1 = class_vc_masks(mp, jnp.int32(1))
    np.testing.assert_array_equal(g0, [True, True, False, False])
    np.testing.assert_array_equal(g1, [True, True, True, False])
    assert bool(jnp.all(g0 ^ c0)) and bool(jnp.all(g1 ^ c1))

    with pytest.raises(ValueError):
        mode_policy("bogus", 4)


def test_apply_policy_gated_is_noop_when_disabled():
    mp_off = mode_policy("fair", 4)
    mp_on = mode_policy("kf", 4)
    st0 = init_policy_state()
    sig, cyc = jnp.int32(1), jnp.int32(20_000)
    off = apply_policy_gated(CFG, mp_off, st0, sig, cyc)
    on = apply_policy_gated(CFG, mp_on, st0, sig, cyc)
    assert int(off.config) == 0
    assert int(off.last_change) == int(st0.last_change)
    assert int(off.boosted_since) == int(st0.boosted_since)
    assert int(on.config) == 1


def test_sa_pattern():
    # config 0: round robin (-1); config 1: GPU,GPU,CPU repeating
    assert int(sa_priority_pattern(jnp.int32(0), jnp.int32(0))) == -1
    pat = [int(sa_priority_pattern(jnp.int32(1), jnp.int32(c))) for c in range(6)]
    assert pat == [1, 1, 0, 1, 1, 0]


if hypothesis is not None:

    @hypothesis.given(
        sigs=st.lists(st.integers(0, 1), min_size=1, max_size=60),
        step=st.integers(100, 3_000),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_property_partition_disjoint_and_complete(sigs, step):
        """At every reachable policy state the VC masks partition the VC set,
        so no VC is ever unowned (deadlock) or double-owned (class mixing)."""
        st_ = init_policy_state()
        for i, sig in enumerate(sigs):
            st_ = apply_policy(CFG, st_, jnp.int32(sig), jnp.int32(i * step))
            g, c = vc_partition(st_.config, 4)
            assert bool(jnp.all(g ^ c))  # disjoint and covering

    @hypothesis.given(
        sigs=st.lists(st.integers(0, 1), min_size=2, max_size=80),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_property_no_change_within_hold(sigs):
        """Reallocation intervals respect the paper's 5,000-cycle minimum,
        except the revert rule which may only move config back to 0."""
        st_ = init_policy_state()
        prev_cfg, prev_change_cycle = 0, None
        for i, sig in enumerate(sigs):
            cycle = 10_000 + i * 1_000
            st_ = apply_policy(CFG, st_, jnp.int32(sig), jnp.int32(cycle))
            cfg_now = int(st_.config)
            if cfg_now != prev_cfg:
                if prev_change_cycle is not None:
                    gap = cycle - prev_change_cycle
                    assert gap >= CFG.hold or cfg_now == 0  # revert is exempt
                prev_change_cycle = cycle
            prev_cfg = cfg_now

else:

    def test_property_suite_needs_hypothesis():
        pytest.skip("hypothesis not installed (pip install -e .[test])")


def test_starvation_freedom_of_sa_pattern():
    """Even in boosted mode the CPU gets a guaranteed arbitration phase."""
    prefs = [int(sa_priority_pattern(jnp.int32(1), jnp.int32(c))) for c in range(30)]
    assert prefs.count(0) == 10  # one CPU phase per 3 cycles
