"""Serving engine: correctness of slot algebra + the KF arbitration A/B
(the paper's technique at the serving layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serve import batching, cache as cache_lib
from repro.serve.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.smoke("llama3.2-3b")
    params, _ = lm.make_lm(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_insert_and_clear_slot(small_model):
    params, cfg = small_model
    state = lm.init_decode_state(4, 32, cfg)
    toks = jnp.zeros((1, 8), jnp.int32)
    prefilled = lm.prefill_caches(params, toks, cfg, 32)
    state = cache_lib.insert_request(state, prefilled, 2)
    assert int(state.length[2]) == 8
    assert int(state.length[0]) == 0
    kv = state.caches[0]
    assert bool(jnp.any(kv.k[:, 2] != 0))
    assert not bool(jnp.any(kv.k[:, 0] != 0))
    state = cache_lib.clear_slot(state, 2)
    assert int(state.length[2]) == 0


def test_decode_after_insert_matches_direct(small_model):
    """Decoding through an engine slot == decoding the request directly."""
    params, cfg = small_model
    toks = jnp.arange(8, dtype=jnp.int32)[None, :]
    direct = lm.prefill_caches(params, toks, cfg, 32)
    lg_direct, _ = lm.decode_step(params, jnp.array([[9]], jnp.int32),
                                  direct, cfg)

    state = lm.init_decode_state(4, 32, cfg)
    state = cache_lib.insert_request(state, direct, 1)
    tok_b = jnp.zeros((4, 1), jnp.int32).at[1, 0].set(9)
    lg_batch, _ = lm.decode_step(params, tok_b, state, cfg)
    np.testing.assert_allclose(
        np.asarray(lg_batch[1, 0]), np.asarray(lg_direct[0, 0]),
        atol=2e-2, rtol=2e-2)  # bf16 activations


def _run(mode, params, cfg, n_requests=24, seed=0):
    wl = batching.WorkloadConfig(
        n_requests=n_requests, mean_prompt=24, mean_gen=6, seed=seed)
    reqs = batching.generate(wl)
    ecfg = EngineConfig(mode=mode, max_slots=4, max_len=64,
                        budget_tokens=64)
    eng = Engine(params, cfg, ecfg)
    return eng.run(reqs, max_iters=600).summary()


def test_engine_completes_all_requests(small_model):
    params, cfg = small_model
    s = _run("rr", params, cfg, n_requests=12)
    assert s["n_finished"] == 12


def test_kf_reacts_to_bursts(small_model):
    """Under bursty arrivals the KF engine must actually reconfigure."""
    params, cfg = small_model
    wl = batching.WorkloadConfig(n_requests=24, mean_prompt=40, mean_gen=6,
                                 burst_rate=8.0, calm_rate=0.1, seed=3)
    reqs = batching.generate(wl)
    ecfg = EngineConfig(mode="kf", max_slots=4, max_len=64,
                        budget_tokens=64, warmup_iters=2)
    eng = Engine(params, cfg, ecfg)
    stats = eng.run(reqs, max_iters=600)
    assert stats.summary()["n_finished"] == 24
    assert max(stats.configs) == 1          # boost engaged at least once
    assert min(stats.configs) == 0          # and not permanently


def test_hysteresis_hold(small_model):
    """After a reconfiguration the config must hold >= hold_iters."""
    params, cfg = small_model
    wl = batching.WorkloadConfig(n_requests=20, mean_prompt=40, mean_gen=6,
                                 burst_rate=8.0, calm_rate=0.1, seed=3)
    ecfg = EngineConfig(mode="kf", max_slots=4, max_len=64,
                        budget_tokens=64, warmup_iters=2, hold_iters=4)
    eng = Engine(params, cfg, ecfg)
    stats = eng.run(batching.generate(wl), max_iters=600)
    cfgs = stats.configs
    changes = [i for i in range(1, len(cfgs)) if cfgs[i] != cfgs[i - 1]]
    for a, b in zip(changes, changes[1:]):
        assert b - a >= ecfg.hold_iters
