"""System-level invariants: packet conservation in the NoC sim, SSM slot
algebra in the serving engine, cross-pod group classification."""
import jax
import numpy as np
import pytest

from repro.core.noc.sim import run_workload


@pytest.mark.parametrize("mode", ["baseline", "kf"])
def test_noc_packet_conservation(mode):
    """Completions can never exceed injections, injections never exceed
    generation — across every epoch, cumulatively."""
    res = run_workload(mode, "PATH", n_epochs=30)
    c = res.counters
    gen = np.cumsum(np.asarray(c.gpu_gen) + np.asarray(c.cpu_gen))
    push = np.cumsum(np.asarray(c.gpu_push) + np.asarray(c.cpu_push))
    done = np.cumsum(np.asarray(c.gpu_done) + np.asarray(c.cpu_done))
    assert (push <= gen).all()
    assert (done <= push).all()
    # the network actually serves traffic
    assert done[-1] > 0.5 * gen[-1]


def test_noc_latency_positive_and_bounded():
    res = run_workload("baseline", "LIB", n_epochs=30)
    lat = np.asarray(res.avg_latency[5:])
    assert (lat > 0).all()
    assert (lat < 500).all()   # no runaway livelock


def test_engine_with_ssm_arch():
    """Slot insert/clear works for Mamba (conv+ssm) caches, not just KV."""
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import batching
    from repro.serve.engine import Engine, EngineConfig

    cfg = configs.smoke("falcon-mamba-7b")
    params, _ = lm.make_lm(jax.random.PRNGKey(0), cfg)
    wl = batching.WorkloadConfig(n_requests=8, mean_prompt=16, mean_gen=4,
                                 seed=2)
    eng = Engine(params, cfg, EngineConfig(
        mode="kf", max_slots=2, max_len=48, budget_tokens=48,
        warmup_iters=2))
    stats = eng.run(batching.generate(wl), max_iters=400)
    assert stats.summary()["n_finished"] == 8


def test_crosses_pod_classifier():
    from repro.launch.hlo_cost import _crosses_pod

    # explicit groups within one pod
    assert not _crosses_pod("x), replica_groups={{0,1,2,3}}, y", 256)
    # explicit groups spanning pods
    assert _crosses_pod("x), replica_groups={{0,256},{1,257}}, y", 256)
    # plain iota, consecutive 16-groups: intra-pod
    assert not _crosses_pod("x), replica_groups=[32,16]<=[512], y", 256)
    # plain iota, one group of 512: spans both pods
    assert _crosses_pod("x), replica_groups=[1,512]<=[512], y", 256)
    # transposed iota (strided groups): pod-spanning
    assert _crosses_pod(
        "x), replica_groups=[256,2]<=[2,256]T(1,0), y", 256)
    # no pod_size => never cross
    assert not _crosses_pod("x), replica_groups={{0,256}}, y", None)


def test_ring_swa_cache_matches_full_cache():
    """SWA ring decode == full-cache decode for the in-window history."""
    import repro.configs as configs
    from repro.models import lm

    cfg = configs.smoke("h2o-danube-1.8b")  # window 16
    params, _ = lm.make_lm(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 40), 0,
                              cfg.vocab_size)
    # ring cache: max_len capped at window inside init_decode_state
    st_ring = lm.init_decode_state(1, 64, cfg)
    assert st_ring.caches[0].k.shape[2] == cfg.sliding_window
    logits_ring = None
    for t in range(40):
        logits_ring, st_ring = lm.decode_step(
            params, toks[:, t:t + 1], st_ring, cfg)
    # oracle: full forward, last-position logits
    out = lm.forward(params, toks, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_ring[0, 0]), np.asarray(out.logits[0, -1]),
        atol=3e-2, rtol=3e-2)  # bf16 activations
