"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + one decode step on CPU; output shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.models import encdec, lm
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

B, S = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {
        "tokens": toks,
        "labels": jnp.roll(toks, -1, axis=1),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    if cfg.is_encoder_decoder:
        params, _ = encdec.make_encdec(key, cfg)
        logits = encdec.forward(params, batch["tokens"], batch["embeds"], cfg)
    else:
        params, _ = lm.make_lm(key, cfg)
        logits = lm.forward(params, batch["tokens"], cfg,
                            embeds=batch.get("embeds")).logits
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_one_train_step(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(1)
    opt_cfg = opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state, _ = step_lib.init_train_state(key, cfg, opt_cfg)
    step = jax.jit(step_lib.make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, key)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state.opt.step) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params))
    assert any(moved)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(2)
    tok = jnp.zeros((B, 1), jnp.int32)
    if cfg.is_encoder_decoder:
        params, _ = encdec.make_encdec(key, cfg)
        emb = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        st = encdec.init_encdec_state(params, emb, cfg, max_len=S)
        logits, st2 = encdec.decode_step(params, tok, st, cfg)
    else:
        params, _ = lm.make_lm(key, cfg)
        st = lm.init_decode_state(B, S, cfg)
        logits, st2 = lm.decode_step(params, tok, st, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(st2.length[0]) == int(st.length[0]) + 1


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dims (never instantiated
    here — dims only)."""
    import repro.configs as C

    g = C.get("glm4-9b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    l4 = C.get("llama4-maverick-400b-a17b")
    assert (l4.n_experts, l4.n_experts_active, l4.moe_layer_period) == (128, 1, 2)
    gr = C.get("grok-1-314b")
    assert (gr.n_experts, gr.n_experts_active, gr.attn_logit_softcap) == (8, 2, 30.0)
    fm = C.get("falcon-mamba-7b")
    assert (fm.n_layers, fm.d_model, fm.ssm_state, fm.n_heads) == (64, 4096, 16, 0)
    z = C.get("zamba2-2.7b")
    assert (z.n_layers, z.ssm_state, z.shared_attn_period) == (54, 64, 6)
    sm = C.get("seamless-m4t-large-v2")
    assert sm.is_encoder_decoder and sm.n_encoder_layers == 24
    iv = C.get("internvl2-2b")
    assert iv.frontend == "vision" and iv.vocab_size == 92553
    hd = C.get("h2o-danube-1.8b")
    assert hd.sliding_window == 4096
    l3 = C.get("llama3.2-3b")
    assert l3.tie_embeddings and l3.vocab_size == 128256
    st = C.get("stablelm-1.6b")
    assert st.norm == "layernorm" and st.rope_fraction == 0.25
