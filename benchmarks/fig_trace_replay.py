"""Trace-driven demand replay: KF-vs-naive ordering on replayed traces
(DESIGN.md §15).

The predictor ablation (fig_ablation) runs on synthetic scenario
schedules; this driver runs the SAME comparison on *replayed* demand:

  * by default, the HLO-cost adapter's serving trace — per-epoch demand
    derived from XLA `cost_analysis()` of this repo's own prefill/decode
    steps (`repro.core.noc.trace_adapters`), the first non-synthetic
    workload family;
  * with ``--trace F.npz``, any recorded demand trace (e.g. a
    `repro.obs.recorder.TraceRecorder` capture).

The replayed trace registers as a sweep workload, so the whole
predictor x seed grid still shares the simulator's ONE compiled program
(``--gate`` asserts it).  ``--check`` is the CI record->replay smoke: a
4-epoch `TraceRecorder` capture of the gate scenario round-trips through
the npz schema and must replay bitwise-identical to the originating run.

Gate: KF mean GPU IPC >= every naive predictor on the replayed trace,
single-trace grid, and the record->replay check bitwise-green.  Non-smoke
runs append a `noc_trace_replay` ledger row, which
`benchmarks/check_bench.py` tolerates-until-present and then gates on.

    PYTHONPATH=src python -m benchmarks.fig_trace_replay
        [--smoke] [--gate] [--check] [--trace F.npz] [--save-trace F.npz]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.fig_ablation import (
    KF_Q_ABLATION,
    PREDICTORS,
    kf_verdict,
    run as ablation_run,
)

# Registry name the default HLO-adapter trace lands under.
HLO_WORKLOAD = "HLO_SERVE"
SEEDS = (0, 1, 2)
SMOKE_SEEDS = (0,)
# The record->replay smoke's capture source and dims: 4 epochs is enough
# to exercise the schema + scan-xs path while staying milliseconds-cheap.
CHECK_SCENARIO = "SHIFT_PATH_BFS"
CHECK_EPOCHS = 4


def prepare_source(args) -> tuple[str, dict]:
    """Register the demand source; return (workload name, provenance).

    ``--trace F.npz`` wins; otherwise the HLO-cost adapter builds the
    serving trace from this repo's own model steps.
    """
    from benchmarks import _cli

    name = _cli.registered_trace(args)
    if name:
        from repro.core.noc.traffic import lookup_workload

        return name, dict(lookup_workload(name).meta, path=args.trace)
    from repro.core.noc import trace_adapters

    trace = trace_adapters.register_hlo_workload(HLO_WORKLOAD,
                                                 overwrite=True)
    if getattr(args, "save_trace", None):
        trace.save(args.save_trace)
        print(f"# saved the HLO serving trace to {args.save_trace}")
    return HLO_WORKLOAD, trace.meta


def replay_check(save_path: str | None = None) -> list[str]:
    """Record->save->load->replay round trip; return failures ([] = pass).

    Captures CHECK_EPOCHS epochs of the gate scenario with TraceRecorder,
    round-trips the capture through the npz trace schema, replays it, and
    requires (a) a clean schema validation and (b) bitwise equality with
    running the scenario directly.
    """
    from repro.core.noc import sim
    from repro.core.noc.traffic import RecordedTrace, validate_trace_npz
    from repro.obs.recorder import TraceRecorder

    failures = []
    cfg = sim.NoCConfig(mode="kf", n_epochs=CHECK_EPOCHS, epoch_len=200)
    own_tmp = save_path is None
    if own_tmp:
        fd, save_path = tempfile.mkstemp(suffix=".npz")
        os.close(fd)
    try:
        TraceRecorder(name="replay_check", observe=False).record_to(
            save_path, cfg, CHECK_SCENARIO)
        with np.load(save_path, allow_pickle=False) as data:
            problems = validate_trace_npz(data)
        if problems:
            failures.append(f"trace schema: {problems}")
        replayed = RecordedTrace.load(save_path)
        ref = sim.simulate(cfg, CHECK_SCENARIO)
        rep = sim.simulate(cfg, replayed)
        for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(ref),
                                jax.tree.leaves(rep)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                failures.append(
                    "replay diverged at leaf "
                    + jax.tree_util.keystr(path)
                )
                break
    finally:
        if own_tmp:
            os.unlink(save_path)
    return failures


def record(res: dict, verdict: dict, grid: dict, source: str,
           provenance: dict) -> dict:
    cells = res["table"][source]
    row = {
        "bench": "noc_trace_replay",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "source": source,
        "grid": grid,
        "traces": res["traces"],
        "gpu_ipc": {p: round(cells[p]["gpu_ipc"], 6) for p in PREDICTORS},
        **verdict,
    }
    phases = provenance.get("phases")
    if phases:
        # the HLO adapter's roofline mapping, for provenance: what each
        # serving phase cost and the injection rate it mapped to
        row["hlo_phases"] = {
            p: {k: c[k] for k in ("flops", "bytes", "intensity", "rate")}
            for p, c in phases.items()
        }
    return row


def main(argv=None):
    from benchmarks import _cli

    ap = _cli.build_parser(
        __doc__,
        smoke_help="one seed on the replayed trace at full simulated dims; "
                   "no BENCH_noc.json append",
        gate_help="exit 1 unless KF >= every naive predictor on the "
                  "replayed trace, the grid ran single-trace, and the "
                  "record->replay check is bitwise-green",
    )
    ap.add_argument("--check", action="store_true",
                    help="record->replay smoke only: capture "
                         f"{CHECK_EPOCHS} epochs of {CHECK_SCENARIO}, "
                         "round-trip the npz schema, assert bitwise replay")
    ap.add_argument("--save-trace", metavar="F.npz", default=None,
                    help="save the HLO serving trace (default source) "
                         "for reuse via --trace")
    args = ap.parse_args(argv)
    from repro.obs import profiling

    if args.check:
        failures = replay_check()
        for f in failures:
            print(f"TRACE REPLAY CHECK: {f}", file=sys.stderr)
        if not failures:
            print(f"replay check OK: {CHECK_EPOCHS}-epoch "
                  f"{CHECK_SCENARIO} capture replays bitwise through the "
                  "npz schema")
        return 1 if failures else 0

    source, provenance = prepare_source(args)
    seeds = SMOKE_SEEDS if args.smoke else SEEDS
    res = profiling.profiled_run(
        args.profile,
        lambda: ablation_run(n_epochs=120, seeds=seeds,
                             scenarios=(source,), devices=args.devices,
                             backend=args.backend,
                             **_cli.shared_overrides(args)),
        label="fig_trace_replay",
    )
    print("source,predictor,gpu_ipc,gpu_ipc_std,cpu_ipc,avg_latency,"
          "boost_frac")
    for p, s in res["table"][source].items():
        print(f"{source},{p},{s['gpu_ipc']:.4f},{s['gpu_ipc_std']:.4f},"
              f"{s['cpu_ipc']:.4f},{s['avg_latency']:.2f},"
              f"{s['kf_on_frac']:.2f}")

    verdict = kf_verdict(res["table"], source)
    replay_failures = replay_check()
    print(f"# traces: {res['traces']} (contract: 1)")
    print(f"# {source}: KF gpu_ipc {verdict['kf_gpu_ipc']:.4f}; margins "
          "vs naive: "
          + ", ".join(f"{p} {m:+.4f}" for p, m in verdict["margins"].items()))
    print(f"# kf_beats_all: {verdict['kf_beats_all']} "
          "(KF >= every naive predictor on the replayed trace)")
    print(f"# record->replay bitwise: {not replay_failures}")

    if not args.smoke:
        from benchmarks.bench_sweep import BENCH_PATH, append_record

        grid = {"predictors": list(PREDICTORS), "seeds": list(seeds),
                "n_epochs": 120, "kf_q": KF_Q_ABLATION}
        rec = record(res, verdict, grid, source, provenance)
        rec["replay_bitwise"] = not replay_failures
        append_record(rec)
        print(json.dumps(rec, indent=2))
        print(f"appended noc_trace_replay record to {BENCH_PATH}")

    if args.gate:
        failures = list(replay_failures)
        if res["traces"] != 1:
            failures.append(f"replay grid traced simulate {res['traces']}x "
                            "(contract: the one shared program)")
        if not verdict["kf_beats_all"]:
            losing = {p: m for p, m in verdict["margins"].items() if m < 0}
            failures.append(
                f"KF lost to {losing} on {source} mean GPU IPC")
        for f in failures:
            print(f"TRACE REPLAY GATE: {f}", file=sys.stderr)
        if failures:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
