"""Placement-control ablation: bandwidth vs relocation vs joint levers
(DESIGN.md §17).

The paper's KF pulls one lever — the VC bandwidth split.  With node
identity refactored into traced per-epoch data (`placement.py`), the same
hysteresis signal can also *relocate compute*: swap CPU tiles sitting
next to memory controllers with far-away GPU tiles (the SHIFT-style
co-design the roadmap calls for).  This driver ablates which lever(s) the
applied config drives, over the scenario library:

  * bandwidth  — the paper's controller: VC boosts only, the static
                 checkerboard layout (placement lever disarmed);
  * placement  — relocation only: the boost plan is `GPU_NEAR_MC`
                 (GPU tiles ranked to the MC-adjacent ring), VC split
                 stays at fair;
  * joint      — both levers armed by the same KF signal.

All three controls are `ModePolicy` leaves and the placement plan rides
the epoch scan as traced data, so the whole control x scenario x seed
grid — plus an identity (placement=None) pair — shares the simulator's
ONE compiled program (`--gate` asserts it).  The identity pair pins the
refactor contract: a bandwidth-control row CARRYING the GPU_NEAR_MC
stream must be BITWISE equal to a row with no placement stream at all,
because a disarmed lever may not perturb a single bit.

Gate: joint's mean GPU IPC >= bandwidth-only's on the gate scenario
(MIX_PATH_STO_BFS — the phase-mix program whose demand migrations the
relocation lever exploits), the identity pair bitwise, and the grid
single-trace.  Non-smoke runs also
capture a probed joint run (relocation timeline: `place_moves_total`)
and append a `noc_placement` ledger row that `benchmarks/check_bench.py`
tolerates-until-present and then gates on.

    PYTHONPATH=src python -m benchmarks.fig_placement [--smoke] [--gate]
"""
from __future__ import annotations

import json
import math
import sys
import time

import jax
import numpy as np

from benchmarks.fig_ablation import KF_Q_ABLATION
from repro.core.allocator import CONTROLS, PolicyConfig
from repro.core.noc import sim
from repro.core.noc.sim import (
    NoCConfig,
    SweepSpec,
    summarize_seeds,
    sweep,
)
from repro.obs.probes import summarize_trace

ARMS = CONTROLS  # ("bandwidth", "placement", "joint")
# The boost-slot relocation plan every armed row carries: GPU tiles ranked
# onto the MC-adjacent ring while the KF signal holds.
PLACEMENT = "GPU_NEAR_MC"
# The gate binds where the relocation lever's win actually lives: the
# mixed phase program (PATH <-> STO <-> BFS), whose between-phase demand
# shifts are what compute relocation exploits.  On the pure-shift
# scenarios the joint margin is sub-quantum negative (toggle churn eats
# the layout gain); those margins are still reported, not gated.
GATE_SCENARIO = "MIX_PATH_STO_BFS"
SCENARIOS = (
    "SHIFT_PATH_BFS",
    "SHIFT_SMOOTH",
    "RAMP_LIB",
    "MIX_PATH_STO_BFS",
    "BURSTS_BFS",
)
SEEDS = (0, 1, 2)
# The identity-pair control cell's label in the results table.
IDENTITY = "identity"

# Smoke trims seeds and the scenario set, not the simulated dims — the
# boost windows only open after the policy's warmup (20 of 120 epochs at
# the default epoch_len), so shrinking n_epochs would ablate a grid in
# which the placement lever never fires.
SMOKE = dict(seeds=(0,), scenarios=(GATE_SCENARIO,))


def _arm_spec(arm: str, scenario: str, seed: int) -> SweepSpec:
    return SweepSpec(
        "kf", scenario, seed=seed, placement=PLACEMENT, control=arm,
    )


def _bitwise_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def run(
    n_epochs: int = 120,
    seeds: tuple[int, ...] = SEEDS,
    scenarios: tuple[str, ...] = SCENARIOS,
    devices: int | None = None,
    probe: bool = True,
    **overrides,
) -> dict:
    """Sweep scenarios x control arms x seeds (+ identity pair); summarize.

    Returns the per-cell summary table, the identity-pair bitwise verdict,
    the sweep's trace count (captured BEFORE the probed run — probes-on is
    deliberately its own compiled program), and one probed joint run's
    relocation counters on the gate scenario.
    """
    overrides.setdefault("kf_q", KF_Q_ABLATION)
    points = [(sc, arm, s) for sc in scenarios for arm in ARMS for s in seeds]
    specs = [_arm_spec(arm, sc, s) for sc, arm, s in points]
    # Identity pair: same bandwidth control, NO placement stream.  Rides
    # the same dispatch; must be bitwise-equal to the armed-but-disarmed
    # bandwidth rows above.
    id_specs = [
        SweepSpec("kf", GATE_SCENARIO, seed=s, placement=None,
                  control="bandwidth")
        for s in seeds
    ]
    sim.reset_trace_count()
    rows = sweep(specs + id_specs, n_epochs=n_epochs, devices=devices,
                 **overrides)
    traces = sim.trace_count()
    id_rows = rows[len(specs):]

    by_cell: dict[tuple[str, str], list] = {}
    for (sc, arm, _), row in zip(points, rows):
        by_cell.setdefault((sc, arm), []).append(row)

    policy = overrides.get("policy", PolicyConfig())
    epoch_len = overrides.get("epoch_len", 500)
    warmup_epochs = min(math.ceil(policy.warmup / epoch_len), n_epochs - 1)
    table = {
        sc: {
            arm: summarize_seeds(by_cell[(sc, arm)],
                                 warmup_epochs=warmup_epochs)
            for arm in ARMS
        }
        for sc in scenarios
    }

    # Identity contract: a disarmed placement lever may not perturb a bit —
    # bandwidth control carrying the GPU_NEAR_MC stream vs no stream at
    # all, per seed, across the full SimResult.
    identity_bitwise = all(
        _bitwise_equal(a, b)
        for a, b in zip(by_cell[(GATE_SCENARIO, "bandwidth")], id_rows)
    )

    probes = {}
    if probe:
        cfg = NoCConfig(
            mode="kf", n_epochs=n_epochs, seed=seeds[0],
            placement=PLACEMENT, control="joint", **overrides,
        )
        _, trace = sim.simulate_with_trace(cfg, GATE_SCENARIO)
        s = summarize_trace(trace)
        probes["joint"] = {
            k: s[k] for k in ("place_moves_total", "epochs")
        }

    return {
        "table": table,
        "traces": traces,
        "identity_bitwise": identity_bitwise,
        "probes": probes,
        "warmup_epochs": warmup_epochs,
    }


def control_verdict(table: dict, scenarios: tuple[str, ...]) -> dict:
    """Joint-vs-{bandwidth, placement} GPU-IPC margins per scenario.

    The gate only binds on GATE_SCENARIO (joint >= bandwidth there); the
    other margins are reported for the record.  Margins compare UNROUNDED
    values (rounding only the report): the gate must catch a sub-quantum
    ordering violation.
    """
    margins = {}
    for sc in scenarios:
        cells = table[sc]
        j = cells["joint"]["gpu_ipc"]
        margins[sc] = {
            "vs_bandwidth": round(j - cells["bandwidth"]["gpu_ipc"], 6),
            "vs_placement": round(j - cells["placement"]["gpu_ipc"], 6),
        }
    gate_cells = table.get(GATE_SCENARIO)
    joint_beats_bandwidth = (
        gate_cells is not None
        and gate_cells["joint"]["gpu_ipc"]
        >= gate_cells["bandwidth"]["gpu_ipc"]
    )
    return {"margins": margins,
            "joint_beats_bandwidth": joint_beats_bandwidth}


def record(res: dict, grid: dict, verdict: dict) -> dict:
    return {
        "bench": "noc_placement",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "gate_scenario": GATE_SCENARIO,
        "placement": PLACEMENT,
        "grid": grid,
        "traces": res["traces"],
        "identity_bitwise": res["identity_bitwise"],
        "gpu_ipc": {
            sc: {arm: round(cells[arm]["gpu_ipc"], 6) for arm in ARMS}
            for sc, cells in res["table"].items()
        },
        "probes": res["probes"],
        **verdict,
    }


def main(argv=None):
    from benchmarks import _cli

    ap = _cli.build_parser(
        __doc__,
        smoke_help="one seed on the gate scenario at full simulated dims "
                   "(see SMOKE); no BENCH_noc.json append",
        gate_help="exit 1 unless joint >= bandwidth-only mean GPU IPC on "
                  "the gate scenario, the identity pair is bitwise, and "
                  "the grid ran single-trace",
        trace=False,
    )
    args = ap.parse_args(argv)
    from repro.obs import profiling

    n_epochs, overrides = 120, {"backend": args.backend}
    if args.smoke:
        seeds, scenarios = SMOKE["seeds"], SMOKE["scenarios"]
    else:
        seeds, scenarios = SEEDS, SCENARIOS
    overrides.update(_cli.fault_overrides(args))
    overrides.update(_cli.topology_overrides(args))
    if args.placement:
        # here the shared flag swaps the plan under ablation rather than
        # injecting it into every row (each row already carries one)
        from repro.core.noc.placement import lookup_placement

        lookup_placement(args.placement)
        global PLACEMENT
        PLACEMENT = args.placement
        print(f"# --placement: ablating plan {PLACEMENT!r}")

    res = profiling.profiled_run(
        args.profile,
        lambda: run(n_epochs=n_epochs, seeds=seeds, scenarios=scenarios,
                    devices=args.devices, **overrides),
        label="fig_placement",
    )
    print("scenario,control,gpu_ipc,gpu_ipc_std,cpu_ipc,avg_latency,"
          "boost_frac")
    for sc, cells in res["table"].items():
        for arm, s in cells.items():
            print(f"{sc},{arm},{s['gpu_ipc']:.4f},{s['gpu_ipc_std']:.4f},"
                  f"{s['cpu_ipc']:.4f},{s['avg_latency']:.2f},"
                  f"{s['kf_on_frac']:.2f}")

    verdict = control_verdict(res["table"], scenarios)
    print(f"# traces: {res['traces']} (contract: 1)")
    print(f"# identity pair bitwise (disarmed lever is free): "
          f"{res['identity_bitwise']}")
    for sc, m in verdict["margins"].items():
        print(f"# {sc}: joint margin vs bandwidth {m['vs_bandwidth']:+.4f},"
              f" vs placement {m['vs_placement']:+.4f}")
    p = res["probes"].get("joint", {})
    if p:
        print(f"# joint relocation timeline: {p['place_moves_total']} "
              f"router-moves over {p['epochs']} epochs "
              f"({GATE_SCENARIO}, seed {seeds[0]})")
    print(f"# joint_beats_bandwidth: {verdict['joint_beats_bandwidth']} "
          f"(mean GPU IPC on {GATE_SCENARIO})")

    if not args.smoke:
        from benchmarks.bench_sweep import BENCH_PATH, append_record

        grid = {"scenarios": list(scenarios), "arms": list(ARMS),
                "seeds": list(seeds), "n_epochs": n_epochs,
                "kf_q": KF_Q_ABLATION}
        rec = record(res, grid, verdict)
        append_record(rec)
        print(json.dumps(rec, indent=2))
        print(f"appended noc_placement record to {BENCH_PATH}")

    if args.gate:
        failures = []
        if res["traces"] != 1:
            failures.append(f"placement grid traced simulate "
                            f"{res['traces']}x (contract: the one shared "
                            "program)")
        if not res["identity_bitwise"]:
            failures.append("bandwidth-control row carrying the placement "
                            "stream is not bitwise-equal to the no-stream "
                            "row (a disarmed lever must be free)")
        if not verdict["joint_beats_bandwidth"]:
            m = verdict["margins"][GATE_SCENARIO]["vs_bandwidth"]
            failures.append(f"joint control lost to bandwidth-only on "
                            f"{GATE_SCENARIO} (margin {m:+.6f})")
        for f in failures:
            print(f"PLACEMENT GATE: {f}", file=sys.stderr)
        if failures:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
