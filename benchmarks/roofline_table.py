"""Render the roofline table from results/dryrun artifacts (§Roofline)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str = None):
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(p))
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(r)
    return rows


def main():
    rows = load()
    if not rows:
        print("no dry-run artifacts; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    hdr = (f"{'mesh':9s}{'arch':26s}{'shape':12s}{'status':7s}"
           f"{'dominant':11s}{'compute_s':>10s}{'memory_s':>10s}"
           f"{'coll_s':>10s}{'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        rl = r.get("roofline", {})
        uf = r.get("useful_flops_ratio")
        print(f"{r['mesh']:9s}{r['arch']:26s}{r['shape']:12s}"
              f"{r['status']:7s}{rl.get('dominant', '-'):11s}"
              f"{rl.get('compute_s', 0):10.4f}{rl.get('memory_s', 0):10.4f}"
              f"{rl.get('collective_s', 0):10.4f}"
              f"{uf if uf is None else format(uf, '.2f')!s:>7s}")
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skip"]
    err = [r for r in rows if r["status"] == "error"]
    print(f"\ncells: {len(ok)} ok, {len(skip)} skip "
          f"(long_500k on full-attention archs), {len(err)} error")


if __name__ == "__main__":
    main()
