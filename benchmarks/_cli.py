"""Shared CLI surface for the fig drivers (DESIGN.md §15).

Every fig driver used to copy-paste the same argparse block
(``--devices``, ``--backend``, ``--profile``, plus ``--smoke``/``--gate``
where gated); new cross-cutting flags then had to land six times.  This
helper is the one place that surface lives now:

    ap = _cli.build_parser(__doc__, smoke_help=..., gate_help=...)
    args = ap.parse_args(argv)
    wl = _cli.registered_trace(args)        # --trace F.npz -> workload name

``--trace PATH`` (and its ``--trace-fit`` companion) registers a recorded
demand trace (`traffic.RecordedTrace` npz schema) as a sweep workload so
any figure can be driven by replayed/adapted demand instead of its
builtin synthetic workloads.  The default fit is "stretch": drivers run
at many ``n_epochs``, and a linear resample keeps any trace usable
everywhere (pass ``--trace-fit exact`` to insist on bitwise replay).

``--faults NAME`` injects a registered fault scenario (`faults.FAULTS`,
DESIGN.md §16) into every row a driver sweeps: drivers splat
`fault_overrides(args)` into their `run(**overrides)` call, and since
`faults` is an `NoCConfig` field carried as traced data, the faulty grid
still shares the healthy grid's one compiled program.

``--placement NAME`` (placement scenarios, `placement.PLACEMENTS`) and
``--topology WxH`` (non-paper mesh grids) follow the same pattern
(DESIGN.md §17): `placement_overrides(args)` / `topology_overrides(args)`
splat into `run(**overrides)` with the same precedence rule — the CLI
value overrides any per-spec value.  Placement is traced data (shared
program); topology is structural (its own compile, like ``--backend``).
"""
from __future__ import annotations

import argparse

BACKENDS = ("ref", "pallas", "pallas_arb")

# The registry name `--trace` files land under: drivers substitute it for
# their builtin workload/scenario set when the flag is present.
TRACE_WORKLOAD = "TRACE"


def build_parser(
    description: str | None = None,
    *,
    smoke_help: str | None = None,
    gate_help: str | None = None,
    trace: bool = True,
) -> argparse.ArgumentParser:
    """The fig drivers' common parser; driver-specific flags add on top."""
    ap = argparse.ArgumentParser(
        description=description,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the sweep batch axis across N devices")
    ap.add_argument("--backend", choices=BACKENDS, default="ref",
                    help="cycle engine: dense jnp (ref), fused full-cycle "
                         "lane kernel (pallas), or arbitration-only kernel "
                         "(pallas_arb); all bitwise-identical")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="capture jax.profiler traces (compile + steady "
                         "phases) into DIR")
    if smoke_help is not None:
        ap.add_argument("--smoke", action="store_true", help=smoke_help)
    if gate_help is not None:
        ap.add_argument("--gate", action="store_true", help=gate_help)
    ap.add_argument("--faults", metavar="NAME", default=None,
                    help="inject a registered fault scenario "
                         "(repro.core.noc.faults.FAULTS, e.g. FLAP_BFS) "
                         "into every swept row; default: healthy fabric")
    ap.add_argument("--placement", metavar="NAME", default=None,
                    help="apply a registered placement scenario "
                         "(repro.core.noc.placement.PLACEMENTS, e.g. "
                         "GPU_NEAR_MC) to every swept row; default: the "
                         "static paper layout")
    ap.add_argument("--topology", metavar="WxH", default=None,
                    help="run on a WxH mesh instead of the paper's 6x6 "
                         "(e.g. 4x4, 8x8; validated against the MC rows, "
                         "capped at 64 routers)")
    if trace:
        ap.add_argument("--trace", metavar="F.npz", default=None,
                        help="drive the figure with a recorded demand trace "
                             "(DESIGN.md §15 npz schema) instead of its "
                             "builtin workloads")
        ap.add_argument("--trace-fit", choices=("exact", "tile", "stretch"),
                        default="stretch",
                        help="how a trace of T epochs fits a run of "
                             "n_epochs: exact requires T == n_epochs, tile "
                             "repeats cyclically, stretch resamples "
                             "linearly (default)")
    return ap


def fault_overrides(args) -> dict:
    """Config overrides for ``--faults`` ({} when the flag is absent).

    Drivers splat the result into their `run(**overrides)` call; `sweep`
    forwards overrides to every row's `NoCConfig`, where an explicit
    `faults` key takes precedence over any per-spec value.  The name is
    validated eagerly so a typo fails at the CLI (with the registry's
    close-match suggestions) instead of deep inside the dispatch.
    """
    name = getattr(args, "faults", None)
    if not name:
        return {}
    from repro.core.noc.faults import lookup_faults

    lookup_faults(name)
    print(f"# --faults: injecting fault scenario {name!r} into every row")
    return {"faults": name}


def placement_overrides(args) -> dict:
    """Config overrides for ``--placement`` ({} when the flag is absent).

    Mirrors `fault_overrides` precedence exactly: `sweep` forwards the
    override to every row's `NoCConfig`, beating any per-spec value; the
    name is validated eagerly (with close-match suggestions)."""
    name = getattr(args, "placement", None)
    if not name:
        return {}
    from repro.core.noc.placement import lookup_placement

    lookup_placement(name)
    print(f"# --placement: applying placement scenario {name!r} to every row")
    return {"placement": name}


def topology_overrides(args) -> dict:
    """Config overrides for ``--topology WxH`` ({} when absent).

    Parses "WxH" into `NoCConfig(width=..., height=...)` and validates the
    grid eagerly (`topology.validate_topology_args`, against the default
    MC count) so an impossible mesh fails at the CLI."""
    spec = getattr(args, "topology", None)
    if not spec:
        return {}
    try:
        w_s, h_s = spec.lower().split("x")
        width, height = int(w_s), int(h_s)
    except ValueError:
        raise SystemExit(
            f"--topology expects WxH (e.g. 6x6, 4x8), got {spec!r}"
        ) from None
    from repro.core.noc.sim import NoCConfig
    from repro.core.noc.topology import validate_topology_args

    validate_topology_args(width, height, NoCConfig().n_mc)
    print(f"# --topology: running every row on a {width}x{height} mesh")
    return {"width": width, "height": height}


def shared_overrides(args) -> dict:
    """Every cross-cutting override in one splat: --faults, --placement,
    --topology.  The keys are disjoint by construction."""
    return {
        **fault_overrides(args),
        **placement_overrides(args),
        **topology_overrides(args),
    }


def registered_trace(args) -> str | None:
    """Register ``--trace`` (if given) as a workload; return its name.

    Returns None when the flag is absent so drivers can fall back to
    their builtin workload sets.
    """
    path = getattr(args, "trace", None)
    if not path:
        return None
    from repro.core.noc.traffic import register_trace

    trace = register_trace(TRACE_WORKLOAD, path,
                           fit=getattr(args, "trace_fit", "stretch"),
                           overwrite=True)
    print(f"# --trace: registered {path} as workload {TRACE_WORKLOAD!r} "
          f"({trace.n_epochs_recorded} epochs, fit={trace.fit})")
    return TRACE_WORKLOAD
