"""Perf-regression gate over the committed BENCH_noc.json trajectory.

Re-runs the sweep grid (`bench_sweep.run`) and fails if the engine
regressed versus the last committed `noc_sweep_serial_vs_batched` row on a
guarded axis:

  * trace count — the batched arm must not trace the simulator more often
    than the committed row did (1 since the S-padding refactor; the whole
    point of the engine is that the sweep is ONE compiled program);
  * end-to-end speedup — the serial-vs-batched speedup must clear an
    absolute floor AND a fraction of the committed row's speedup.  The
    fraction is deliberately loose — this is a cliff detector (e.g. the
    jit-cache identity gotcha quietly rebatching the serial arm, or a
    retrace per point sneaking back in), not a 5%-noise tripwire;
  * steady-state speedup (full grid only) — the packed-lane cycle engine
    (DESIGN.md §11) recovered `speedup_steady` to ~1x from the 0.39 the
    padded program paid before it, and this gate keeps it recovered: a
    fresh full-grid row must clear an absolute floor and a fraction of the
    committed row's steady speedup.

`--grid smoke` keeps the old fast mode: trace + end-to-end gates on the
tiny CI grid, with the steady gate skipped — a smoke steady pass is
milliseconds of scan against fixed per-op dispatch overhead (observed
0.2-1x run to run), so gating it would only add flakes.  The default full
grid takes a few minutes (24 fresh serial compiles) but measures a steady
state worth gating.

Pre-PR-3 BENCH rows lack some of the guarded fields (`batched_traces`,
`speedup_steady`); a missing baseline field downgrades that gate to its
absolute floor instead of raising KeyError.

The `noc_ablation` record (benchmarks/fig_ablation.py, DESIGN.md §12) is
guarded the same tolerate-then-gate way: while no committed row exists the
gate is skipped with a note, and once one lands it must say the KF beat
every naive predictor on the phase-shift scenario from a single-trace grid
— a committed ablation row that stopped clearing the paper's ordering is a
regression even though this script never re-runs the (expensive) grid.

Fused-engine rows (`bench_sweep --backend pallas`, DESIGN.md §13) are
guarded the same way via `check_pallas_row`: they never become the ref
baseline, and once one is committed it must show a single-trace batched
arm with a recorded steady speedup.

Since the run-ledger PR (DESIGN.md §14) two more gates run over the
committed rows: every row is validated against the ledger schema
(`check_ledger_schema` — hard for `ledger_version`-stamped rows, tolerant
for pre-ledger history), and the `noc_obs` flight-recorder row, once
committed, must keep its probe-overhead measurement and one-trace-per-
probe-setting contract (`check_obs_row`).

The `noc_faults` row (benchmarks/fig_faults.py, DESIGN.md §16) follows
the same tolerate-then-gate pattern via `check_faults_row`: once
committed it must keep showing the guarded KF >= unguarded KF and >=
always_off under every fault scenario, a bitwise-free healthy guard, and
a single-trace fault x guard grid.

So does the `noc_placement` row (benchmarks/fig_placement.py,
DESIGN.md §17) via `check_placement_row`: once committed it must keep
showing joint (bandwidth + relocation) control >= bandwidth-only mean
GPU IPC on its gate scenario, a bitwise-free disarmed placement lever,
and a single-trace control x placement grid.

    PYTHONPATH=src python -m benchmarks.check_bench [--grid smoke|full]

Exit code 0 = within tolerance, 1 = regression (message says which gate).
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks import bench_sweep

DEFAULT_MIN_SPEEDUP = 1.5  # absolute end-to-end floor
DEFAULT_FRAC = 0.25  # of the last committed row's end-to-end speedup
DEFAULT_MIN_STEADY = 0.4  # absolute steady floor (full grid; pre-§11 was 0.39)
DEFAULT_STEADY_FRAC = 0.5  # of the last committed row's steady speedup


def load_records(path: str) -> list:
    with open(path) as f:
        return json.load(f)


def last_committed_row(records: list, bench: str = "noc_sweep_serial_vs_batched"):
    """Last committed row of the REF-engine trajectory.

    Rows produced with `bench_sweep --backend pallas|pallas_arb` carry a
    `sim_backend` marker and are excluded here: they time a different
    engine (interpret-mode Pallas on CPU), so letting one become the
    baseline would silently relax — or falsely trip — every relative gate.
    Pre-PR-4 rows lack the field and are ref by construction.
    """
    rows = [
        r for r in records
        if r.get("bench") == bench and r.get("sim_backend", "ref") == "ref"
    ]
    if not rows:
        msg = f"no committed ref-engine {bench!r} row in the bench json"
        raise SystemExit(msg + "; run benchmarks.bench_sweep (non-smoke) first")
    return rows[-1]


def check_pallas_row(records: list) -> list:
    """Tolerate-then-gate the committed fused-engine sweep row.

    Same onboarding pattern as `check_ablation`: while no
    `sim_backend == "pallas"` row exists the gate is skipped with a note;
    once one lands it must document the fused engine's contract — a
    single-trace batched arm and a recorded `speedup_steady` (the honest
    serial-ref-vs-batched-pallas number; interpret mode on CPU, so only
    its presence and the trace count are gated, not its magnitude).
    """
    rows = [
        r for r in records
        if r.get("bench") == "noc_sweep_serial_vs_batched"
        and r.get("sim_backend") == "pallas"
    ]
    if not rows:
        print("pallas sweep: no committed sim_backend=pallas row yet — "
              "tolerated (run benchmarks.bench_sweep --backend pallas "
              "non-smoke to add one)")
        return []
    row = rows[-1]
    failures = []
    if row.get("batched_traces") != 1:
        failures.append(
            "pallas regression: committed fused-engine row traced simulate "
            f"{row.get('batched_traces')}x (contract: the one shared "
            "program per backend)"
        )
    if "speedup_steady" not in row:
        failures.append(
            "pallas regression: committed fused-engine row lacks "
            "speedup_steady (bench must record the honest steady number)"
        )
    return failures


def check_ledger_schema(records: list) -> list:
    """Validate every committed BENCH row against the ledger schema.

    Tolerate-then-gate along the ROW axis: rows written before the ledger
    (no `ledger_version`) only get the core check (bench/timestamp/backend
    present and typed) and a failing legacy row is tolerated with a note —
    rewriting history to satisfy a new schema is not this gate's job.
    Rows stamped by `repro.obs.ledger.append` are hard-gated on the full
    schema: a malformed stamped row means the single-append-path contract
    broke.
    """
    from repro.obs import ledger

    failures, legacy_bad = [], 0
    for i, row in enumerate(records):
        stamped = isinstance(row, dict) and "ledger_version" in row
        problems = ledger.validate_row(row)
        if not problems:
            continue
        if stamped:
            failures += [
                f"ledger schema: row {i} "
                f"({row.get('bench', '?')}): {p}" for p in problems
            ]
        else:
            legacy_bad += 1
    if legacy_bad:
        print(f"ledger schema: {legacy_bad} pre-ledger row(s) with core-"
              "schema gaps — tolerated (no ledger_version stamp)")
    return failures


def check_obs_row(records: list) -> list:
    """Tolerate-then-gate the committed `noc_obs` flight-recorder row.

    Same onboarding pattern as `check_ablation`: absent -> tolerated with
    a note; present -> it must document the probe contract — recorded
    probe overhead (steady-time ratio probes-on/off) and a single trace
    for each of the probes-off and probes-on programs.
    """
    rows = [r for r in records if r.get("bench") == "noc_obs"]
    if not rows:
        print("noc_obs: no committed flight-recorder row yet — tolerated "
              "(run benchmarks.noc_trace --record to add one)")
        return []
    row = rows[-1]
    failures = []
    overhead = row.get("probe_overhead_steady")
    if not isinstance(overhead, (int, float)) or overhead <= 0:
        failures.append(
            "obs regression: committed noc_obs row lacks a positive "
            f"probe_overhead_steady (got {overhead!r})"
        )
    for field in ("traces_off", "traces_on"):
        if row.get(field) != 1:
            failures.append(
                f"obs regression: committed noc_obs row has {field}="
                f"{row.get(field)!r} (contract: one compiled program per "
                "probe setting)"
            )
    return failures


def check_ablation(records: list) -> list:
    """Tolerate-then-gate the committed `noc_ablation` record.

    Mirrors the pre-PR-3 missing-field path: absent record -> tolerated
    (the ablation bench has simply never been run on this checkout);
    present record -> it must document the paper's predictor ordering
    (kf_beats_all) and the single-trace contract.
    """
    rows = [r for r in records if r.get("bench") == "noc_ablation"]
    if not rows:
        print("noc_ablation: no committed record yet — tolerated "
              "(run benchmarks.fig_ablation non-smoke to add one)")
        return []
    row = rows[-1]
    failures = []
    if row.get("traces", 1) != 1:
        failures.append(
            f"ablation regression: committed noc_ablation row traced "
            f"simulate {row.get('traces')}x (contract: 1)"
        )
    if row.get("kf_beats_all") is not True:
        failures.append(
            "ablation regression: committed noc_ablation row no longer "
            f"shows KF >= every naive predictor on {row.get('scenario')!r} "
            f"(margins: {row.get('margins')})"
        )
    return failures


def check_trace_replay_row(records: list) -> list:
    """Tolerate-then-gate the committed `noc_trace_replay` record.

    Absent record -> tolerated (the trace-replay bench has never been run
    on this checkout); present record -> it must document KF >= every
    naive predictor on the replayed trace, the single-trace contract, and
    a bitwise-green record->replay round trip.
    """
    rows = [r for r in records if r.get("bench") == "noc_trace_replay"]
    if not rows:
        print("noc_trace_replay: no committed record yet — tolerated "
              "(run benchmarks.fig_trace_replay non-smoke to add one)")
        return []
    row = rows[-1]
    failures = []
    if row.get("traces", 1) != 1:
        failures.append(
            f"trace-replay regression: committed noc_trace_replay row "
            f"traced simulate {row.get('traces')}x (contract: 1)"
        )
    if row.get("kf_beats_all") is not True:
        failures.append(
            "trace-replay regression: committed noc_trace_replay row no "
            "longer shows KF >= every naive predictor on the replayed "
            f"trace {row.get('source')!r} (margins: {row.get('margins')})"
        )
    if row.get("replay_bitwise") is not True:
        failures.append(
            "trace-replay regression: committed noc_trace_replay row's "
            "record->replay round trip was not bitwise-identical"
        )
    return failures


def check_faults_row(records: list) -> list:
    """Tolerate-then-gate the committed `noc_faults` record.

    Absent record -> tolerated (the fault-injection bench has never been
    run on this checkout); present record -> it must document the
    robustness contract (DESIGN.md §16): guarded KF >= unguarded KF and
    >= always_off under every fault scenario, the healthy guard-on/off
    pair bitwise-identical, and the fault x guard grid single-trace.
    """
    rows = [r for r in records if r.get("bench") == "noc_faults"]
    if not rows:
        print("noc_faults: no committed record yet — tolerated "
              "(run benchmarks.fig_faults non-smoke to add one)")
        return []
    row = rows[-1]
    failures = []
    if row.get("traces", 1) != 1:
        failures.append(
            f"faults regression: committed noc_faults row traced simulate "
            f"{row.get('traces')}x (contract: 1)"
        )
    if row.get("guard_beats_all") is not True:
        failures.append(
            "faults regression: committed noc_faults row no longer shows "
            "guarded KF >= unguarded KF and >= always_off under every "
            f"fault scenario (margins: {row.get('margins')})"
        )
    if row.get("healthy_bitwise") is not True:
        failures.append(
            "faults regression: committed noc_faults row's healthy "
            "guard-on run was not bitwise-equal to guard-off (arming the "
            "guard must be free on clean telemetry)"
        )
    return failures


def check_placement_row(records: list) -> list:
    """Tolerate-then-gate the committed `noc_placement` record.

    Absent record -> tolerated (the placement-control bench has never
    been run on this checkout); present record -> it must document the
    placement-layer contract (DESIGN.md §17): joint control >=
    bandwidth-only mean GPU IPC on the gate scenario, the identity pair
    (bandwidth control with vs without a carried placement stream)
    bitwise-identical, and the control x placement grid single-trace.
    """
    rows = [r for r in records if r.get("bench") == "noc_placement"]
    if not rows:
        print("noc_placement: no committed record yet — tolerated "
              "(run benchmarks.fig_placement non-smoke to add one)")
        return []
    row = rows[-1]
    failures = []
    if row.get("traces", 1) != 1:
        failures.append(
            f"placement regression: committed noc_placement row traced "
            f"simulate {row.get('traces')}x (contract: 1)"
        )
    if row.get("joint_beats_bandwidth") is not True:
        failures.append(
            "placement regression: committed noc_placement row no longer "
            "shows joint control >= bandwidth-only mean GPU IPC on "
            f"{row.get('gate_scenario')!r} (margins: {row.get('margins')})"
        )
    if row.get("identity_bitwise") is not True:
        failures.append(
            "placement regression: committed noc_placement row's "
            "bandwidth-control run carrying a placement stream was not "
            "bitwise-equal to the no-stream run (a disarmed lever must "
            "be free)"
        )
    return failures


def check(rec: dict, baseline: dict, min_speedup: float, frac: float,
          min_steady: float = DEFAULT_MIN_STEADY,
          steady_frac: float = DEFAULT_STEADY_FRAC,
          gate_steady: bool = True) -> list:
    """Return the list of violated gates (empty = pass).

    Baseline fields may be absent (pre-PR-3 rows): a missing field drops
    the relative term of its gate, leaving the absolute floor.
    """
    failures = []
    allowed = baseline.get("batched_traces", 1)
    got = rec["batched_traces"]
    if got > allowed:
        failures.append(
            f"trace regression: batched arm traced simulate {got}x "
            f"(committed row: {allowed}x)"
        )

    base_e2e = baseline.get("speedup_end_to_end")
    floor = (
        max(min_speedup, frac * base_e2e)
        if base_e2e is not None
        else min_speedup
    )
    speedup = rec["speedup_end_to_end"]
    if speedup < floor:
        failures.append(
            f"speedup regression: end-to-end {speedup}x < floor {floor:.2f}x "
            f"(committed row: {base_e2e}x, frac {frac}, abs min {min_speedup})"
        )

    if gate_steady:
        base_steady = baseline.get("speedup_steady")
        steady_floor = (
            max(min_steady, steady_frac * base_steady)
            if base_steady is not None
            else min_steady
        )
        committed = (
            f"committed row: {base_steady}x, frac {steady_frac}, "
            if base_steady is not None
            else "committed row predates speedup_steady, "
        )
        steady = rec["speedup_steady"]
        if steady < steady_floor:
            failures.append(
                f"steady-state regression: {steady}x < floor "
                f"{steady_floor:.2f}x ({committed}abs min {min_steady}) — "
                "the packed-lane cycle engine (DESIGN.md §11) is supposed "
                "to keep the padded program at parity with the dedicated "
                "traces"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", choices=("full", "smoke"), default="full",
                    help="full: default bench grid, all gates incl. steady; "
                         "smoke: tiny grid, steady gate skipped (noise)")
    ap.add_argument("--min-speedup", type=float, default=DEFAULT_MIN_SPEEDUP)
    ap.add_argument("--frac", type=float, default=DEFAULT_FRAC)
    ap.add_argument("--min-steady", type=float, default=DEFAULT_MIN_STEADY)
    ap.add_argument("--steady-frac", type=float, default=DEFAULT_STEADY_FRAC)
    ap.add_argument("--bench-json", default=bench_sweep.BENCH_PATH)
    args = ap.parse_args(argv)

    records = load_records(args.bench_json)
    baseline = last_committed_row(records)
    rec = bench_sweep.run(smoke=args.grid == "smoke")
    print(json.dumps(rec, indent=2))

    failures = check(
        rec, baseline, args.min_speedup, args.frac,
        min_steady=args.min_steady, steady_frac=args.steady_frac,
        gate_steady=args.grid == "full",
    )
    failures += check_ablation(records)
    failures += check_trace_replay_row(records)
    failures += check_faults_row(records)
    failures += check_placement_row(records)
    failures += check_pallas_row(records)
    failures += check_ledger_schema(records)
    failures += check_obs_row(records)
    if failures:
        for failure in failures:
            print(f"BENCH REGRESSION: {failure}", file=sys.stderr)
        return 1
    steady_note = (
        f", steady {rec['speedup_steady']}x" if args.grid == "full"
        else " (steady not gated on smoke)"
    )
    print(
        f"bench gate OK: {rec['batched_traces']} trace(s), "
        f"{rec['speedup_end_to_end']}x end-to-end{steady_note} (committed: "
        f"{baseline.get('speedup_end_to_end')}x on "
        f"{baseline['grid']['n_points']} points)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
