"""Perf-regression gate over the committed BENCH_noc.json trajectory.

Re-runs the sweep smoke grid (`bench_sweep.run(smoke=True)`) and fails if
the engine regressed versus the last committed `noc_sweep_serial_vs_batched`
row on either guarded axis:

  * trace count — the batched arm must not trace the simulator more often
    than the committed row did (1 since the S-padding refactor; the whole
    point of the engine is that the sweep is ONE compiled program);
  * end-to-end speedup — the smoke grid's serial-vs-batched speedup must
    clear an absolute floor AND a fraction of the committed row's speedup.
    The committed row is usually the full grid, whose per-point compile
    amortization is stronger than the smoke grid's, so the fraction is
    deliberately loose — this is a cliff detector (e.g. the jit-cache
    identity gotcha quietly rebatching the serial arm, or a retrace per
    point sneaking back in), not a 5%-noise tripwire.

`speedup_steady` is intentionally NOT gated: at smoke scale the steady
pass is milliseconds of scan work and swings 0.4-1.1x run to run, and the
S/V-padded program's ~2x steady cost on 2-subnet-only grids is a known,
documented trade (DESIGN.md §10, bench_sweep.run docstring) — gate it and
the gate flakes; watch the full-grid trajectory rows instead.

    PYTHONPATH=src python -m benchmarks.check_bench

Exit code 0 = within tolerance, 1 = regression (message says which gate).
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks import bench_sweep

DEFAULT_MIN_SPEEDUP = 1.5  # absolute floor for the smoke grid
DEFAULT_FRAC = 0.25  # of the last committed row's speedup


def last_committed_row(path: str, bench: str = "noc_sweep_serial_vs_batched"):
    with open(path) as f:
        records = json.load(f)
    rows = [r for r in records if r.get("bench") == bench]
    if not rows:
        msg = f"no committed {bench!r} row in {path}"
        raise SystemExit(msg + "; run benchmarks.bench_sweep (non-smoke) first")
    return rows[-1]


def check(rec: dict, baseline: dict, min_speedup: float, frac: float) -> list:
    """Return the list of violated gates (empty = pass)."""
    failures = []
    allowed = baseline.get("batched_traces", 1)
    got = rec["batched_traces"]
    if got > allowed:
        failures.append(
            f"trace regression: batched arm traced simulate {got}x "
            f"(committed row: {allowed}x)"
        )
    floor = max(min_speedup, frac * baseline["speedup_end_to_end"])
    speedup = rec["speedup_end_to_end"]
    if speedup < floor:
        failures.append(
            f"speedup regression: end-to-end {speedup}x < floor {floor:.2f}x "
            f"(committed row: {baseline['speedup_end_to_end']}x, "
            f"frac {frac}, abs min {min_speedup})"
        )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=DEFAULT_MIN_SPEEDUP)
    ap.add_argument("--frac", type=float, default=DEFAULT_FRAC)
    ap.add_argument("--bench-json", default=bench_sweep.BENCH_PATH)
    args = ap.parse_args(argv)

    baseline = last_committed_row(args.bench_json)
    rec = bench_sweep.run(smoke=True)
    print(json.dumps(rec, indent=2))

    failures = check(rec, baseline, args.min_speedup, args.frac)
    if failures:
        for failure in failures:
            print(f"BENCH REGRESSION: {failure}", file=sys.stderr)
        return 1
    print(
        f"bench gate OK: {rec['batched_traces']} trace(s), "
        f"{rec['speedup_end_to_end']}x end-to-end (committed: "
        f"{baseline['speedup_end_to_end']}x on "
        f"{baseline['grid']['n_points']} points)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
