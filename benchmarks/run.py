"""Benchmark aggregator: one section per paper table/figure + the TPU
adaptation A/B + kernel micro-benches.

    PYTHONPATH=src python -m benchmarks.run [--fast]

The NoC figures reproduce the paper's evaluation qualitatively (synthetic
workload profiles — DESIGN.md §2) and all run on the batched sweep engine
(DESIGN.md §4): one compiled program per network structure, every
(mode, workload, ratio, seed) point dispatched in lockstep batches.  The
roofline table comes from the dry-run artifacts in results/dryrun (run
repro.launch.dryrun first for the full 40-cell table).

Observability (DESIGN.md §14): each fig driver's own `main` takes
`--profile DIR` to capture jax.profiler traces of its compile and steady
phases, and `benchmarks/noc_trace.py` replays probes-on flight-recorder
captures (per-epoch occupancy / arbitration / MC-queue / KF-internals
timelines) for any workload or scenario.
"""
from __future__ import annotations

import argparse
import time


def _section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer epochs / one seed for the NoC sims")
    args = ap.parse_args(argv)
    epochs = 30 if args.fast else 60
    seeds = (0,) if args.fast else (0, 1, 2)

    t0 = time.time()

    _section("Fig 2/3 — IPC vs static VC allocation ratio")
    from benchmarks import fig2_3_vc_sweep
    res = fig2_3_vc_sweep.run(n_epochs=epochs, seeds=seeds)
    for wl, row in res.items():
        line = "  ".join(
            f"{r}: gpu={s['gpu_ipc']:.3f}±{s['gpu_ipc_std']:.3f} "
            f"cpu={s['cpu_ipc']:.3f}"
            for r, s in row.items())
        print(f"{wl:6s} {line}")

    _section("Fig 4 — dynamic traffic pattern (bursty GPU, stable CPU)")
    from benchmarks import fig4_traffic
    tr = fig4_traffic.run(n_epochs=epochs)
    gpu_cov = tr["gpu_inj_rate"].std() / max(tr["gpu_inj_rate"].mean(), 1e-9)
    cpu_cov = tr["cpu_push"].std() / max(tr["cpu_push"].mean(), 1e-9)
    print(f"gpu_inj CoV={gpu_cov:.3f}  cpu_push CoV={cpu_cov:.3f}  "
          f"bursty-vs-stable: {gpu_cov > 2 * cpu_cov}")

    _section("Figs 9/10/11 — four configurations")
    from benchmarks import fig9_10_11_configs
    res = fig9_10_11_configs.run(n_epochs=epochs, seeds=seeds)
    wls = list(res)
    for wl in wls:
        row = res[wl]
        print(f"{wl:5s} " + "  ".join(
            f"{m}: gpu={s['gpu_ipc']:.3f}±{s['gpu_ipc_std']:.3f} "
            f"lat={s['avg_latency']:.1f}"
            for m, s in row.items()))
    lat_wins = sum(res[w]["kf"]["avg_latency"]
                   <= res[w]["baseline"]["avg_latency"] for w in wls)
    gains = [res[w]["kf"]["gpu_ipc"] / max(res[w]["baseline"]["gpu_ipc"], 1e-9)
             - 1 for w in wls]
    print(f"KF latency wins: {lat_wins}/{len(wls)}; GPU IPC gain "
          f"mean {sum(gains)/len(gains):+.1%} max {max(gains):+.1%} "
          f"(paper: +7% mean, +19% max)")

    _section("Fig 12 — dynamic GPU IPC, fair vs KF")
    from benchmarks import fig12_dynamic_kf
    tr = fig12_dynamic_kf.run(n_epochs=max(epochs, 100), seeds=seeds)
    sl = slice(10, None)
    print(f"mean GPU IPC: fair {tr['fair_ipc'][sl].mean():.4f} "
          f"kf {tr['kf_ipc'][sl].mean():.4f}; "
          f"KF engaged {tr['kf_config'][sl].mean():.0%} of epochs")

    _section("Sweep engine — serial vs batched wall-clock")
    from benchmarks import bench_sweep
    rec = bench_sweep.run(smoke=args.fast)
    if not args.fast:
        bench_sweep.append_record(rec)
    print(f"serial {rec['serial_total_s']:.1f}s "
          f"(compile {rec['serial_compile_s']:.1f}s) vs batched "
          f"{rec['batched_total_s']:.1f}s "
          f"(compile {rec['batched_compile_s']:.1f}s): "
          f"{rec['speedup_end_to_end']:.1f}x end-to-end, "
          f"{rec['speedup_steady']:.1f}x steady-state")

    _section("Predictor ablation — KF vs naive predictors (DESIGN.md §12)")
    from benchmarks import fig_ablation
    ab = fig_ablation.run(**(fig_ablation.SMOKE if args.fast else {}))
    for sc, cells in ab["table"].items():
        print(f"{sc}: " + "  ".join(
            f"{p}={s['gpu_ipc']:.3f}" for p, s in cells.items()))
    verdict = fig_ablation.kf_verdict(ab["table"])
    print(f"kf_beats_all={verdict['kf_beats_all']} on "
          f"{verdict['scenario']} ({ab['traces']} trace)")

    _section("TPU adaptation — KF-arbitrated serving engine A/B")
    from benchmarks import kf_scheduler_ab
    res = kf_scheduler_ab.run()
    for mode, s in res.items():
        print(f"{mode:7s} ttft={s['mean_ttft']:.4f} "
              f"p90={s['p90_ttft']:.4f} lat={s['mean_latency']:.4f} "
              f"thr={s['throughput_tok_s']:.1f} "
              f"kf_on={s['kf_on_frac']:.2f}")

    _section("Fleet-KF bank — per-epoch filter-bank timings")
    from benchmarks import bench_fleet_kf
    for r in bench_fleet_kf.run():
        print(f"n={r['n_filters']:5d} epoch={r['epoch_us']:.1f}us "
              f"({r['ns_per_filter']:.0f}ns/filter)")

    _section("Kernel micro-benches (interpret mode)")
    from benchmarks import kernels_bench
    kernels_bench.main([])  # no --record: aggregator runs never append

    _section("Roofline table (from dry-run artifacts)")
    from benchmarks import roofline_table
    roofline_table.main()

    print(f"\n[benchmarks.run] done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
