"""Paper Fig. 4: dynamic traffic pattern — GPU injection is bursty, CPU
injection is stable; GPU stalls track injection bursts.

Emits the per-epoch traces (gpu injection rate, stall counters, IPC proxy)
that the KF consumes, for the PATH workload.  With `seeds` given, the seed
replicas run as one lockstep batch (optionally device-sharded via
`devices=N`) and the returned traces are seed-0's, matching the paper's
single-run figure while exercising the shared sweep engine.
"""
from __future__ import annotations

import numpy as np

from repro.core.noc.sim import (
    SWEEP_TILE,
    NoCConfig,
    run_workload,
    simulate_batch,
)


def run(workload: str = "PATH", n_epochs: int = 120,
        seeds: tuple[int, ...] | None = None, devices: int | None = None,
        **overrides):
    if seeds is not None or devices is not None:
        import jax

        seeds = seeds or (0,)
        cfgs = [NoCConfig(mode="baseline", n_epochs=n_epochs, seed=s,
                          **overrides)
                for s in seeds]
        batch_tile = None if devices is not None else SWEEP_TILE
        batch = simulate_batch(cfgs, workload,
                               batch_tile=batch_tile, devices=devices)
        res = jax.tree.map(lambda x: x[0], batch)
    else:
        res = run_workload("baseline", workload, n_epochs=n_epochs,
                           **overrides)
    c = res.counters
    return {
        "gpu_inj_rate": np.asarray(res.gpu_inj_rate),
        "gpu_ipc": np.asarray(res.gpu_ipc),
        "gpu_stall_icnt": np.asarray(c.gpu_stall_icnt),
        "gpu_stall_dram": np.asarray(c.gpu_stall_dram),
        "cpu_push": np.asarray(c.cpu_push),
    }


def main(argv=None):
    from benchmarks import _cli

    args = _cli.build_parser(__doc__).parse_args(argv)
    from repro.obs import profiling

    workload = _cli.registered_trace(args) or "PATH"
    tr = profiling.profiled_run(
        args.profile,
        lambda: run(workload=workload, devices=args.devices,
                    backend=args.backend, **_cli.shared_overrides(args)),
        label="fig4",
    )
    print("epoch,gpu_inj_rate,gpu_ipc,gpu_stall_icnt,gpu_stall_dram,cpu_push")
    for i in range(len(tr["gpu_ipc"])):
        print(f"{i},{tr['gpu_inj_rate'][i]:.4f},{tr['gpu_ipc'][i]:.4f},"
              f"{tr['gpu_stall_icnt'][i]},{tr['gpu_stall_dram'][i]},"
              f"{tr['cpu_push'][i]}")
    # claims: GPU bursty (high CoV), CPU stable (low CoV)
    gpu_cov = tr["gpu_inj_rate"].std() / max(tr["gpu_inj_rate"].mean(), 1e-9)
    cpu_cov = tr["cpu_push"].std() / max(tr["cpu_push"].mean(), 1e-9)
    print(f"# gpu_inj CoV={gpu_cov:.3f} cpu_push CoV={cpu_cov:.3f} "
          f"(claim: gpu >> cpu): {gpu_cov > 2 * cpu_cov}")
    return tr


if __name__ == "__main__":
    main()
