"""Paper Fig. 2/3: GPU and CPU IPC vs static VC allocation ratio.

Sweeps the [GPU:CPU] VC partition {1:3, 2:2, 3:1} (paper's x-axis) over the
four GPU workloads of Fig. 2/3 (PATH, LIB, STO, MUM; CPUs run the stable
omnetpp-like profile).  Claim to validate: GPU IPC rises with more GPU VCs;
CPU IPC barely moves (and can even dip when CPU packets pile into the MCs).

The whole grid (workloads x ratios x seeds) runs through `sim.sweep` as one
batched dispatch sharing a single compiled program; multi-seed replicas are
therefore nearly free, and every cell reports mean +- std across seeds.
`devices=N` shards the grid's batch axis data-parallel across devices
(the same dispatch `sim.sweep_sharded` uses).
"""
from __future__ import annotations

from repro.core.noc.sim import SweepSpec, summarize_seeds, sweep

WORKLOADS = ("PATH", "LIB", "STO", "MUM")
RATIOS = (1, 2, 3)   # GPU VCs out of 4
SEEDS = (0, 1, 2)


def run(n_epochs: int = 60, seeds: tuple[int, ...] = SEEDS,
        devices: int | None = None,
        workloads: tuple[str, ...] = WORKLOADS, **overrides) -> dict:
    specs = [
        SweepSpec("static", wl, static_gpu_vcs=g, seed=s)
        for wl in workloads for g in RATIOS for s in seeds
    ]
    rows = sweep(specs, n_epochs=n_epochs, devices=devices, **overrides)
    by_point = {
        (sp.workload, sp.static_gpu_vcs): [] for sp in specs
    }
    for sp, row in zip(specs, rows):
        by_point[(sp.workload, sp.static_gpu_vcs)].append(row)
    return {
        wl: {
            f"{g}:{4 - g}": summarize_seeds(by_point[(wl, g)])
            for g in RATIOS
        }
        for wl in workloads
    }


def main(argv=None):
    from benchmarks import _cli

    args = _cli.build_parser(__doc__).parse_args(argv)
    from repro.obs import profiling

    trace_wl = _cli.registered_trace(args)
    workloads = (trace_wl,) if trace_wl else WORKLOADS
    results = profiling.profiled_run(
        args.profile,
        lambda: run(devices=args.devices, backend=args.backend,
                    workloads=workloads, **_cli.shared_overrides(args)),
        label="fig2_3",
    )
    print("workload,ratio,gpu_ipc,gpu_ipc_std,cpu_ipc,cpu_ipc_std,avg_latency")
    for wl, row in results.items():
        for ratio, s in row.items():
            print(f"{wl},{ratio},{s['gpu_ipc']:.4f},{s['gpu_ipc_std']:.4f},"
                  f"{s['cpu_ipc']:.4f},{s['cpu_ipc_std']:.4f},"
                  f"{s['avg_latency']:.2f}")
    # headline claims
    for wl, row in results.items():
        gpu_up = row["3:1"]["gpu_ipc"] >= row["1:3"]["gpu_ipc"]
        print(f"# {wl}: GPU IPC rises with GPU VCs: {gpu_up}")
    return results


if __name__ == "__main__":
    main()
