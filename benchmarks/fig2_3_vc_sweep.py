"""Paper Fig. 2/3: GPU and CPU IPC vs static VC allocation ratio.

Sweeps the [GPU:CPU] VC partition {1:3, 2:2, 3:1} (paper's x-axis) over the
four GPU workloads of Fig. 2/3 (PATH, LIB, STO, MUM; CPUs run the stable
omnetpp-like profile).  Claim to validate: GPU IPC rises with more GPU VCs;
CPU IPC barely moves (and can even dip when CPU packets pile into the MCs).
"""
from __future__ import annotations

import json

from repro.core.noc.sim import run_workload, summarize

WORKLOADS = ("PATH", "LIB", "STO", "MUM")
RATIOS = (1, 2, 3)   # GPU VCs out of 4


def run(n_epochs: int = 60) -> dict:
    out = {}
    for wl in WORKLOADS:
        row = {}
        for g in RATIOS:
            res = run_workload("static", wl, static_gpu_vcs=g,
                               n_epochs=n_epochs)
            row[f"{g}:{4 - g}"] = summarize(res)
        out[wl] = row
    return out


def main():
    results = run()
    print("workload,ratio,gpu_ipc,cpu_ipc,avg_latency")
    for wl, row in results.items():
        for ratio, s in row.items():
            print(f"{wl},{ratio},{s['gpu_ipc']:.4f},{s['cpu_ipc']:.4f},"
                  f"{s['avg_latency']:.2f}")
    # headline claims
    for wl, row in results.items():
        gpu_up = row["3:1"]["gpu_ipc"] >= row["1:3"]["gpu_ipc"]
        print(f"# {wl}: GPU IPC rises with GPU VCs: {gpu_up}")
    return results


if __name__ == "__main__":
    main()
