"""Paper Figs. 9/10/11: CPU IPC, GPU IPC, packet latency across the four
network configurations (4-subnet, 2-subnet baseline, 2-subnet fair, KF).

Claims validated:
  * KF reduces packet latency vs baseline on ALL workloads (Fig. 11);
  * 4-subnet hurts GPU IPC (can't borrow idle bandwidth);
  * fair ~ baseline; KF >= fair on GPU IPC; CPU IPC unaffected (±5%).
"""
from __future__ import annotations

from repro.core.noc.sim import run_workload, summarize

WORKLOADS = ("PATH", "LIB", "STO", "MUM", "BFS", "LPS")
MODES = ("4subnet", "baseline", "fair", "kf")


def run(n_epochs: int = 60) -> dict:
    out = {}
    for wl in WORKLOADS:
        out[wl] = {m: summarize(run_workload(m, wl, n_epochs=n_epochs))
                   for m in MODES}
    return out


def main():
    results = run()
    print("workload,mode,gpu_ipc,cpu_ipc,avg_latency,kf_on_frac")
    for wl, row in results.items():
        for m, s in row.items():
            print(f"{wl},{m},{s['gpu_ipc']:.4f},{s['cpu_ipc']:.4f},"
                  f"{s['avg_latency']:.2f},{s['kf_on_frac']:.2f}")
    lat_wins = sum(results[w]["kf"]["avg_latency"]
                   <= results[w]["baseline"]["avg_latency"]
                   for w in WORKLOADS)
    gpu_gains = [results[w]["kf"]["gpu_ipc"]
                 / max(results[w]["baseline"]["gpu_ipc"], 1e-9) - 1
                 for w in WORKLOADS]
    cpu_moves = [abs(results[w]["kf"]["cpu_ipc"]
                     / max(results[w]["baseline"]["cpu_ipc"], 1e-9) - 1)
                 for w in WORKLOADS]
    print(f"# KF latency <= baseline on {lat_wins}/{len(WORKLOADS)} workloads")
    print(f"# KF GPU IPC gain: mean {sum(gpu_gains)/len(gpu_gains):+.1%}, "
          f"max {max(gpu_gains):+.1%} (paper: ~+7% mean, up to +19%)")
    print(f"# CPU IPC max |change| {max(cpu_moves):.1%} "
          f"(paper: unaffected)")
    return results


if __name__ == "__main__":
    main()
