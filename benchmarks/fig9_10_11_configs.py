"""Paper Figs. 9/10/11: CPU IPC, GPU IPC, packet latency across the four
network configurations (4-subnet, 2-subnet baseline, 2-subnet fair, KF).

Claims validated:
  * KF reduces packet latency vs baseline on ALL workloads (Fig. 11);
  * 4-subnet hurts GPU IPC (can't borrow idle bandwidth);
  * fair ~ baseline; KF >= fair on GPU IPC; CPU IPC unaffected (±5%).

All (workload, mode, seed) rows go through `sim.sweep`: since the
S-padding refactor ALL four modes — 4-subnet included — share the one
compiled program (mode and subnet structure are traced policy tensors);
rows execute as batched lockstep dispatches, and each cell reports mean
+- std across seeds.  `devices=N` shards the batch axis across devices.
"""
from __future__ import annotations

from repro.core.noc.sim import SweepSpec, summarize_seeds, sweep

WORKLOADS = ("PATH", "LIB", "STO", "MUM", "BFS", "LPS")
MODES = ("4subnet", "baseline", "fair", "kf")
SEEDS = (0, 1, 2)


def run(n_epochs: int = 60, seeds: tuple[int, ...] = SEEDS,
        devices: int | None = None,
        workloads: tuple[str, ...] = WORKLOADS, **overrides) -> dict:
    specs = [
        SweepSpec(m, wl, seed=s)
        for wl in workloads for m in MODES for s in seeds
    ]
    rows = sweep(specs, n_epochs=n_epochs, devices=devices, **overrides)
    by_point: dict[tuple[str, str], list] = {}
    for sp, row in zip(specs, rows):
        by_point.setdefault((sp.workload, sp.mode), []).append(row)
    return {
        wl: {m: summarize_seeds(by_point[(wl, m)]) for m in MODES}
        for wl in workloads
    }


def main(argv=None):
    from benchmarks import _cli

    args = _cli.build_parser(__doc__).parse_args(argv)
    from repro.obs import profiling

    trace_wl = _cli.registered_trace(args)
    workloads = (trace_wl,) if trace_wl else WORKLOADS
    results = profiling.profiled_run(
        args.profile,
        lambda: run(devices=args.devices, backend=args.backend,
                    workloads=workloads, **_cli.shared_overrides(args)),
        label="fig9_10_11",
    )
    print("workload,mode,gpu_ipc,gpu_ipc_std,cpu_ipc,avg_latency,kf_on_frac")
    for wl, row in results.items():
        for m, s in row.items():
            print(f"{wl},{m},{s['gpu_ipc']:.4f},{s['gpu_ipc_std']:.4f},"
                  f"{s['cpu_ipc']:.4f},{s['avg_latency']:.2f},"
                  f"{s['kf_on_frac']:.2f}")
    lat_wins = sum(results[w]["kf"]["avg_latency"]
                   <= results[w]["baseline"]["avg_latency"]
                   for w in workloads)
    gpu_gains = [results[w]["kf"]["gpu_ipc"]
                 / max(results[w]["baseline"]["gpu_ipc"], 1e-9) - 1
                 for w in workloads]
    cpu_moves = [abs(results[w]["kf"]["cpu_ipc"]
                     / max(results[w]["baseline"]["cpu_ipc"], 1e-9) - 1)
                 for w in workloads]
    print(f"# KF latency <= baseline on {lat_wins}/{len(workloads)} workloads")
    print(f"# KF GPU IPC gain: mean {sum(gpu_gains)/len(gpu_gains):+.1%}, "
          f"max {max(gpu_gains):+.1%} (paper: ~+7% mean, up to +19%)")
    print(f"# CPU IPC max |change| {max(cpu_moves):.1%} "
          f"(paper: unaffected)")
    return results


if __name__ == "__main__":
    main()
