"""TPU-adaptation A/B: the KF-arbitrated serving engine vs static policies.

The serving-layer instantiation of the paper (DESIGN.md §3): prefill is the
bursty bandwidth class, decode the steady latency class; the KF predicts
decode pressure and switches the token-budget split + interleave pattern
(50/50 P,D  <->  75/25 P,P,D) under the paper's hysteresis rules.

Reports TTFT / latency / throughput for rr, static-boost, and kf modes on
the bursty workload — the Fig. 9/10/11 analogue for the TPU system.
"""
from __future__ import annotations

import jax

import repro.configs as configs
from repro.models import lm
from repro.serve import batching
from repro.serve.engine import Engine, EngineConfig

MODES = ("rr", "static", "kf")


def run(arch: str = "llama3.2-3b", n_requests: int = 48, seed: int = 0):
    cfg = configs.smoke(arch)
    params, _ = lm.make_lm(jax.random.PRNGKey(0), cfg)
    wl = batching.WorkloadConfig(
        n_requests=n_requests, mean_prompt=40, mean_gen=10,
        burst_rate=6.0, calm_rate=0.2, seed=seed)
    out = {}
    for mode in MODES:
        ecfg = EngineConfig(mode=mode, max_slots=4, max_len=96,
                            budget_tokens=96, warmup_iters=3)
        eng = Engine(params, cfg, ecfg, seed=seed)
        out[mode] = eng.run(batching.generate(wl), max_iters=2000).summary()
    return out


def main():
    results = run()
    print("mode,n_finished,mean_ttft,p90_ttft,mean_latency,"
          "throughput_tok_s,kf_on_frac")
    for mode, s in results.items():
        print(f"{mode},{s['n_finished']},{s['mean_ttft']:.4f},"
              f"{s['p90_ttft']:.4f},{s['mean_latency']:.4f},"
              f"{s['throughput_tok_s']:.2f},{s['kf_on_frac']:.2f}")
    kf, rr = results["kf"], results["rr"]
    print(f"# kf vs rr: mean_latency {kf['mean_latency'] / rr['mean_latency'] - 1:+.1%}, "
          f"throughput {kf['throughput_tok_s'] / rr['throughput_tok_s'] - 1:+.1%}")
    return results


if __name__ == "__main__":
    main()
