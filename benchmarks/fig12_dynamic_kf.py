"""Paper Fig. 12: per-epoch GPU performance with and without KF-assisted
allocation, plus the KF output signal trace.

Claim: in the epochs where 2-subnet-fair dips (GPU burst under-provisioned),
the KF run holds IPC up, and the dips align with KF signal = 1.

Both arms and every seed replica run in ONE `simulate_batch` dispatch (fair
and kf differ only in traced policy tensors) on the standard `SWEEP_TILE`
tiling, so the dispatch reuses the same executable as the Fig. 2/3 and
9/10/11 sweeps; per-epoch IPC traces are averaged across seeds,
signal/config traces come from the first seed.  `devices=N` shards the
batch across devices instead.
"""
from __future__ import annotations

import numpy as np

from repro.core.noc.sim import SWEEP_TILE, NoCConfig, simulate_batch

SEEDS = (0, 1, 2)


def run(workload: str = "STO", n_epochs: int = 120,
        seeds: tuple[int, ...] = SEEDS, devices: int | None = None,
        **overrides):
    cfgs = [NoCConfig(mode=m, n_epochs=n_epochs, seed=s, **overrides)
            for m in ("fair", "kf") for s in seeds]
    batch_tile = None if devices is not None else SWEEP_TILE
    res = simulate_batch(cfgs, workload, batch_tile=batch_tile,
                         devices=devices)
    n = len(seeds)
    fair_ipc = np.asarray(res.gpu_ipc[:n])
    kf_ipc = np.asarray(res.gpu_ipc[n:])
    return {
        "fair_ipc": fair_ipc.mean(axis=0),
        "kf_ipc": kf_ipc.mean(axis=0),
        "fair_ipc_std": fair_ipc.std(axis=0),
        "kf_ipc_std": kf_ipc.std(axis=0),
        # discrete traces are per-seed; report the first seed's trajectory
        "kf_signal": np.asarray(res.kf_signal[n]),
        "kf_config": np.asarray(res.applied_config[n]),
    }


def main(argv=None):
    from benchmarks import _cli

    args = _cli.build_parser(__doc__).parse_args(argv)
    from repro.obs import profiling

    workload = _cli.registered_trace(args) or "STO"
    tr = profiling.profiled_run(
        args.profile,
        lambda: run(workload=workload, devices=args.devices,
                    backend=args.backend, **_cli.shared_overrides(args)),
        label="fig12",
    )
    print("epoch,fair_gpu_ipc,kf_gpu_ipc,kf_signal,applied_config")
    for i in range(len(tr["fair_ipc"])):
        print(f"{i},{tr['fair_ipc'][i]:.4f},{tr['kf_ipc'][i]:.4f},"
              f"{tr['kf_signal'][i]},{tr['kf_config'][i]}")
    sl = slice(10, None)
    mean_fair = tr["fair_ipc"][sl].mean()
    mean_kf = tr["kf_ipc"][sl].mean()
    # IPC specifically in fair's WORST decile of epochs (the dips)
    dips = np.argsort(tr["fair_ipc"][sl])[: max(len(tr["fair_ipc"][sl]) // 10, 1)]
    dip_gain = tr["kf_ipc"][sl][dips].mean() / max(
        tr["fair_ipc"][sl][dips].mean(), 1e-9) - 1
    print(f"# mean GPU IPC: fair {mean_fair:.4f} kf {mean_kf:.4f} "
          f"({mean_kf / mean_fair - 1:+.1%})")
    print(f"# IPC in fair's dip epochs: KF {dip_gain:+.1%} "
          f"(claim: KF avoids the dips)")
    print(f"# KF engaged in {tr['kf_config'][sl].mean():.0%} of epochs")
    return tr


if __name__ == "__main__":
    main()
