"""Flight-recorder replay: probe captures -> per-epoch ASCII/CSV timelines.

Runs any workload or scenario from the traffic library with the probes on
(`sim.simulate_with_trace`, DESIGN.md §14) and renders the capture as a
per-epoch timeline: occupancy heat per subnet, arbitration grant/deny,
MC queue depth, and the KF's decision annotations (observation, innovation,
gain, one-step prediction, emitted signal, applied config) — the "why did
the KF flip the VC allocation at epoch e" view the paper's Fig. 4/12
narrative is built on.

    PYTHONPATH=src python -m benchmarks.noc_trace [--workload SHIFT_PATH_BFS]
        [--mode kf] [--epochs 24] [--epoch-len 200] [--seed 0]
        [--backend ref|pallas|pallas_arb] [--csv] [--save F.npz] [--load F.npz]

Special modes:

  --check    CI self-validation: tiny probes-on capture, invariant checks,
             save/load round-trip, both renderers.  Exit 0 = OK.
  --record   Measure the probe overhead (steady-state wall-clock ratio
             probes-on / probes-off) and append a `noc_obs` ledger row to
             BENCH_noc.json (gated by benchmarks/check_bench.py).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.noc import sim
from repro.obs import ledger, probes

HEAT = " .:-=+*#%@"

# capture metadata keys stored alongside the SimTrace arrays in the npz
META_KEYS = ("workload", "mode", "n_epochs", "epoch_len", "seed", "backend")


def capture(workload: str = "SHIFT_PATH_BFS", mode: str = "kf",
            n_epochs: int = 24, epoch_len: int = 200, seed: int = 0,
            backend: str = "ref", faults: str | None = None,
            guard: bool = False, placement: str | None = None,
            control: str = "bandwidth") -> dict:
    """Probes-on run -> flat dict of numpy arrays + run metadata."""
    cfg = sim.NoCConfig(mode=mode, n_epochs=n_epochs, epoch_len=epoch_len,
                        seed=seed, faults=faults, guard=guard,
                        placement=placement, control=control)
    res, trace = sim.simulate_with_trace(cfg, workload, backend=backend)
    cap = {f: np.asarray(v) for f, v in zip(sim.SimTrace._fields, trace)}
    cap["kf_signal"] = np.asarray(res.kf_signal)
    cap["applied_config"] = np.asarray(res.applied_config)
    cap["gpu_ipc"] = np.asarray(res.gpu_ipc)
    cap["avg_latency"] = np.asarray(res.avg_latency)
    cap.update(workload=workload, mode=mode, n_epochs=n_epochs,
               epoch_len=epoch_len, seed=seed, backend=backend)
    return cap


def save(cap: dict, path: str) -> None:
    np.savez(path, **cap)


def load(path: str) -> dict:
    with np.load(path, allow_pickle=False) as f:
        cap = {k: f[k] for k in f.files}
    for k in META_KEYS:  # 0-d string/int arrays back to scalars
        if k in cap:
            cap[k] = cap[k].item() if cap[k].ndim == 0 else cap[k]
    return cap


def _occ_frac(cap: dict) -> np.ndarray:
    """(E, S) mean buffer occupancy as a fraction of capacity."""
    occ = cap["occ_sum"]                      # (E, S, R, P, V)
    _, S, R, P, V = occ.shape
    # sum over cycles of count / (cycles * buffers * depth); depth B is not
    # in the capture, so normalize by the observed per-buffer ceiling
    per_buf = occ.sum(axis=(2, 3, 4)) / (cap["epoch_len"] * R * P * V)
    return per_buf  # mean flits per buffer per cycle (0..B)


def render_ascii(cap: dict) -> list:
    """One line per epoch: subnet occupancy heat + KF decision annotations."""
    frac = _occ_frac(cap)
    depth_est = max(float(frac.max()), 1e-9)
    E, S = frac.shape
    has_faults = "faults_active" in cap  # pre-§16 captures lack the channels
    has_place = "place_cls" in cap       # pre-§17 captures lack the channel
    lines = [
        f"# workload={cap['workload']} mode={cap['mode']} "
        f"epochs={cap['n_epochs']} epoch_len={cap['epoch_len']} "
        f"seed={cap['seed']} backend={cap['backend']}",
        "#  ep |occ/subnet| grant  deny mcqMax | z(dram,push,icnt) "
        "innov0   gain0  x_pred sig cfg"
        + (" | flt rej rst ok     nis" if has_faults else "")
        + (" |  mv gpu" if has_place else ""),
    ]
    for e in range(E):
        heat = "".join(
            HEAT[min(int(frac[e, s] / depth_est * (len(HEAT) - 1)),
                     len(HEAT) - 1)]
            for s in range(S)
        )
        z = cap["z_obs"][e]
        fault_cols = ""
        if has_faults:
            # the fault -> reject -> reset -> recover story, one glyph each
            fault_cols = (
                f" | {'F' if cap['faults_active'][e] else '.':>3s}"
                f" {'R' if cap['kf_rejected'][e] else '.':>3s}"
                f" {'*' if cap['kf_reset'][e] else '.':>3s}"
                f" {'y' if cap['kf_healthy'][e] else 'n':>2s}"
                f" {float(cap['kf_nis'][e]):7.2f}"
            )
        place_cols = ""
        if has_place:
            # relocation timeline (DESIGN.md §17): tiles whose class moved
            # vs the previous epoch's plan, and the GPU tile count ('M'
            # marks a migration epoch)
            moves = (
                0 if e == 0
                else int((cap["place_cls"][e] != cap["place_cls"][e - 1]).sum())
            )
            n_gpu = int((cap["place_cls"][e] == 1).sum())
            place_cols = (
                f" | {('M' + str(moves)) if moves else '.':>3s} {n_gpu:3d}"
            )
        lines.append(
            f"{e:5d} |{heat:^10s}| {int(cap['arb_grant'][e].sum()):6d}"
            f" {int(cap['arb_deny'][e].sum()):5d}"
            f" {int(cap['mcq_max'][e].max()):6d} |"
            f" ({z[0]:+.2f},{z[1]:+.2f},{z[2]:+.2f})"
            f" {cap['kf_innovation'][e][0]:+.3f}"
            f" {cap['kf_gain'][e][0]:7.3f}"
            f" {cap['kf_x_pred'][e]:+.3f}"
            f" {int(cap['kf_signal'][e]):3d}"
            f" {int(cap['applied_config'][e]):3d}"
            + fault_cols
            + place_cols
        )
    return lines


def render_csv(cap: dict) -> list:
    """Machine-readable per-epoch rows (same quantities as the ASCII view)."""
    has_faults = "faults_active" in cap  # pre-§16 captures lack the channels
    has_place = "place_cls" in cap       # pre-§17 captures lack the channel
    cols = (
        ["epoch", "occ_sum", "arb_grant", "arb_deny", "mcq_sum", "mcq_max"]
        + [f"z_{i}" for i in range(3)]
        + [f"innovation_{i}" for i in range(3)]
        + [f"gain_{i}" for i in range(3)]
        + ["cov_trace", "x_pred", "kf_signal", "applied_config",
           "gpu_ipc", "avg_latency"]
        + (["faults_active", "kf_nis", "kf_rejected", "kf_reset",
            "kf_healthy"] if has_faults else [])
        + (["place_moves", "place_gpu_tiles"] if has_place else [])
    )
    lines = [",".join(cols)]
    for e in range(int(cap["n_epochs"])):
        row = (
            [e, int(cap["occ_sum"][e].sum()), int(cap["arb_grant"][e].sum()),
             int(cap["arb_deny"][e].sum()), int(cap["mcq_sum"][e].sum()),
             int(cap["mcq_max"][e].max())]
            + [float(v) for v in cap["z_obs"][e]]
            + [float(v) for v in cap["kf_innovation"][e]]
            + [float(v) for v in cap["kf_gain"][e]]
            + [float(cap["kf_cov_trace"][e]), float(cap["kf_x_pred"][e]),
               int(cap["kf_signal"][e]), int(cap["applied_config"][e]),
               float(cap["gpu_ipc"][e]), float(cap["avg_latency"][e])]
            + ([int(cap["faults_active"][e]), float(cap["kf_nis"][e]),
                int(cap["kf_rejected"][e]), int(cap["kf_reset"][e]),
                int(cap["kf_healthy"][e])] if has_faults else [])
            + ([0 if e == 0 else
                int((cap["place_cls"][e] != cap["place_cls"][e - 1]).sum()),
                int((cap["place_cls"][e] == 1).sum())] if has_place else [])
        )
        lines.append(",".join(str(v) for v in row))
    return lines


def check(save_path: str | None = None) -> int:
    """CI self-validation: capture, invariants, round-trip, renderers."""
    sim.reset_trace_count()
    cap = capture(workload="PATH", n_epochs=4, epoch_len=60)
    assert sim.trace_count() == 1, (
        f"probes-on capture traced {sim.trace_count()}x (contract: 1)"
    )
    E, L = int(cap["n_epochs"]), int(cap["epoch_len"])
    occ = cap["occ_sum"]
    assert occ.min() >= 0 and occ.max() <= L * 64, "occupancy out of bounds"
    assert cap["mcq_max"].min() >= 0, "negative MC queue depth"
    assert (cap["arb_grant"] >= 0).all() and (cap["arb_deny"] >= 0).all()
    assert np.isfinite(cap["kf_gain"]).all(), "non-finite Kalman gain"
    # the KF member's signal is the binarized one-step prediction
    assert (
        (cap["kf_x_pred"] > 0.0).astype(np.int32) == cap["kf_signal"]
    ).all(), "kf_signal inconsistent with one-step prediction"

    path = save_path or "probe_capture.npz"
    save(cap, path)
    cap2 = load(path)
    for k, v in cap.items():
        np.testing.assert_array_equal(np.asarray(cap2[k]), np.asarray(v),
                                      err_msg=f"round-trip mismatch: {k}")
    a_lines, c_lines = render_ascii(cap2), render_csv(cap2)
    assert len(a_lines) == E + 2 and len(c_lines) == E + 1
    print("\n".join(a_lines))
    print(f"noc_trace check OK ({path}, {E} epochs)")
    return 0


def record(backend: str = "ref") -> dict:
    """Measure probe overhead and append the `noc_obs` ledger row."""
    from benchmarks.bench_sweep import append_record

    cfg = sim.NoCConfig(mode="kf", n_epochs=8, epoch_len=100)
    wl = "SHIFT_PATH_BFS"

    def steady(fn):
        import jax

        jax.block_until_ready(fn())  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    sim.reset_trace_count()
    t_off = steady(lambda: sim.simulate(cfg, wl, backend=backend))
    traces_off = sim.trace_count()
    sim.reset_trace_count()
    res_trace = []
    t_on = steady(
        lambda: res_trace.append(
            sim.simulate_with_trace(cfg, wl, backend=backend)
        ) or res_trace[-1]
    )
    traces_on = sim.trace_count()
    _, trace = res_trace[-1]

    import jax

    rec = {
        "bench": "noc_obs",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "sim_backend": backend,
        "workload": wl,
        "n_epochs": cfg.n_epochs,
        "epoch_len": cfg.epoch_len,
        "config_hash": ledger.config_hash(cfg),
        "steady_off_s": round(t_off, 4),
        "steady_on_s": round(t_on, 4),
        "probe_overhead_steady": round(t_on / max(t_off, 1e-9), 3),
        "traces_off": traces_off,
        "traces_on": traces_on,
        "probe_summary": probes.summarize_trace(trace),
    }
    append_record(rec)
    print(f"noc_obs row appended: overhead {rec['probe_overhead_steady']}x "
          f"(off {t_off:.3f}s, on {t_on:.3f}s), "
          f"traces off/on {traces_off}/{traces_on}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Replay NoC/KF flight-recorder captures (DESIGN.md §14)"
    )
    ap.add_argument("--workload", default="SHIFT_PATH_BFS",
                    help="any PROFILES or SCENARIOS name")
    ap.add_argument("--mode", default="kf")
    ap.add_argument("--epochs", type=int, default=24)
    ap.add_argument("--epoch-len", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="ref",
                    choices=("ref", "pallas", "pallas_arb"),
                    help="cycle engine; all bitwise-identical, incl. probes")
    ap.add_argument("--faults", metavar="NAME", default=None,
                    help="inject a registered fault scenario (DESIGN.md §16)"
                         " and render the fault/reject/reset/recover columns")
    ap.add_argument("--guard", action="store_true",
                    help="arm the self-healing KF guard (innovation gate +"
                         " watchdog + fair-split fallback)")
    ap.add_argument("--placement", metavar="NAME", default=None,
                    help="apply a registered placement scenario "
                         "(DESIGN.md §17) and render the relocation-timeline"
                         " columns")
    ap.add_argument("--control", default="bandwidth",
                    choices=("bandwidth", "placement", "joint"),
                    help="which levers the KF signal may pull: VC bandwidth"
                         " boosts, placement relocation, or both")
    ap.add_argument("--csv", action="store_true",
                    help="emit CSV rows instead of the ASCII timeline")
    ap.add_argument("--save", metavar="F.npz", help="save the capture")
    ap.add_argument("--load", metavar="F.npz",
                    help="render a saved capture instead of simulating")
    ap.add_argument("--check", action="store_true",
                    help="CI self-validation (tiny capture + invariants)")
    ap.add_argument("--record", action="store_true",
                    help="append the noc_obs probe-overhead ledger row")
    args = ap.parse_args(argv)

    if args.check:
        return check(save_path=args.save)
    if args.record:
        record(backend=args.backend)
        return 0

    if args.load:
        cap = load(args.load)
    else:
        cap = capture(workload=args.workload, mode=args.mode,
                      n_epochs=args.epochs, epoch_len=args.epoch_len,
                      seed=args.seed, backend=args.backend,
                      faults=args.faults, guard=args.guard,
                      placement=args.placement, control=args.control)
    if args.save:
        save(cap, args.save)
    lines = render_csv(cap) if args.csv else render_ascii(cap)
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    sys.exit(main())
