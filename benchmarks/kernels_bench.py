"""Kernel micro-benches: wall time of the Pallas kernels (interpret mode on
CPU — correctness-shaped timings, not TPU perf) vs their jnp oracles, plus
the kf_bank fleet-scale batch sweep."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.flash_attn import ref as fa_ref
from repro.kernels.kf_bank import ops as kf_ops
from repro.kernels.mamba_scan import ops as ms_ops
from repro.kernels.mamba_scan import ref as ms_ref


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main():
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    # flash attention
    q = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    t_kern = _time(lambda: fa_ops.flash_attention(
        q, k, v, block_q=128, block_k=128))
    t_ref = _time(lambda: fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)))
    print(f"flash_attn_512_interp,{t_kern:.0f},ref={t_ref:.0f}us")

    # mamba scan
    a = jax.random.uniform(key, (2, 256, 64, 16), jnp.float32, 0.9, 0.999)
    b = jax.random.normal(key, (2, 256, 64, 16), jnp.float32)
    h0 = jnp.zeros((2, 64, 16))
    t_kern = _time(lambda: ms_ops.mamba_chunk_scan(a, b, h0, chunk=64,
                                                   block_d=64))
    t_ref = _time(lambda: ms_ref.scan_ref(a, b, h0))
    print(f"mamba_scan_256_interp,{t_kern:.0f},ref={t_ref:.0f}us")

    # kf bank: fleet sizes (one filter per link x class x pod)
    for n in (1024, 16384, 131072):
        x = jnp.zeros((n,))
        p = jnp.ones((n,))
        z = jax.random.normal(key, (n, 3))
        h = jnp.ones((3,))
        r = jnp.full((3,), 0.2)
        t = _time(lambda: kf_ops.kf_bank_step(x, p, z, h, r))
        print(f"kf_bank_{n},{t:.0f},filters_per_s={n / t * 1e6:.2e}")


if __name__ == "__main__":
    main()
