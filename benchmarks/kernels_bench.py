"""Kernel micro-benches: wall time of the Pallas kernels (interpret mode on
CPU — correctness-shaped timings, not TPU perf) vs their jnp oracles, plus
the kf_bank fleet-scale batch sweep and the noc_cycle engines (arbitration
lane kernel and the fused full-cycle kernel vs the dense ref engine).

`--record` appends a `noc_cycle_kernels` row to BENCH_noc.json so the
kernel-vs-ref trajectory is tracked alongside the sweep records.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc import router as rt
from repro.core.noc import sim as noc_sim
from repro.core.noc.traffic import PROFILES
from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.flash_attn import ref as fa_ref
from repro.kernels.kf_bank import ops as kf_ops
from repro.kernels.mamba_scan import ops as ms_ops
from repro.kernels.mamba_scan import ref as ms_ref


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _arb_inputs(lead=(4, 36), P=5, V=4, B=4):
    rng = np.random.default_rng(0)
    PV = P * V
    gm = jnp.asarray(rng.random(lead[:-1] + (1, V)) < 0.7)
    cm = jnp.asarray(rng.random(lead[:-1] + (1, V)) < 0.7)
    return dict(
        valid=jnp.asarray(rng.random(lead + (PV,)) < 0.5),
        cls=jnp.asarray(rng.integers(0, 2, lead + (PV,)), jnp.int32),
        out_port=jnp.asarray(rng.integers(0, P, lead + (PV,)), jnp.int32),
        rr_ptr=jnp.asarray(rng.integers(0, PV, lead + (P,)), jnp.int32),
        down_count=jnp.asarray(
            rng.integers(0, B + 1, lead + (P, V)), jnp.int32
        ),
        down_exists=jnp.asarray(rng.random(lead + (P,)) < 0.8),
        gpu_vc_mask=jnp.broadcast_to(gm, lead + (V,)),
        cpu_vc_mask=jnp.broadcast_to(cm, lead + (V,)),
        sa_pref=jnp.asarray(rng.integers(-1, 2, lead), jnp.int32),
        accept=jnp.asarray(rng.random(lead) < 0.7),
        active=jnp.asarray(rng.random(lead) < 0.9),
    )


def noc_cycle_entries() -> dict:
    """Time the noc_cycle engines at the paper's shapes (S=4, R=36).

    * arbitration-only: `router.arbitrate` (dense oracle) vs
      `ops.arbitrate_lanes` (lane kernel; interpret mode off-TPU);
    * fused full cycle: `simulate` steady-state per backend — the dense ref
      engine vs one `fused_cycle_kernel` launch per simulated cycle.
    """
    from repro.kernels.noc_cycle import ops as noc_ops

    mode = "compiled" if jax.default_backend() == "tpu" else "interp"
    inp = _arb_inputs()
    t_arb_ref = _time(jax.jit(lambda: rt.arbitrate(**inp, depth=4)))
    t_arb_lanes = _time(jax.jit(lambda: noc_ops.arbitrate_lanes(
        **inp, depth=4)))

    cfg = noc_sim.NoCConfig(mode="static", static_gpu_vcs=3,
                            n_epochs=4, epoch_len=100)
    n_cycles = cfg.n_epochs * cfg.epoch_len
    prof = PROFILES["PATH"]
    t_sim = {
        be: _time(lambda be=be: noc_sim.simulate(cfg, prof, backend=be))
        for be in ("ref", "pallas")
    }
    return {
        "mode": mode,
        "arb_shapes": "(4,36) lanes",
        "arb_ref_us": round(t_arb_ref, 1),
        "arb_lanes_us": round(t_arb_lanes, 1),
        "sim_cycles": n_cycles,
        "sim_ref_us": round(t_sim["ref"], 1),
        "sim_fused_us": round(t_sim["pallas"], 1),
        "fused_us_per_cycle": round(t_sim["pallas"] / n_cycles, 2),
        "fused_vs_ref": round(t_sim["ref"] / max(t_sim["pallas"], 1e-9), 3),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help="append a noc_cycle_kernels row to BENCH_noc.json")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    # flash attention
    q = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    t_kern = _time(lambda: fa_ops.flash_attention(
        q, k, v, block_q=128, block_k=128))
    t_ref = _time(lambda: fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)))
    print(f"flash_attn_512_interp,{t_kern:.0f},ref={t_ref:.0f}us")

    # mamba scan
    a = jax.random.uniform(key, (2, 256, 64, 16), jnp.float32, 0.9, 0.999)
    b = jax.random.normal(key, (2, 256, 64, 16), jnp.float32)
    h0 = jnp.zeros((2, 64, 16))
    t_kern = _time(lambda: ms_ops.mamba_chunk_scan(a, b, h0, chunk=64,
                                                   block_d=64))
    t_ref = _time(lambda: ms_ref.scan_ref(a, b, h0))
    print(f"mamba_scan_256_interp,{t_kern:.0f},ref={t_ref:.0f}us")

    # kf bank: fleet sizes (one filter per link x class x pod)
    for n in (1024, 16384, 131072):
        x = jnp.zeros((n,))
        p = jnp.ones((n,))
        z = jax.random.normal(key, (n, 3))
        h = jnp.ones((3,))
        r = jnp.full((3,), 0.2)
        t = _time(lambda: kf_ops.kf_bank_step(x, p, z, h, r))
        print(f"kf_bank_{n},{t:.0f},filters_per_s={n / t * 1e6:.2e}")

    # noc_cycle: arbitration lane kernel + fused full-cycle engine
    noc = noc_cycle_entries()
    print(f"noc_arb_lanes_{noc['mode']},{noc['arb_lanes_us']:.0f},"
          f"ref={noc['arb_ref_us']:.0f}us")
    print(f"noc_cycle_fused_{noc['mode']},{noc['sim_fused_us']:.0f},"
          f"ref={noc['sim_ref_us']:.0f}us per {noc['sim_cycles']}-cycle sim "
          f"({noc['fused_us_per_cycle']:.1f}us/cycle, "
          f"{noc['fused_vs_ref']:.2f}x vs ref)")

    if args.record:
        from benchmarks.bench_sweep import BENCH_PATH, append_record

        rec = {
            "bench": "noc_cycle_kernels",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "backend": jax.default_backend(),
            **noc,
        }
        append_record(rec)
        print(json.dumps(rec, indent=2))
        print(f"appended noc_cycle_kernels record to {BENCH_PATH}")


if __name__ == "__main__":
    main()
