"""Generate the EXPERIMENTS.md tables from results/dryrun artifacts."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load():
    rows = {}
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(p))
        name = os.path.basename(p)[:-5]
        rows[name] = r
    return rows


def fmt_s(x):
    return f"{x:9.4f}"


def baseline_table(rows, mesh):
    out = [
        "| arch | shape | status | compute_s | memory_s | collective_s | "
        "dominant | MODEL/HLO flops | HBM GB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for name, r in sorted(rows.items()):
        if r.get("mesh") != mesh or "_v" in name or "_flash" in name \
                or "_fused" in name or r.get("options", {}).get("flash"):
            continue
        if any(name.endswith(t) for t in ("_flash", "_sp", "_dots", "_nr",
                                          "_fused", "_v1")):
            continue
        rl = r.get("roofline", {})
        mem = r.get("memory", {})
        hbm = mem.get("total_hbm_bytes", 0) / 1e9
        uf = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} | "
            f"{rl.get('compute_s', 0):.4f} | {rl.get('memory_s', 0):.4f} | "
            f"{rl.get('collective_s', 0):.4f} | {rl.get('dominant', '—')} | "
            f"{uf:.2f} | {hbm:.1f} |"
            if r["status"] == "ok" else
            f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | — | — "
            f"| — | — |")
    return "\n".join(out)


def perf_table(rows, cells):
    out = [
        "| cell | config | compute_s | memory_s | collective_s | "
        "step (max) | vs baseline |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch, shape, mesh, tags in cells:
        base_key = f"{mesh}_{arch}_{shape}"
        base = rows.get(base_key)
        if not base or base["status"] != "ok":
            continue
        t0 = base["roofline"]["step_time_s"]
        for label, key in [("baseline", base_key)] + [
                (t, f"{mesh}_{arch}_{shape}_{t}") for t in tags]:
            r = rows.get(key)
            if not r or r.get("status") != "ok":
                continue
            rl = r["roofline"]
            out.append(
                f"| {arch} × {shape} ({mesh}) | {label} | "
                f"{rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
                f"{rl['collective_s']:.3f} | {rl['step_time_s']:.3f} | "
                f"{t0 / rl['step_time_s']:.2f}x |")
    return "\n".join(out)


def main():
    rows = load()
    print("### single-pod (16x16 = 256 chips)\n")
    print(baseline_table(rows, "pod"))
    print("\n### multi-pod (2x16x16 = 512 chips)\n")
    print(baseline_table(rows, "multipod"))
    print("\n### perf iterations\n")
    cells = [
        ("glm4-9b", "train_4k", "pod",
         ["flash", "flash_sp", "flash_sp_dots"]),
        ("glm4-9b", "train_4k", "multipod",
         ["v1_v1", "flash_sp", "v1_v1_flash_sp"]),
        ("falcon-mamba-7b", "train_4k", "pod",
         ["fused", "fused_dots", "fused_sp", "fused_sp_nr"]),
        ("llama3.2-3b", "prefill_32k", "pod",
         ["flash", "flash_sp", "flash_sp_nr"]),
    ]
    print(perf_table(rows, cells))


if __name__ == "__main__":
    main()
