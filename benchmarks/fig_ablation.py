"""Paper Figs. 9/10 predictor ablation: KF vs naive predictors, across the
scenario library (DESIGN.md §12).

The paper's central claim is that the *Kalman Filter's prediction quality*
— not merely "having a reconfiguration knob" — is what lets the network
follow traffic changes without thrashing.  This benchmark reproduces that
comparison: the same hysteresis machine (mode="kf") is driven by each
member of the predictor bank (KF / EMA / last-value / always-on /
always-off) over non-stationary scenario schedules (workload phase shift,
rate ramp, multi-program mix, deterministic burst train), and every
(scenario x predictor x seed) point runs through `sim.sweep` — predictor
and scenario are both traced data, so the whole grid shares the simulator's
ONE compiled program (`--gate` asserts it).

Gate (paper Fig. 9/10 qualitative ordering): on the phase-shift scenario
the KF's mean GPU IPC must be >= every naive predictor's.  Non-smoke runs
append a `noc_ablation` record to BENCH_noc.json, which
`benchmarks/check_bench.py` then tolerates-until-present and gates on.

    PYTHONPATH=src python -m benchmarks.fig_ablation [--smoke] [--gate]
                                                     [--devices N]
"""
from __future__ import annotations

import json
import math
import sys
import time

import jax

from repro.core.allocator import PolicyConfig
from repro.core.noc import sim
from repro.core.noc.sim import SweepSpec, summarize_seeds, sweep

PREDICTORS = ("kf", "ema", "last", "always_on", "always_off")
SCENARIO_SET = (
    "SHIFT_PATH_BFS", "RAMP_LIB", "MIX_PATH_STO_BFS", "BURSTS_BFS",
)
# the acceptance scenario: KF >= every naive predictor on mean GPU IPC here
GATE_SCENARIO = "SHIFT_PATH_BFS"
SEEDS = (0, 1, 2)

# Process-noise tuning for the ablation's KF (every predictor is
# parameterized for the scenario suite's timescale: EMA runs the textbook
# α=0.5, the KF a q matched to the ~30-epoch kernel arcs).  q=2e-2 gives an
# effective per-epoch gain of ~0.4: the posterior still rides a one-epoch
# inter-kernel dip (x ≈ 1 - 0.4*2 > 0) but releases within ~3 calm epochs,
# so the revert budget resets every arc instead of firing mid-burst the way
# the fig-12 default q=1e-3 (tuned to the free-Markov workloads' multi-
# thousand-cycle dwell times, release ~10 epochs) does on fast arcs.  The
# default-path goldens are untouched (kf_q is a SimStatic field, so this
# override compiles its own spec — shared by EVERY ablation point, keeping
# the grid at one trace — and never perturbs the default program).
KF_Q_ABLATION = 2e-2

# Smoke trims SEEDS and SCENARIOS, not the simulated dims: the gate
# scenario's observational structure is cycle-calibrated (the burst
# backlog takes ~1 epoch_len=500 to drain, which is what hides the dip's
# first epoch), so shrinking epoch_len or n_epochs erases the very dip the
# ablation discriminates on.  The pinned arcs make runs near-deterministic
# (cross-seed std ~0.001 vs gate margins ~0.005-0.015), so one seed on the
# gate scenario is a faithful CI-scale check.
SMOKE = dict(seeds=(0,), scenarios=(GATE_SCENARIO,))


def run(
    n_epochs: int = 120,
    seeds: tuple[int, ...] = SEEDS,
    scenarios: tuple[str, ...] = SCENARIO_SET,
    devices: int | None = None,
    **overrides,
) -> dict:
    """Sweep predictors x scenarios x seeds; summarize per cell.

    Means are taken from the first epoch the hysteresis machine may act
    (warmup/epoch_len), so always-off's head start on config 0 epochs does
    not dilute the comparison window.
    """
    overrides.setdefault("kf_q", KF_Q_ABLATION)
    specs = [
        SweepSpec("kf", sc, seed=s, predictor=p)
        for sc in scenarios for p in PREDICTORS for s in seeds
    ]
    sim.reset_trace_count()
    rows = sweep(specs, n_epochs=n_epochs, devices=devices, **overrides)
    traces = sim.trace_count()
    policy = overrides.get("policy", PolicyConfig())
    epoch_len = overrides.get("epoch_len", 500)
    warmup_epochs = min(math.ceil(policy.warmup / epoch_len), n_epochs - 1)
    by_cell: dict[tuple[str, str], list] = {}
    for sp, row in zip(specs, rows):
        by_cell.setdefault((sp.workload, sp.predictor), []).append(row)
    table = {
        sc: {
            p: summarize_seeds(by_cell[(sc, p)], warmup_epochs=warmup_epochs)
            for p in PREDICTORS
        }
        for sc in scenarios
    }
    return {"table": table, "traces": traces, "warmup_epochs": warmup_epochs}


def kf_verdict(table: dict, scenario: str = GATE_SCENARIO) -> dict:
    """KF-vs-naive margins on the gate scenario's mean GPU IPC.

    The verdict compares UNROUNDED margins (rounding only the reported
    values): a sub-rounding-quantum KF loss must still fail the gate.
    """
    cells = table[scenario]
    kf = cells["kf"]["gpu_ipc"]
    margins = {p: kf - cells[p]["gpu_ipc"] for p in PREDICTORS if p != "kf"}
    return {
        "scenario": scenario,
        "kf_gpu_ipc": round(kf, 6),
        "margins": {p: round(m, 6) for p, m in margins.items()},
        "kf_beats_all": all(m >= 0.0 for m in margins.values()),
    }


def record(res: dict, grid: dict, scenario: str = GATE_SCENARIO) -> dict:
    verdict = kf_verdict(res["table"], scenario)
    return {
        "bench": "noc_ablation",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "grid": grid,
        "traces": res["traces"],
        "gpu_ipc": {
            sc: {p: round(cells[p]["gpu_ipc"], 6) for p in PREDICTORS}
            for sc, cells in res["table"].items()
        },
        **verdict,
    }


def main(argv=None):
    from benchmarks import _cli

    ap = _cli.build_parser(
        __doc__,
        smoke_help="one seed on the gate scenario at full simulated "
                   "dims (see SMOKE); no BENCH_noc.json append",
        gate_help="exit 1 unless KF >= every naive predictor on the "
                  "phase-shift scenario AND the grid ran single-trace",
    )
    args = ap.parse_args(argv)
    from repro.obs import profiling

    n_epochs, overrides = 120, {"backend": args.backend}
    overrides.update(_cli.shared_overrides(args))
    if args.smoke:
        seeds, scenarios = SMOKE["seeds"], SMOKE["scenarios"]
    else:
        seeds, scenarios = SEEDS, SCENARIO_SET
    trace_wl = _cli.registered_trace(args)
    if trace_wl:
        # the replayed trace becomes both the scenario set and the gate
        scenarios = (trace_wl,)

    res = profiling.profiled_run(
        args.profile,
        lambda: run(n_epochs=n_epochs, seeds=seeds, scenarios=scenarios,
                    devices=args.devices, **overrides),
        label="fig_ablation",
    )
    print("scenario,predictor,gpu_ipc,gpu_ipc_std,cpu_ipc,avg_latency,"
          "boost_frac")
    for sc, cells in res["table"].items():
        for p, s in cells.items():
            print(f"{sc},{p},{s['gpu_ipc']:.4f},{s['gpu_ipc_std']:.4f},"
                  f"{s['cpu_ipc']:.4f},{s['avg_latency']:.2f},"
                  f"{s['kf_on_frac']:.2f}")

    gate_scenario = trace_wl or GATE_SCENARIO
    verdict = kf_verdict(res["table"], gate_scenario)
    print(f"# traces: {res['traces']} (contract: 1)")
    print(f"# {verdict['scenario']}: KF gpu_ipc {verdict['kf_gpu_ipc']:.4f}; "
          "margins vs naive: "
          + ", ".join(f"{p} {m:+.4f}" for p, m in verdict["margins"].items()))
    print(f"# kf_beats_all: {verdict['kf_beats_all']} "
          "(paper Fig. 9/10 ordering: KF >= every naive predictor)")

    if not args.smoke:
        from benchmarks.bench_sweep import BENCH_PATH, append_record

        grid = {"scenarios": list(scenarios), "predictors": list(PREDICTORS),
                "seeds": list(seeds), "n_epochs": n_epochs}
        rec = record(res, grid, gate_scenario)
        append_record(rec)
        print(json.dumps(rec, indent=2))
        print(f"appended noc_ablation record to {BENCH_PATH}")

    if args.gate:
        failures = []
        if res["traces"] != 1:
            failures.append(f"ablation grid traced simulate {res['traces']}x "
                            "(contract: the one shared program)")
        if not verdict["kf_beats_all"]:
            losing = {p: m for p, m in verdict["margins"].items() if m < 0}
            failures.append(
                f"KF lost to {losing} on {verdict['scenario']} mean GPU IPC")
        for f in failures:
            print(f"ABLATION GATE: {f}", file=sys.stderr)
        if failures:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
