"""Perf-trajectory harness for the fleet KF bank.

Times `FleetKF.epoch` (one banked predict+correct cycle through the Pallas
kf_bank kernel) at fleet sizes n in {64, 1024} filters and appends a record
to BENCH_noc.json, extending the perf trajectory started by bench_sweep to
the distribution subsystem.

    PYTHONPATH=src python -m benchmarks.bench_fleet_kf [--no-append]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_sweep import append_record
from repro.dist.kf_scheduler import FleetKF, SchedulerConfig

SIZES = (64, 1024)


def time_epoch(n: int, iters: int = 200, seed: int = 0) -> dict:
    fleet = FleetKF(n, SchedulerConfig(kf_q=1e-2, kf_r=1e-1))
    zs = jnp.asarray(
        np.random.default_rng(seed).normal(0, 0.5, (iters, n, 3)),
        jnp.float32)
    jax.block_until_ready(fleet.epoch(zs[0]))  # compile + first dispatch
    t0 = time.perf_counter()
    for t in range(iters):
        sig = fleet.epoch(zs[t])
    jax.block_until_ready(sig)
    dt = (time.perf_counter() - t0) / iters
    return {
        "n_filters": n,
        "iters": iters,
        "epoch_us": round(dt * 1e6, 2),
        "ns_per_filter": round(dt * 1e9 / n, 1),
    }


def run(sizes=SIZES) -> list[dict]:
    return [time_epoch(n) for n in sizes]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-append", action="store_true",
                    help="print only; don't extend BENCH_noc.json")
    args = ap.parse_args(argv)
    points = run()
    rec = {
        "bench": "fleet_kf_epoch",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "points": points,
    }
    print(json.dumps(rec, indent=2))
    if not args.no_append:
        append_record(rec)
        print("appended to BENCH_noc.json")
    return rec


if __name__ == "__main__":
    main()
