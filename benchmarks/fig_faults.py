"""Fault-injection study: the self-healing KF under fabric and telemetry
faults (DESIGN.md §16).

The paper's controller assumes clean counters and a healthy fabric; this
driver measures what the repo's KF allocator does when neither holds.
Every registered fault scenario (`faults.FAULTS`: link flaps, router
brownouts, telemetry NaN/spike/drop glitches, a flap landing mid
phase-shift) runs three arms over the ablation's gate scenario:

  * kf_guarded  — the KF with the self-healing layer armed (innovation
                  gate + divergence watchdog + covariance reset +
                  fair-split fallback while unhealthy);
  * kf          — the same KF unguarded (telemetry corruption poisons
                  the filter state; NaNs persist);
  * always_off  — the static fair split (config 0), the floor a degraded
                  controller is allowed to fall to.

Fault masks are traced scan inputs, so the whole healthy x faulty x
guarded grid shares the simulator's ONE compiled program (`--gate`
asserts it).  A healthy (faults=None) guard-on vs guard-off pair rides in
the grid and must be BITWISE equal: with clean telemetry the gate never
fires, so arming the guard costs nothing.

Gate (robustness ordering): under every fault scenario the guarded KF's
mean GPU IPC must be >= the unguarded KF's AND >= always_off's, the grid
single-trace, and the healthy pair bitwise.  Non-smoke runs also capture
a probed (flight-recorder) guarded run per scenario — innovation
rejections, covariance resets, fallback epochs — and append a
`noc_faults` ledger row that `benchmarks/check_bench.py`
tolerates-until-present and then gates on.

    PYTHONPATH=src python -m benchmarks.fig_faults [--smoke] [--gate]
                                                   [--faults NAME]
"""
from __future__ import annotations

import json
import math
import sys
import time

import jax
import numpy as np

from benchmarks.fig_ablation import KF_Q_ABLATION
from repro.core.allocator import PolicyConfig
from repro.core.noc import sim
from repro.core.noc.faults import FAULTS
from repro.core.noc.sim import (
    NoCConfig,
    SweepSpec,
    summarize_seeds,
    sweep,
)
from repro.obs.probes import summarize_trace

# Every registered fault scenario, in registry order.
FAULT_SET = tuple(FAULTS)
ARMS = ("kf_guarded", "kf", "always_off")
# Same scenario + KF tuning as the predictor ablation: the fault study
# asks "does the guard preserve the ablation's win under faults", so it
# must run the configuration that produced the win.
GATE_SCENARIO = "SHIFT_PATH_BFS"
SEEDS = (0, 1, 2)
# The healthy control cell's label in the results table.
HEALTHY = "healthy"

# Smoke trims seeds and the fault set (one physical + one telemetry
# scenario), not the simulated dims — the fault windows are phased
# against the gate scenario's full 120-epoch arc structure, so shrinking
# n_epochs would move the faults off the transients they target.
SMOKE = dict(seeds=(0,), fault_set=("FLAP_BFS", "TELEM_GLITCH"))


def _arm_spec(arm: str, faults: str | None, seed: int) -> SweepSpec:
    return SweepSpec(
        "kf", GATE_SCENARIO, seed=seed,
        predictor="always_off" if arm == "always_off" else "kf",
        faults=faults, guard=arm == "kf_guarded",
    )


def _bitwise_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def run(
    n_epochs: int = 120,
    seeds: tuple[int, ...] = SEEDS,
    fault_set: tuple[str, ...] = FAULT_SET,
    devices: int | None = None,
    probe: bool = True,
    **overrides,
) -> dict:
    """Sweep (healthy + fault scenarios) x arms x seeds; summarize + probe.

    Returns the per-cell summary table, the healthy guard-on/guard-off
    bitwise verdict, the sweep's trace count (captured BEFORE the probed
    runs — probes-on is deliberately its own compiled program), and one
    probed guarded run's self-healing counters per fault scenario.
    """
    overrides.setdefault("kf_q", KF_Q_ABLATION)
    cells: list[str | None] = [None] + list(fault_set)
    points = [(flt, arm, s) for flt in cells for arm in ARMS for s in seeds]
    specs = [_arm_spec(arm, flt, s) for flt, arm, s in points]
    sim.reset_trace_count()
    rows = sweep(specs, n_epochs=n_epochs, devices=devices, **overrides)
    traces = sim.trace_count()

    by_cell: dict[tuple[str | None, str], list] = {}
    for (flt, arm, _), row in zip(points, rows):
        by_cell.setdefault((flt, arm), []).append(row)

    policy = overrides.get("policy", PolicyConfig())
    epoch_len = overrides.get("epoch_len", 500)
    warmup_epochs = min(math.ceil(policy.warmup / epoch_len), n_epochs - 1)
    table = {
        (flt or HEALTHY): {
            arm: summarize_seeds(by_cell[(flt, arm)],
                                 warmup_epochs=warmup_epochs)
            for arm in ARMS
        }
        for flt in cells
    }

    # Healthy control: arming the guard on a clean fabric must be free —
    # bitwise, per seed, across the full SimResult.
    healthy_bitwise = all(
        _bitwise_equal(a, b)
        for a, b in zip(by_cell[(None, "kf_guarded")], by_cell[(None, "kf")])
    )

    probes = {}
    if probe:
        for flt in fault_set:
            cfg = NoCConfig(
                mode="kf", n_epochs=n_epochs, seed=seeds[0],
                predictor="kf", faults=flt, guard=True, **overrides,
            )
            _, trace = sim.simulate_with_trace(cfg, GATE_SCENARIO)
            s = summarize_trace(trace)
            probes[flt] = {
                k: s[k]
                for k in ("kf_rejected_total", "kf_reset_total",
                          "fallback_epochs", "fault_epochs")
            }

    return {
        "table": table,
        "traces": traces,
        "healthy_bitwise": healthy_bitwise,
        "probes": probes,
        "warmup_epochs": warmup_epochs,
    }


def guard_verdict(table: dict, fault_set: tuple[str, ...]) -> dict:
    """Per-scenario guarded-vs-{unguarded, always_off} GPU-IPC margins.

    Margins compare UNROUNDED values (rounding only the report): the gate
    must catch a sub-quantum ordering violation.
    """
    margins = {}
    for flt in fault_set:
        cells = table[flt]
        g = cells["kf_guarded"]["gpu_ipc"]
        margins[flt] = {
            "vs_kf": round(g - cells["kf"]["gpu_ipc"], 6),
            "vs_always_off": round(g - cells["always_off"]["gpu_ipc"], 6),
        }
    beats = all(
        table[flt]["kf_guarded"]["gpu_ipc"] >= table[flt][arm]["gpu_ipc"]
        for flt in fault_set for arm in ("kf", "always_off")
    )
    return {"margins": margins, "guard_beats_all": beats}


def record(res: dict, grid: dict, verdict: dict) -> dict:
    return {
        "bench": "noc_faults",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "scenario": GATE_SCENARIO,
        "grid": grid,
        "traces": res["traces"],
        "healthy_bitwise": res["healthy_bitwise"],
        "gpu_ipc": {
            flt: {arm: round(cells[arm]["gpu_ipc"], 6) for arm in ARMS}
            for flt, cells in res["table"].items()
        },
        "probes": res["probes"],
        **verdict,
    }


def main(argv=None):
    from benchmarks import _cli

    ap = _cli.build_parser(
        __doc__,
        smoke_help="one seed on one physical + one telemetry fault "
                   "scenario at full simulated dims (see SMOKE); no "
                   "BENCH_noc.json append",
        gate_help="exit 1 unless the guarded KF >= unguarded KF and >= "
                  "always_off under every fault scenario, the healthy "
                  "guard-on/off pair is bitwise, and the grid ran "
                  "single-trace",
        trace=False,
    )
    args = ap.parse_args(argv)
    from repro.obs import profiling

    n_epochs, overrides = 120, {"backend": args.backend}
    if args.smoke:
        seeds, fault_set = SMOKE["seeds"], SMOKE["fault_set"]
    else:
        seeds, fault_set = SEEDS, FAULT_SET
    if args.faults:
        # here the shared flag narrows the study to one scenario rather
        # than injecting it into every row (each row already carries its
        # own fault source)
        from repro.core.noc.faults import lookup_faults

        lookup_faults(args.faults)
        fault_set = (args.faults,)
    overrides.update(_cli.placement_overrides(args))
    overrides.update(_cli.topology_overrides(args))

    res = profiling.profiled_run(
        args.profile,
        lambda: run(n_epochs=n_epochs, seeds=seeds, fault_set=fault_set,
                    devices=args.devices, **overrides),
        label="fig_faults",
    )
    print("faults,arm,gpu_ipc,gpu_ipc_std,cpu_ipc,avg_latency,boost_frac")
    for flt, cells in res["table"].items():
        for arm, s in cells.items():
            print(f"{flt},{arm},{s['gpu_ipc']:.4f},{s['gpu_ipc_std']:.4f},"
                  f"{s['cpu_ipc']:.4f},{s['avg_latency']:.2f},"
                  f"{s['kf_on_frac']:.2f}")

    verdict = guard_verdict(res["table"], fault_set)
    print(f"# traces: {res['traces']} (contract: 1)")
    print(f"# healthy guard-on == guard-off bitwise: "
          f"{res['healthy_bitwise']}")
    for flt, m in verdict["margins"].items():
        p = res["probes"].get(flt, {})
        note = (f" [rejected {p['kf_rejected_total']}, resets "
                f"{p['kf_reset_total']}, fallback {p['fallback_epochs']} "
                f"of {p['fault_epochs']} fault epochs]" if p else "")
        print(f"# {flt}: guarded margin vs kf {m['vs_kf']:+.4f}, "
              f"vs always_off {m['vs_always_off']:+.4f}{note}")
    print(f"# guard_beats_all: {verdict['guard_beats_all']} "
          "(guarded KF >= unguarded KF and >= fair static split under "
          "every fault)")

    if not args.smoke:
        from benchmarks.bench_sweep import BENCH_PATH, append_record

        grid = {"fault_set": list(fault_set), "arms": list(ARMS),
                "seeds": list(seeds), "n_epochs": n_epochs,
                "kf_q": KF_Q_ABLATION}
        rec = record(res, grid, verdict)
        append_record(rec)
        print(json.dumps(rec, indent=2))
        print(f"appended noc_faults record to {BENCH_PATH}")

    if args.gate:
        failures = []
        if res["traces"] != 1:
            failures.append(f"fault grid traced simulate {res['traces']}x "
                            "(contract: the one shared program)")
        if not res["healthy_bitwise"]:
            failures.append("healthy guard-on run is not bitwise-equal to "
                            "guard-off (arming the guard must be free on "
                            "clean telemetry)")
        if not verdict["guard_beats_all"]:
            losing = {
                flt: m for flt, m in verdict["margins"].items()
                if min(m.values()) < 0
            }
            failures.append(f"guarded KF lost the robustness ordering on "
                            f"{losing}")
        for f in failures:
            print(f"FAULTS GATE: {f}", file=sys.stderr)
        if failures:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
