"""Perf-trajectory harness: serial-vs-batched NoC sweep timings.

Times the Fig. 2/3-style grid (workloads x static VC ratios x seeds) two
ways and appends a record to BENCH_noc.json so the speedup trajectory is
tracked across PRs:

  * serial  — the seed-repo execution model: one jit cache per (config,
              workload) tuple, i.e. XLA retraces and recompiles `simulate`
              for every grid point, then runs them one dispatch at a time.
  * batched — `sim.simulate_batch`: every point shares ONE compiled
              program (mode/ratio/rates/seed are traced data) and executes
              as lockstep batch dispatches.

Compile and steady-state wall-clock are reported separately: steady-state
is a second timed pass over already-compiled programs, and compile time is
the first-pass excess over it.

    PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke] [--seeds N]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.core.noc import sim
from repro.core.noc.traffic import PROFILES

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_noc.json")


def _grid(workloads, ratios, seeds, **overrides):
    cfgs, profs = [], []
    for wl in workloads:
        for g in ratios:
            for s in seeds:
                cfgs.append(sim.NoCConfig(
                    mode="static", static_gpu_vcs=g, seed=s, **overrides))
                profs.append(PROFILES[wl])
    return cfgs, profs


def _block(res):
    jax.block_until_ready(res)
    return res


def time_serial_seed_style(cfgs, profs) -> float:
    """Seed-repo model: `simulate` was jitted with the WHOLE config and the
    workload profile as static arguments, so XLA retraced and recompiled for
    every (config, workload) grid point.  A fresh function identity per
    point reproduces that (jit's cache is keyed on the underlying function,
    so merely re-wrapping `_simulate_impl` would share one compilation and
    understate the seed's cost)."""
    t0 = time.perf_counter()
    for cfg, prof in zip(cfgs, profs):
        def point(stc, mp, profile, seed, state0):
            return sim._simulate_impl(stc, mp, profile, seed, state0)

        fresh = jax.jit(point, static_argnums=0)
        stc = cfg.static_spec()
        _block(fresh(stc, cfg.mode_policy(), prof, cfg.seed,
                     sim.init_sim_state(stc)))
    return time.perf_counter() - t0


def time_serial_steady(cfgs, profs) -> float:
    """Serial dispatches through the shared (pre-warmed) executable."""
    _block(sim.simulate(cfgs[0], profs[0]))  # warm the cache
    t0 = time.perf_counter()
    for cfg, prof in zip(cfgs, profs):
        _block(sim.simulate(cfg, prof))
    return time.perf_counter() - t0


def run(n_epochs: int = 8, epoch_len: int = 100,
        seeds=(0, 1), smoke: bool = False) -> dict:
    """Default grid: 24 points x 800 cycles — the smoke/--fast sweep regime
    where the seed's per-point recompile dominated wall-clock.  (On CPU the
    batched engine's steady-state is ~1x — same total work, scan-bound — so
    the end-to-end win *is* compile amortization; the JSON reports both
    components separately, and accelerator backends add execution-side
    batch parallelism on top.)"""
    workloads = ("PATH", "LIB") if smoke else ("PATH", "LIB", "STO", "MUM")
    ratios = (1, 3) if smoke else (1, 2, 3)
    if smoke:
        n_epochs, epoch_len, seeds = 4, 50, (0,)
    ov = dict(n_epochs=n_epochs, epoch_len=epoch_len)
    cfgs, profs = _grid(workloads, ratios, seeds, **ov)

    serial_total = time_serial_seed_style(cfgs, profs)

    t0 = time.perf_counter()
    _block(sim.simulate_batch(cfgs, profs))
    batched_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    _block(sim.simulate_batch(cfgs, profs))
    batched_steady = time.perf_counter() - t0

    serial_steady = time_serial_steady(cfgs, profs)

    rec = {
        "bench": "noc_sweep_serial_vs_batched",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "smoke": smoke,
        "grid": {"workloads": list(workloads), "ratios": list(ratios),
                 "seeds": list(seeds), "n_epochs": n_epochs,
                 "epoch_len": epoch_len, "n_points": len(cfgs)},
        "serial_total_s": round(serial_total, 3),
        "serial_steady_s": round(serial_steady, 3),
        "serial_compile_s": round(max(serial_total - serial_steady, 0.0), 3),
        "batched_total_s": round(batched_first, 3),
        "batched_steady_s": round(batched_steady, 3),
        "batched_compile_s": round(max(batched_first - batched_steady, 0.0), 3),
        "speedup_end_to_end": round(serial_total / max(batched_first, 1e-9), 2),
        "speedup_steady": round(serial_steady / max(batched_steady, 1e-9), 2),
    }
    return rec


def append_record(rec: dict, path: str = BENCH_PATH) -> None:
    records = []
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    records.append(rec)
    with open(path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no BENCH_noc.json append)")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--epoch-len", type=int, default=100)
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args(argv)
    rec = run(n_epochs=args.epochs, epoch_len=args.epoch_len,
              seeds=tuple(range(args.seeds)), smoke=args.smoke)
    print(json.dumps(rec, indent=2))
    if not args.smoke:
        append_record(rec)
        print(f"appended to {os.path.normpath(BENCH_PATH)}")
    ratio = rec["speedup_end_to_end"]
    print(f"end-to-end speedup over serial seed path: {ratio:.1f}x "
          f"(steady-state {rec['speedup_steady']:.1f}x)")
    return rec


if __name__ == "__main__":
    main()
