"""Perf-trajectory harness: serial vs batched vs device-sharded NoC sweeps.

Times the Fig. 2/3-style grid (workloads x static VC ratios x seeds) and
appends records to BENCH_noc.json so the speedup trajectory is tracked
across PRs:

  * serial  — the seed-repo execution model: one jit cache per (config,
              workload) tuple, i.e. XLA retraces and recompiles `simulate`
              for every grid point, then runs them one dispatch at a time.
  * batched — `sim.simulate_batch`: every point (2-subnet AND 4-subnet,
              since the S-padding refactor) shares ONE compiled program and
              executes as lockstep batch dispatches.
  * sharded — `--devices N`: the same batch split data-parallel over N
              devices through the shard_map path; results are asserted
              equal to the batched arm before timing is reported.

Compile and steady-state wall-clock are reported separately: steady-state
is a second timed pass over already-compiled programs, and compile time is
the first-pass excess over it.

    PYTHONPATH=src python -m benchmarks.bench_sweep \
        [--smoke] [--seeds N] [--devices N]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.core.noc import sim
from repro.core.noc.traffic import PROFILES, resolve_source

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_noc.json")


def _grid(workloads, ratios, seeds, **overrides):
    cfgs, profs = [], []
    for wl in workloads:
        for g in ratios:
            for s in seeds:
                cfgs.append(sim.NoCConfig(
                    mode="static", static_gpu_vcs=g, seed=s, **overrides))
                profs.append(PROFILES[wl])
    return cfgs, profs


def _block(res):
    jax.block_until_ready(res)
    return res


def _fresh_jit(fn):
    """Wrap `fn` in jit under a NEW function identity.

    jax.jit's cache is keyed on the *underlying function object*, so
    re-wrapping the same function merely returns the cached executable; only
    a fresh `def` per call site forces the recompile that the seed repo paid
    per grid point.  Keep the serial baseline on this helper — timing the
    shared-cache path instead silently reads ~1x and buries the regression
    this harness exists to track.
    """
    def point(stc, mp, profile, seed, state0, faults, placement):
        return fn(stc, mp, profile, seed, state0, faults, placement)

    return jax.jit(point, static_argnums=0)


def time_serial_seed_style(cfgs, profs) -> float:
    """Seed-repo model: `simulate` was jitted with the WHOLE config and the
    workload profile as static arguments, so XLA retraced and recompiled for
    every (config, workload) grid point (see `_fresh_jit`).

    Runs the mode's DEDICATED (padded=False) trace: the seed repo predates
    S/V padding, so timing the padded program here would overstate the
    baseline's cost ~2x and break row-to-row trajectory comparability in
    BENCH_noc.json."""
    t0 = time.perf_counter()
    for cfg, prof in zip(cfgs, profs):
        fresh = _fresh_jit(sim._simulate_impl)
        stc = cfg.static_spec(padded=False)
        _block(fresh(stc, cfg.mode_policy(padded=False),
                     resolve_source(prof, stc.n_epochs), cfg.seed,
                     sim.init_sim_state(stc), sim._run_faults(None, stc),
                     sim._run_placement(None, stc)))
    return time.perf_counter() - t0


def time_serial_steady(cfgs, profs) -> float:
    """Serial dispatches through the shared (pre-warmed) dedicated
    executable (padded=False, matching the seed-style arm)."""
    _block(sim.simulate(cfgs[0], profs[0], padded=False))  # warm the cache
    t0 = time.perf_counter()
    for cfg, prof in zip(cfgs, profs):
        _block(sim.simulate(cfg, prof, padded=False))
    return time.perf_counter() - t0


def _assert_batches_equal(a, b, label: str) -> None:
    for (path, x), (_, y) in zip(
        jax.tree_util.tree_leaves_with_path(a),
        jax.tree_util.tree_leaves_with_path(b),
    ):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), atol=1e-6, rtol=1e-6,
            err_msg=f"{label}: leaf {jax.tree_util.keystr(path)}",
        )


def state_bytes(stc) -> int:
    """Per-row carry footprint of the packed cycle-engine state (bytes)."""
    leaves = jax.tree.leaves(sim.init_sim_state(stc))
    return int(sum(x.size * x.dtype.itemsize for x in leaves))


def run(n_epochs: int = 8, epoch_len: int = 100,
        seeds=(0, 1), smoke: bool = False, devices: int | None = None,
        sim_backend: str = "ref") -> dict:
    """Default grid: 24 points x 800 cycles — the smoke/--fast sweep regime
    where the seed's per-point recompile dominated wall-clock.

    Reading the record: on CPU the end-to-end win is compile amortization
    (N dedicated compiles -> 1).  Steady-state was the weak axis of the
    S/V-padded single-trace program until the packed-lane cycle engine
    (DESIGN.md §11) — the padded program's per-dispatch cost now tracks the
    dedicated traces (full-grid `speedup_steady` ~1x, up from 0.39), so a
    full-grid row regressing on it is a real engine cliff and
    `benchmarks/check_bench.py` gates it.  SMOKE rows are different: their
    steady pass is milliseconds of scan against fixed per-op dispatch
    overhead, swinging 0.2-1x run to run — meaningless for trend-reading,
    which is why only full rows land in BENCH_noc.json.

    `sim_backend` switches the BATCHED arm's cycle engine ("ref" |
    "pallas" fused full-cycle kernel | "pallas_arb"); the serial arms
    always run the dense ref engine so every row's serial baseline stays
    comparable across the committed trajectory, and the resulting
    `speedup_*` is the honest serial-ref-vs-batched-<backend> number
    (interpret-mode Pallas on CPU — see `check_bench.check_pallas_row`)."""
    workloads = ("PATH", "LIB") if smoke else ("PATH", "LIB", "STO", "MUM")
    ratios = (1, 3) if smoke else (1, 2, 3)
    if smoke:
        n_epochs, epoch_len, seeds = 4, 50, (0,)
    ov = dict(n_epochs=n_epochs, epoch_len=epoch_len, backend=sim_backend)
    cfgs, profs = _grid(workloads, ratios, seeds, **ov)
    ref_cfgs = (
        cfgs if sim_backend == "ref"
        else [dataclasses.replace(c, backend="ref") for c in cfgs]
    )

    serial_total = time_serial_seed_style(ref_cfgs, profs)

    sim.reset_trace_count()
    t0 = time.perf_counter()
    batched_res = _block(sim.simulate_batch(cfgs, profs))
    batched_first = time.perf_counter() - t0
    batched_traces = sim.trace_count()
    t0 = time.perf_counter()
    _block(sim.simulate_batch(cfgs, profs))
    batched_steady = time.perf_counter() - t0

    serial_steady = time_serial_steady(ref_cfgs, profs)

    stc = cfgs[0].static_spec()
    rec = {
        "bench": "noc_sweep_serial_vs_batched",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "sim_backend": stc.backend,
        "cycle_unroll": stc.cycle_unroll,
        "state_bytes": state_bytes(stc),
        "smoke": smoke,
        "grid": {"workloads": list(workloads), "ratios": list(ratios),
                 "seeds": list(seeds), "n_epochs": n_epochs,
                 "epoch_len": epoch_len, "n_points": len(cfgs)},
        "serial_total_s": round(serial_total, 3),
        "serial_steady_s": round(serial_steady, 3),
        "serial_compile_s": round(max(serial_total - serial_steady, 0.0), 3),
        "batched_total_s": round(batched_first, 3),
        "batched_steady_s": round(batched_steady, 3),
        "batched_compile_s": round(max(batched_first - batched_steady, 0.0), 3),
        "batched_traces": batched_traces,
        "speedup_end_to_end": round(serial_total / max(batched_first, 1e-9), 2),
        "speedup_steady": round(serial_steady / max(batched_steady, 1e-9), 2),
    }
    if devices is not None:
        rec["sharded"] = run_sharded(cfgs, profs, devices, batched_res,
                                     batched_steady)
    return rec


def run_sharded(cfgs, profs, devices: int, batched_res,
                batched_steady: float) -> dict:
    """Time the device-sharded dispatch and pin it equal to the batched arm.

    The equivalence assert runs before any timing is reported: a sharded
    path that drifts numerically must fail the bench (and the CI job built
    on it), not report a speedup.
    """
    n_dev = len(jax.devices())
    if devices > n_dev:
        raise SystemExit(
            f"--devices {devices} but only {n_dev} available; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count on CPU")
    t0 = time.perf_counter()
    sharded_res = _block(sim.simulate_batch(cfgs, profs, devices=devices))
    sharded_first = time.perf_counter() - t0
    _assert_batches_equal(sharded_res, batched_res, "sharded vs batched")
    t0 = time.perf_counter()
    _block(sim.simulate_batch(cfgs, profs, devices=devices))
    sharded_steady = time.perf_counter() - t0
    return {
        "bench": "noc_sweep_sharded",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "devices": devices,
        "n_points": len(cfgs),
        "sharded_total_s": round(sharded_first, 3),
        "sharded_steady_s": round(sharded_steady, 3),
        "sharded_compile_s": round(
            max(sharded_first - sharded_steady, 0.0), 3),
        "steady_speedup_vs_batched": round(
            batched_steady / max(sharded_steady, 1e-9), 2),
        "equivalent_to_batched": True,  # asserted above
    }


def append_record(rec: dict, path: str = BENCH_PATH) -> None:
    """Append a bench row via the run ledger (repro.obs.ledger).

    Every driver in benchmarks/ funnels through here, so the ledger is the
    single append path: rows get stamped with provenance (git sha, device
    kind, ledger_version), schema-validated before the write, and mirrored
    to the gitignored LEDGER_noc.jsonl next to BENCH_noc.json.
    """
    from repro.obs import ledger

    ledger.append(rec, path=path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no BENCH_noc.json append)")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--epoch-len", type=int, default=100)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--devices", type=int, default=None,
                    help="also time the device-sharded dispatch over N "
                         "devices (asserts equality with the batched arm)")
    ap.add_argument("--backend", choices=("ref", "pallas", "pallas_arb"),
                    default="ref",
                    help="cycle engine for the batched arm (serial arms "
                         "always time the dense ref engine)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="capture one jax.profiler trace of the whole run "
                         "into DIR (the harness already separates compile "
                         "vs steady phases internally)")
    args = ap.parse_args(argv)
    from repro.obs import profiling

    with profiling.trace(args.profile, "bench_sweep"):
        rec = run(n_epochs=args.epochs, epoch_len=args.epoch_len,
                  seeds=tuple(range(args.seeds)), smoke=args.smoke,
                  devices=args.devices, sim_backend=args.backend)
    sharded = rec.pop("sharded", None)
    print(json.dumps(rec, indent=2))
    if sharded is not None:
        print(json.dumps(sharded, indent=2))
    if not args.smoke:
        append_record(rec)
        if sharded is not None:
            append_record(sharded)
        print(f"appended to {os.path.normpath(BENCH_PATH)}")
    ratio = rec["speedup_end_to_end"]
    print(f"end-to-end speedup over serial seed path: {ratio:.1f}x "
          f"(steady-state {rec['speedup_steady']:.1f}x, "
          f"{rec['batched_traces']} trace(s))")
    if sharded is not None:
        print(f"sharded over {sharded['devices']} devices: steady "
              f"{sharded['steady_speedup_vs_batched']:.2f}x vs batched, "
              f"results equivalent")
    return rec


if __name__ == "__main__":
    main()
